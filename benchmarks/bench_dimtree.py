"""Beyond-paper: dimension-tree ALS sweep vs standard sweep (wall clock).

The paper's Sec. 6 predicts ~2x per-iteration CP-ALS gain in 4-D from reusing
partial MTTKRPs across modes (Phan et al. III.C).  The dry-run confirms the
byte/flop model at pod scale (EXPERIMENTS SPerf cell 1); this benchmark
confirms it in real single-core time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import random_factors, random_tensor, tensor_norm
from repro.core.cpals import als_sweep
from repro.core.dimtree import dimtree_sweep

from .util import row, time_fn

C = 16


def run(full: bool = False) -> list[str]:
    out = []
    shapes = [(64, 64, 64, 64), (32, 32, 32, 32, 32)]
    if full:
        shapes = [(160, 160, 160, 160), (64,) * 5]
    for shape in shapes:
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        w = jnp.ones((C,), x.dtype)
        norm_x = tensor_norm(x)
        it = jnp.asarray(1)

        std = jax.jit(lambda xx, fs, ww: als_sweep(xx, fs, ww, norm_x, it, "auto", True))
        dt = jax.jit(lambda xx, fs, ww: dimtree_sweep(xx, fs, ww, norm_x, it))
        t_std = time_fn(std, x, factors, w, reps=3)["median_s"]
        t_dt = time_fn(dt, x, factors, w, reps=3)["median_s"]
        out.append(
            row(
                f"dimtree_N{len(shape)}_{shape[0]}",
                t_dt,
                f"standard_sweep_s={t_std:.4f};speedup={t_std/t_dt:.2f}x",
            )
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
