"""Benchmark harness entry point -- one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Sections:
  Fig.4  KRP reuse vs naive vs STREAM proxy          (bench_krp)
  Fig.5/6 MTTKRP 1-step / 2-step / reorder baseline  (bench_mttkrp)
  Fig.7/8 CP-ALS per-iteration, fMRI-shaped tensors  (bench_cpals)
  Sec.6  fused-kernel byte model + correctness        (bench_kernels)
  Roofline table from dry-run artifacts (if present)  (roofline_report)

``--full`` restores paper-scale shapes (minutes-to-hours on one core).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--only",
        nargs="*",
        choices=["krp", "mttkrp", "cpals", "kernels", "dimtree", "roofline"],
        default=None,
    )
    args = ap.parse_args()

    from . import (
        bench_cpals,
        bench_dimtree,
        bench_kernels,
        bench_krp,
        bench_mttkrp,
        roofline_report,
    )

    sections = {
        "krp": lambda: bench_krp.run(args.full),
        "mttkrp": lambda: bench_mttkrp.run(args.full),
        "cpals": lambda: bench_cpals.run(args.full),
        "kernels": lambda: bench_kernels.run(args.full),
        "dimtree": lambda: bench_dimtree.run(args.full),
        "roofline": lambda: roofline_report.csv_rows(full=args.full),
    }
    chosen = args.only or list(sections)

    print("name,us_per_call,derived")
    failures = 0
    for name in chosen:
        print(f"# --- {name} ---")
        try:
            for line in sections[name]():
                print(line, flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            traceback.print_exc()
            print(f"# section {name} FAILED", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
