"""Serving throughput: packed fixed-shape batches vs serial dispatch.

A fleet of small same-rank tensors over two shapes (two signatures -- the
fMRI-style workload of the paper's Sec. 6, one subject = one tensor) is
submitted to :class:`repro.serve.CPService` and drained once per batch size.
``batch_size=1`` is the serial baseline (one dispatch per tensor);
the packed rows amortize dispatch overhead over ``B`` problems per compiled
call, so problems/sec must beat serial -- that ratio
(``speedup_packed_vs_serial``) is the acceptance number of the committed
baseline ``benchmarks/BENCH_serve.json``.

Per batch size the JSON row records problems/sec (real problems over
in-dispatch seconds), end-to-end p50/p99 submit-to-result latency (queue
wait included -- packing trades tail latency for throughput and the rows
show both sides), batch occupancy (real-slot fraction: partial batches pad
by cycling real requests), and the serving counters (batches, compiles --
exactly one per signature, warm-plan hits).  Every service is warmed with
one full flush first so compile time never pollutes the measured drain
(compiles are counted in the warm pass and asserted unchanged after).

    PYTHONPATH=src python -m benchmarks.bench_serve --smoke --json out.json
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.util import row
from repro.core.tensor_ops import random_tensor
from repro.serve import CPService


def _fleet(shapes, n_requests):
    """n_requests tensors cycling over ``shapes`` (a mixed-signature stream)."""
    return [
        random_tensor(jax.random.PRNGKey(i), shapes[i % len(shapes)])
        for i in range(n_requests)
    ]


def bench_batch_size(tensors, rank, batch_size, n_iters):
    """One serving row: warm flush (compiles), then the timed drain."""
    svc = CPService(batch_size=batch_size, n_iters=n_iters)
    # warm pass: every signature plans + compiles its dispatch here
    for x in tensors:
        svc.submit(x, rank)
    svc.flush()
    compiles = svc.stats()["compiles"]
    warm_execute_s = svc.stats()["execute_s"]

    for x in tensors:
        svc.submit(x, rank)
    t0 = time.perf_counter()
    done = svc.flush()
    wall_s = time.perf_counter() - t0
    stats = svc.stats()
    assert len(done) == len(tensors)
    assert stats["compiles"] == compiles, "timed drain must be compile-free"

    lat = np.asarray(sorted(f.result().latency_s for f in done))
    timed_execute_s = stats["execute_s"] - warm_execute_s
    return {
        "batch_size": batch_size,
        "serial": batch_size == 1,
        "requests": len(tensors),
        "wall_s": wall_s,
        "execute_s": timed_execute_s,
        "problems_per_s": len(tensors) / timed_execute_s,
        "problems_per_s_wall": len(tensors) / wall_s,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "batches": stats["batches"] // 2,  # warm + timed drains are identical
        "batch_occupancy": stats["batch_occupancy"],
        "signatures": stats["signatures"],
        "compiles": compiles,
        "warm_plan_hits": stats["warm_plan_hits"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny shapes, CI artifact path")
    ap.add_argument("--json", default=None, help="write the rows to this file")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rank", type=int, default=None)
    ap.add_argument("--n-iters", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None, help="edge of the cubic shape")
    ap.add_argument("--batch-sizes", default=None, help="comma list, 1 = serial")
    args = ap.parse_args()

    if args.smoke:
        requests = args.requests or 16
        rank, n_iters, dim = args.rank or 4, args.n_iters or 3, args.dim or 8
        batch_sizes = args.batch_sizes or "1,4,8"
    else:
        requests = args.requests or 64
        rank, n_iters, dim = args.rank or 8, args.n_iters or 10, args.dim or 32
        batch_sizes = args.batch_sizes or "1,4,8,16"
    sizes = [int(s) for s in batch_sizes.split(",")]
    shapes = [(dim,) * 3, (dim, dim // 2, dim)]
    tensors = _fleet(shapes, requests)

    rows = []
    for b in sizes:
        r = bench_batch_size(tensors, rank, b, n_iters)
        rows.append(r)
        tag = "serial" if r["serial"] else f"packed-B{b}"
        print(row(
            f"serve_{tag}",
            r["execute_s"] / requests,
            f"{r['problems_per_s']:.1f}/s p50={r['p50_latency_s'] * 1e3:.1f}ms "
            f"p99={r['p99_latency_s'] * 1e3:.1f}ms occ={r['batch_occupancy']:.2f}",
        ))

    serial = next(r for r in rows if r["serial"])
    packed = max((r for r in rows if not r["serial"]),
                 key=lambda r: r["problems_per_s"], default=None)
    speedup = packed["problems_per_s"] / serial["problems_per_s"] if packed else None
    if packed:
        print(row("serve_speedup_packed_vs_serial", 0.0, f"{speedup:.2f}x"))

    out = {
        "smoke": bool(args.smoke),
        "requests": requests,
        "rank": rank,
        "n_iters": n_iters,
        "shapes": [list(s) for s in shapes],
        "device_count": jax.device_count(),
        "rows": rows,
        "speedup_packed_vs_serial": speedup,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
