"""Paper Figs. 7-8: CP-ALS per-iteration time on fMRI-shaped tensors.

The application tensors are 225 x 59 x 200 x 200 (4D) and, linearizing the
symmetric region-region modes, 225 x 59 x 19900 (3D).  Default here scales
regions down 2x (100x100 / 4950) for single-core wall times; --full restores
paper shapes.  We compare the paper's recommended mixed method ('auto':
1-step external + 2-step internal) against the reorder-baseline and the
plain einsum formulation, for C in {10, 25}.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CPConfig, cp_als, random_tensor

from .util import row


def _tensors(full: bool):
    r = 200 if full else 100
    key = jax.random.PRNGKey(3)
    x4 = random_tensor(key, (225, 59, r, r))
    # symmetrize region modes then linearize upper triangle incl. diagonal
    x4 = 0.5 * (x4 + jnp.swapaxes(x4, 2, 3))
    iu = jnp.triu_indices(r)
    x3 = x4[:, :, iu[0], iu[1]]
    return {"4d": x4, "3d": x3}


def _per_iter_seconds(x, rank: int, method: str, iters: int = 3) -> float:
    times: list[float] = []
    cp_als(
        x,
        CPConfig(rank=rank, n_iters=iters, tol=0.0, method=method, track_fit=False),
        callback=lambda it, fit, dt: times.append(dt),
    )
    return min(times[1:]) if len(times) > 1 else times[0]  # skip compile iter


def run(full: bool = False) -> list[str]:
    out = []
    for name, x in _tensors(full).items():
        for rank in (10, 25):
            t_auto = _per_iter_seconds(x, rank, "auto")
            t_base = _per_iter_seconds(x, rank, "baseline")
            t_1 = _per_iter_seconds(x, rank, "1step")
            t_2 = _per_iter_seconds(x, rank, "2step")
            out.append(
                row(
                    f"cpals_{name}_C{rank}_auto",
                    t_auto,
                    f"shape={tuple(x.shape)};baseline_s={t_base:.3f};"
                    f"speedup={t_base/t_auto:.2f}x;"
                    f"pure_1step_s={t_1:.3f};pure_2step_s={t_2:.3f}",
                )
            )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
