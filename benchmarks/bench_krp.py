"""Paper Fig. 4: KRP with reuse vs naive vs STREAM-proxy.

The paper times Alg. 1 ("Reuse") against a no-reuse row-wise algorithm
("Naive") and the STREAM copy-scale bandwidth bound, for Z in {2,3,4} input
matrices, C in {25,50} columns, ~2e7 output rows.  This container has one
core, so rows default to 2e6 (same memory-bound regime; --full restores the
paper scale) and the expected reuse speedup is the algorithmic flop ratio
(Z-1 Hadamards/row -> ~1), which reproduces independent of thread count.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import krp, krp_naive

from .util import row, time_fn


def stream_proxy(out_rows: int, c: int) -> float:
    """Read+scale+write of an output-sized matrix (STREAM scale analogue)."""
    x = jnp.ones((out_rows, c), jnp.float32)
    fn = jax.jit(lambda a: a * 1.000001)
    return time_fn(fn, x, reps=3)["median_s"]


def run(full: bool = False) -> list[str]:
    rows_target = 20_000_000 if full else 2_000_000
    out = []
    for c in (25, 50):
        stream = stream_proxy(rows_target, c)
        out.append(row(f"krp_stream_proxy_C{c}", stream, f"rows={rows_target}"))
        for z in (2, 3, 4):
            dim = round(rows_target ** (1.0 / z))
            mats = [
                jax.random.normal(jax.random.PRNGKey(i), (dim, c), jnp.float32)
                for i in range(z)
            ]
            reuse_fn = jax.jit(lambda *ms: krp(list(ms)))
            naive_fn = jax.jit(lambda *ms: krp_naive(list(ms)))
            t_reuse = time_fn(reuse_fn, *mats, reps=3)["median_s"]
            t_naive = time_fn(naive_fn, *mats, reps=3)["median_s"]
            t_multi = time_fn(_naive_multipass, mats, reps=3)["median_s"]
            out.append(
                row(
                    f"krp_reuse_Z{z}_C{c}",
                    t_reuse,
                    f"rows={dim**z};naive_fused_s={t_naive:.4f};"
                    f"naive_multipass_s={t_multi:.4f};"
                    f"speedup_vs_fused={t_naive/t_reuse:.2f}x;"
                    f"speedup_vs_multipass={t_multi/t_reuse:.2f}x;"
                    f"vs_stream={t_reuse/stream:.2f}x",
                )
            )
    return out


@jax.jit
def _gather_rows(u, idx):
    return u[idx]


@jax.jit
def _hadamard(a, b):
    return a * b


def _naive_multipass(mats):
    """The paper's actual Naive semantics: no reuse, each of the Z-1 Hadamard
    products is a separate full-size pass (separate jits block fusion --
    matching the unfused row-wise C loop of the paper's comparator)."""
    import numpy as np

    dims = [m.shape[0] for m in mats]
    grids = np.meshgrid(*[np.arange(d) for d in dims], indexing="ij")
    out = _gather_rows(mats[0], jnp.asarray(grids[0].ravel()))
    for u, g in zip(mats[1:], grids[1:]):
        rows = _gather_rows(u, jnp.asarray(g.ravel()))
        out = _hadamard(out, rows)
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
