"""Paper Sec. 6 follow-through: fused-MTTKRP kernel vs explicit-KRP paths.

No TPU in this container, so the Pallas kernel's *performance* claim is made
with the roofline byte model (what the fusion removes from HBM traffic):

    1-step writes + reads the full KRP:   2 * L*R*C * 4 bytes extra
    2-step materializes the partial GEMM: L*I_n*C (or I_n*R*C) extra
    fused:                                 0 extra (KRP tiles live in VMEM)

We report those analytic deltas per shape alongside interpret-mode
correctness (max |err| vs the einsum oracle) and the XLA wall time of the
1-step/2-step paths for context.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import numpy as np

from repro.core import mttkrp_1step, mttkrp_2step, mttkrp_einsum, mttkrp_flops
from repro.core import random_factors, random_tensor
from repro.kernels import ops

from .util import row, time_fn

SHAPES = [(256, 64, 256), (64, 64, 64, 64), (32, 16, 32, 16, 32)]
C = 32

# matrix-free vs reshape+GEMM section: one shape per supported order, sized
# so the 1-step fallback's KRP materialization dominates (where the
# streaming kernel's zero-intermediate-bytes model predicts the win)
MATRIX_FREE_SHAPES = {
    3: (64, 48, 64),
    4: (24, 20, 24, 20),
    5: (16, 12, 16, 12, 16),
    6: (8, 8, 8, 8, 8, 8),
}
MATRIX_FREE_C = 16


def matrix_free_section(reps: int = 3) -> dict:
    """Per (order, mode): analytic bytes-moved + measured ms, matrix-free
    Pallas kernel (interpret mode on CPU) vs the reshape+GEMM 1-step path."""
    from repro.plan import Problem
    from repro.plan.cost import mode_cost

    entries = []
    for order, shape in sorted(MATRIX_FREE_SHAPES.items()):
        x = random_tensor(jax.random.PRNGKey(order), shape)
        factors = random_factors(jax.random.PRNGKey(100 + order), shape, MATRIX_FREE_C)
        problem = Problem(shape=shape, rank=MATRIX_FREE_C)
        for n in range(order):
            # generous tiles: whole target mode per block, reduction blocks
            # capped by the VMEM element budget inside the wrapper
            t_mf = time_fn(
                lambda a, f, n=n: ops.matrix_free_mttkrp(
                    a, f, n, block_i=128, block_r=32
                ),
                x, factors, reps=reps,
            )
            t_1s = time_fn(
                jax.jit(lambda a, f, n=n: mttkrp_1step(a, f, n)), x, factors, reps=reps
            )
            err = float(
                np.max(
                    np.abs(
                        np.asarray(
                            ops.matrix_free_mttkrp(x, factors, n, block_i=128, block_r=32)
                        )
                        - np.asarray(mttkrp_einsum(x, factors, n))
                    )
                )
            )
            bytes_mf = mode_cost(problem, n, "matrix_free").bytes
            bytes_1s = mode_cost(problem, n, "1step").bytes
            entries.append(
                {
                    "order": order,
                    "mode": n,
                    "shape": list(shape),
                    "rank": MATRIX_FREE_C,
                    "bytes_matrix_free": bytes_mf,
                    "bytes_1step": bytes_1s,
                    "bytes_saved": bytes_1s - bytes_mf,
                    "ms_matrix_free": t_mf["median_s"] * 1e3,
                    "ms_1step": t_1s["median_s"] * 1e3,
                    "speedup_vs_1step": t_1s["median_s"] / t_mf["median_s"],
                    "max_err_vs_einsum": err,
                    "wins_ms": t_mf["median_s"] < t_1s["median_s"],
                    "wins_bytes": bytes_mf < bytes_1s,
                }
            )
    return {
        "section": "matrix_free_vs_reshape_gemm",
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "entries": entries,
        "n_wins_ms": sum(e["wins_ms"] for e in entries),
        "n_wins_bytes": sum(e["wins_bytes"] for e in entries),
    }


def matrix_free_rows(section: dict) -> list[str]:
    return [
        row(
            f"matrix_free_o{e['order']}_m{e['mode']}",
            e["ms_matrix_free"] / 1e3,
            f"ms_1step={e['ms_1step']:.3f};bytes_saved={e['bytes_saved']:.3e};"
            f"speedup={e['speedup_vs_1step']:.2f};err={e['max_err_vs_einsum']:.1e}",
        )
        for e in section["entries"]
    ]


def run(full: bool = False) -> list[str]:
    out = []
    for shape in SHAPES:
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        n = len(shape) // 2  # representative internal mode
        flops = mttkrp_flops(shape, C, n)
        err = float(
            np.max(
                np.abs(
                    np.asarray(ops.fused_mttkrp(x, factors, n))
                    - np.asarray(mttkrp_einsum(x, factors, n))
                )
            )
        )
        t1 = time_fn(jax.jit(lambda a, f: mttkrp_1step(a, f, n)), x, factors, reps=3)
        t2 = time_fn(jax.jit(lambda a, f: mttkrp_2step(a, f, n)), x, factors, reps=3)
        krp_bytes = flops["krp_bytes"]
        hbm_saved = 2 * krp_bytes  # write+read of the full KRP avoided
        out.append(
            row(
                f"fused_mttkrp_{'x'.join(map(str, shape))}",
                t2["median_s"],
                f"interp_max_err={err:.2e};hbm_bytes_saved={hbm_saved:.3e};"
                f"t_1step_s={t1['median_s']:.4f};t_2step_s={t2['median_s']:.4f};"
                f"gemm_flops={flops['gemm_flops']:.3e}",
            )
        )
    out.extend(matrix_free_rows(matrix_free_section()))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default=None, help="write the matrix-free section here")
    args = ap.parse_args()
    if args.json:
        section = matrix_free_section()
        pathlib.Path(args.json).write_text(json.dumps(section, indent=1) + "\n")
        for line in matrix_free_rows(section):
            print(line)
    else:
        for line in run(args.full):
            print(line)
