"""Paper Sec. 6 follow-through: fused-MTTKRP kernel vs explicit-KRP paths.

No TPU in this container, so the Pallas kernel's *performance* claim is made
with the roofline byte model (what the fusion removes from HBM traffic):

    1-step writes + reads the full KRP:   2 * L*R*C * 4 bytes extra
    2-step materializes the partial GEMM: L*I_n*C (or I_n*R*C) extra
    fused:                                 0 extra (KRP tiles live in VMEM)

We report those analytic deltas per shape alongside interpret-mode
correctness (max |err| vs the einsum oracle) and the XLA wall time of the
1-step/2-step paths for context.
"""

from __future__ import annotations

import jax
import numpy as np

from repro.core import mttkrp_1step, mttkrp_2step, mttkrp_einsum, mttkrp_flops
from repro.core import random_factors, random_tensor
from repro.kernels import ops

from .util import row, time_fn

SHAPES = [(256, 64, 256), (64, 64, 64, 64), (32, 16, 32, 16, 32)]
C = 32


def run(full: bool = False) -> list[str]:
    out = []
    for shape in SHAPES:
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        n = len(shape) // 2  # representative internal mode
        flops = mttkrp_flops(shape, C, n)
        err = float(
            np.max(
                np.abs(
                    np.asarray(ops.fused_mttkrp(x, factors, n))
                    - np.asarray(mttkrp_einsum(x, factors, n))
                )
            )
        )
        t1 = time_fn(jax.jit(lambda a, f: mttkrp_1step(a, f, n)), x, factors, reps=3)
        t2 = time_fn(jax.jit(lambda a, f: mttkrp_2step(a, f, n)), x, factors, reps=3)
        krp_bytes = flops["krp_bytes"]
        hbm_saved = 2 * krp_bytes  # write+read of the full KRP avoided
        out.append(
            row(
                f"fused_mttkrp_{'x'.join(map(str, shape))}",
                t2["median_s"],
                f"interp_max_err={err:.2e};hbm_bytes_saved={hbm_saved:.3e};"
                f"t_1step_s={t1['median_s']:.4f};t_2step_s={t2['median_s']:.4f};"
                f"gemm_flops={flops['gemm_flops']:.3e}",
            )
        )
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
