"""Benchmark timing utilities (single-core XLA-CPU wall clock)."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def time_fn(fn: Callable, *args, reps: int = 5, warmup: int = 2, **kw) -> dict:
    """Median wall time of ``fn(*args)`` with compile excluded.  Returns stats."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times = np.asarray(times)
    return {
        "median_s": float(np.median(times)),
        "min_s": float(times.min()),
        "mean_s": float(times.mean()),
        "reps": reps,
    }


def row(name: str, seconds: float, derived: str = "") -> str:
    """CSV row in the required ``name,us_per_call,derived`` format."""
    return f"{name},{seconds * 1e6:.1f},{derived}"
