"""Aggregate dry-run JSONs into the SRoofline table (markdown + CSV rows)."""

from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import terms_from_record


def load_records(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(out_dir: str = "results/dryrun", mesh: str = "pod") -> list[str]:
    """Markdown roofline table for one mesh (brief: roofline is single-pod)."""
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | bottleneck | "
        "MODEL/HLO | MFU bound | temp GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(out_dir):
        if rec["mesh"] != mesh:
            continue
        if rec.get("skipped"):
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                f"skipped ({rec['skipped'][:40]}...) | - | - | - |"
            )
            continue
        if not rec.get("ok"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | FAILED | | | | | | |")
            continue
        t = terms_from_record(rec)
        temp = rec["full"].get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t.compute_s:.2e} | "
            f"{t.memory_s:.2e} | {t.collective_s:.2e} | {t.bottleneck} | "
            f"{t.useful_flops_ratio:.2f} | {t.mfu_bound:.2%} | {temp:.1f} |"
        )
    return lines


def csv_rows(out_dir: str = "results/dryrun") -> list[str]:
    rows = []
    for rec in load_records(out_dir):
        if rec.get("skipped") or not rec.get("ok"):
            continue
        t = terms_from_record(rec)
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        rows.append(
            f"{name},{t.step_bound_s * 1e6:.1f},"
            f"bottleneck={t.bottleneck};mfu_bound={t.mfu_bound:.3f};"
            f"useful={t.useful_flops_ratio:.2f}"
        )
    return rows


if __name__ == "__main__":
    for line in table():
        print(line)
