"""Aggregate dry-run JSONs into the SRoofline table (markdown + CSV rows),
plus analytic per-dtype MTTKRP rooflines (no artifacts needed)."""

from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import mttkrp_roofline, terms_from_record


def load_records(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(out_dir: str = "results/dryrun", mesh: str = "pod") -> list[str]:
    """Markdown roofline table for one mesh (brief: roofline is single-pod)."""
    lines = [
        "| arch | shape | compute_s | memory_s | coll_s | bottleneck | "
        "MODEL/HLO | MFU bound | temp GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(out_dir):
        if rec["mesh"] != mesh:
            continue
        if rec.get("skipped"):
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                f"skipped ({rec['skipped'][:40]}...) | - | - | - |"
            )
            continue
        if not rec.get("ok"):
            lines.append(f"| {rec['arch']} | {rec['shape']} | FAILED | | | | | | |")
            continue
        t = terms_from_record(rec)
        temp = rec["full"].get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t.compute_s:.2e} | "
            f"{t.memory_s:.2e} | {t.collective_s:.2e} | {t.bottleneck} | "
            f"{t.useful_flops_ratio:.2f} | {t.mfu_bound:.2%} | {temp:.1f} |"
        )
    return lines


def mttkrp_rows(
    rank: int | None = None,
    dtypes=("bf16", "f32", "f64"),
    full: bool = False,
) -> list[str]:
    """Analytic MTTKRP rooflines for the paper's cubic bench shapes per dtype.

    The byte terms come from the dtype-aware ``mttkrp_flops``, so the bf16 /
    f64 rows differ where the old 4-byte hard-coding made them identical.
    ``full`` selects the paper-scale shapes, like ``bench_mttkrp --full``.
    """
    # shapes AND rank come from bench_mttkrp so the predicted rows stay
    # aligned with the measured rows they sit beside in the CSV
    from .bench_mttkrp import C, DEFAULT_TOTAL, FULL_TOTAL, _dims

    rank = C if rank is None else rank
    total = FULL_TOTAL if full else DEFAULT_TOTAL
    rows = []
    for n_modes in (3, 4, 5, 6):
        shape = _dims(n_modes, total)
        mode = n_modes // 2  # an internal mode: the interesting dispatch case
        for dt in dtypes:
            t = mttkrp_roofline(shape, rank, mode, dtype=dt)
            rows.append(
                f"mttkrp_roofline_N{n_modes}_mode{mode}_{dt},"
                f"{t['bound_s'] * 1e6:.2f},"
                f"bound={t['bound']};intensity={t['intensity_flops_per_byte']:.1f};"
                f"itemsize={t['itemsize']:.0f}"
            )
    return rows


def csv_rows(out_dir: str = "results/dryrun", full: bool = False) -> list[str]:
    rows = mttkrp_rows(full=full)
    for rec in load_records(out_dir):
        if rec.get("skipped") or not rec.get("ok"):
            continue
        t = terms_from_record(rec)
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        rows.append(
            f"{name},{t.step_bound_s * 1e6:.1f},"
            f"bottleneck={t.bottleneck};mfu_bound={t.mfu_bound:.3f};"
            f"useful={t.useful_flops_ratio:.2f}"
        )
    return rows


if __name__ == "__main__":
    for line in table():
        print(line)
