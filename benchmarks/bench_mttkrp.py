"""Paper Figs. 5-6: 1-step vs 2-step vs reorder-baseline MTTKRP across modes.

The paper uses cubic tensors of ~750M entries with N in {3,4,5,6} and C=25.
Single-core default here is ~16M entries (--full restores paper scale); the
algorithmic comparisons (2-step beats baseline, 1-step pays the explicit-KRP
tax, baseline pays the reorder copy the paper's methods avoid) are
size-stable.  We additionally time the baseline's reorder (transpose) cost
separately -- the paper's DGEMM baseline *excludes* it, so we report both.

Each shape is also planned through ``repro.plan.plan_sweep``; the measured
rows carry the planner's predicted seconds so perf JSONs record
predicted-vs-measured, and ``--json`` emits the full ``SweepPlan.describe()``
next to the measurements.  ``--smoke`` shrinks to tiny shapes with one rep
(the CI artifact path).

The JSON additionally carries an ``overlap`` section: per-mode
predicted-vs-measured efficiency of the communication-hiding executors on a
small sharded problem (sharded vs overlapping psum pipeline, plus the
planner's executor pick).  Measurements need >1 device -- run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` as CI does;
predicted rows are emitted either way (planning is pure arithmetic).

    PYTHONPATH=src python -m benchmarks.bench_mttkrp --smoke --json out.json
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.core import (
    matricize,
    mttkrp,
    mttkrp_1step,
    mttkrp_2step,
    mttkrp_baseline,
    random_factors,
    random_tensor,
)
from repro.plan import Problem, plan_sweep

from .util import row, time_fn

C = 25
DEFAULT_TOTAL = 16e6  # ~16M entries: single-core scale
FULL_TOTAL = 750e6  # the paper's scale (--full)
SMOKE_TOTAL = 4096  # tiny CI-artifact scale (--smoke)

# sharded problem of the overlap section: mode 0 rides the single mesh axis,
# so every other mode's MTTKRP psums over it (the hidable collective)
OVERLAP_SHAPE = (8, 32, 8)
OVERLAP_RANK = 8


def overlap_section(reps: int) -> dict:
    """Predicted-vs-measured overlap efficiency of the sharded executors.

    Predictions come straight from the bounded-overlap cost model (computed
    even without devices -- capacity-planning style, assuming 8 shards when
    no multi-device runtime is attached).  Measurements time the plain vs
    overlapped dist_mttkrp per mode when the runtime has >1 device.
    """
    from repro.dist.dist_mttkrp import (
        dist_mttkrp,
        dist_mttkrp_overlapped,
        shard_problem,
    )

    n_dev = jax.device_count()
    shards = n_dev if n_dev > 1 and OVERLAP_SHAPE[0] % n_dev == 0 else 8
    mode_axes = {0: "shard"}
    problem = Problem(
        shape=OVERLAP_SHAPE, rank=OVERLAP_RANK,
        mode_axes=mode_axes, axis_sizes={"shard": shards},
    )
    plans = {
        ex: plan_sweep(problem, executor=ex)
        for ex in ("sharded", "overlapping", "compressed")
    }
    rows = []
    for n in range(len(OVERLAP_SHAPE)):
        sh, ov = plans["sharded"].modes[n], plans["overlapping"].modes[n]
        pred_sh, pred_ov = sh.cost.predicted_s, ov.cost.predicted_s
        rows.append({
            "mode": n,
            "algorithm": ov.algorithm,
            # model internals: fraction of the hidable (smaller) term hidden
            "predicted_overlap_efficiency": ov.cost.predicted_overlap_efficiency,
            # the directly measurable quantity the saving rows compare against
            "predicted_saving_vs_sharded": (pred_sh - pred_ov) / pred_sh,
            "predicted_s_sharded": pred_sh,
            "predicted_s_overlapping": pred_ov,
            "predicted_s_compressed": plans["compressed"].modes[n].cost.predicted_s,
            "measured_s_sharded": None,
            "measured_s_overlapping": None,
            "measured_saving_vs_sharded": None,
        })
    measured = n_dev > 1 and OVERLAP_SHAPE[0] % n_dev == 0
    if measured:
        mesh = jax.make_mesh((n_dev,), ("shard",))
        x = random_tensor(jax.random.PRNGKey(2), OVERLAP_SHAPE)
        factors = random_factors(jax.random.PRNGKey(3), OVERLAP_SHAPE, OVERLAP_RANK)
        xs, fs = shard_problem(x, factors, mode_axes, mesh)
        for r in rows:
            n = r["mode"]
            t_sh = time_fn(
                jax.jit(lambda t, fl, m=n: dist_mttkrp(t, fl, m, mode_axes, mesh)),
                xs, fs, reps=reps,
            )["median_s"]
            t_ov = time_fn(
                jax.jit(lambda t, fl, m=n: dist_mttkrp_overlapped(t, fl, m, mode_axes, mesh)),
                xs, fs, reps=reps,
            )["median_s"]
            r["measured_s_sharded"] = t_sh
            r["measured_s_overlapping"] = t_ov
            # realized saving as a fraction of the no-overlap time -- the
            # same quantity predicted_saving_vs_sharded models
            r["measured_saving_vs_sharded"] = (t_sh - t_ov) / t_sh if t_sh > 0 else None
    return {
        "shape": list(OVERLAP_SHAPE),
        "rank": OVERLAP_RANK,
        "shards": shards,
        "measured": measured,
        "selected_executor": plan_sweep(problem).executor,
        "modes": rows,
    }


def _dims(n: int, total: float) -> tuple[int, ...]:
    d = round(total ** (1.0 / n))
    return (d,) * n


def collect(full: bool = False, smoke: bool = False) -> dict:
    """Measure all shapes; returns {"plans": [...], "results": [...]}."""
    if full and smoke:
        raise ValueError("--full and --smoke are mutually exclusive")
    total = FULL_TOTAL if full else (SMOKE_TOTAL if smoke else DEFAULT_TOTAL)
    reps = 1 if smoke else 3
    plans: list[dict] = []
    results: list[dict] = []

    def rec(name: str, seconds: float, derived: str = "") -> None:
        results.append({"name": name, "median_s": seconds, "derived": derived})

    for n_modes in (3, 4, 5, 6):
        shape = _dims(n_modes, total)
        plan = plan_sweep(Problem(shape=shape, rank=C, dtype="float32"))
        plans.append(plan.describe())
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        # reorder cost: what the straightforward approach pays before DGEMM
        for mp in plan.modes:
            mode = mp.mode
            reorder = jax.jit(lambda t, m=mode: matricize(t, m))
            t_reorder = time_fn(reorder, x, reps=reps)["median_s"]
            t_base = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_baseline(t, fs, m)),
                x, factors, reps=reps,
            )["median_s"]
            t_1step = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_1step(t, fs, m)),
                x, factors, reps=reps,
            )["median_s"]
            rec(f"mttkrp_N{n_modes}_mode{mode}_baseline", t_base,
                f"reorder_s={t_reorder:.4f}")
            rec(f"mttkrp_N{n_modes}_mode{mode}_1step", t_1step,
                f"vs_baseline={t_base/t_1step:.2f}x")
            t_2step = None
            if 0 < mode < n_modes - 1:
                t_2step = time_fn(
                    jax.jit(lambda t, fs, m=mode: mttkrp_2step(t, fs, m)),
                    x, factors, reps=reps,
                )["median_s"]
                rec(f"mttkrp_N{n_modes}_mode{mode}_2step", t_2step,
                    f"vs_baseline={t_base/t_2step:.2f}x")
            # the planner's pick, with its prediction alongside the measurement
            # (reuse the timing above when the pick is a variant already timed:
            # auto's 2step order equals mttkrp_2step's own order rule)
            if mp.algorithm == "1step":
                t_plan = t_1step
            elif mp.algorithm.startswith("2step") and t_2step is not None:
                t_plan = t_2step
            else:
                t_plan = time_fn(
                    jax.jit(lambda t, fs, m=mode, a=mp.algorithm: mttkrp(t, fs, m, method=a)),
                    x, factors, reps=reps,
                )["median_s"]
            rec(f"mttkrp_N{n_modes}_mode{mode}_planned", t_plan,
                f"alg={mp.algorithm};predicted_s={mp.cost.predicted_s:.3e}")
    overlap = overlap_section(reps)
    for r in overlap["modes"]:
        if r["measured_saving_vs_sharded"] is not None:
            rec(
                f"dist_mttkrp_overlap_mode{r['mode']}",
                r["measured_s_overlapping"],
                f"measured_saving={r['measured_saving_vs_sharded']:.2f};"
                f"predicted_saving={r['predicted_saving_vs_sharded']:.2f}",
            )
    return {
        "smoke": smoke, "full": full, "rank": C,
        "plans": plans, "results": results, "overlap": overlap,
    }


def run(full: bool = False, smoke: bool = False) -> list[str]:
    data = collect(full, smoke)
    return [row(r["name"], r["median_s"], r["derived"]) for r in data["results"]]


def main() -> None:
    ap = argparse.ArgumentParser()
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--full", action="store_true", help="paper-scale shapes")
    scale.add_argument("--smoke", action="store_true", help="tiny shapes, 1 rep")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write measurements + SweepPlan.describe() as JSON")
    args = ap.parse_args()
    data = collect(full=args.full, smoke=args.smoke)
    for r in data["results"]:
        print(row(r["name"], r["median_s"], r["derived"]))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(data, f, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
