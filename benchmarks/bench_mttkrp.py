"""Paper Figs. 5-6: 1-step vs 2-step vs reorder-baseline MTTKRP across modes.

The paper uses cubic tensors of ~750M entries with N in {3,4,5,6} and C=25.
Single-core default here is ~16M entries (--full restores paper scale); the
algorithmic comparisons (2-step beats baseline, 1-step pays the explicit-KRP
tax, baseline pays the reorder copy the paper's methods avoid) are
size-stable.  We additionally time the baseline's reorder (transpose) cost
separately -- the paper's DGEMM baseline *excludes* it, so we report both.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    matricize,
    mttkrp_1step,
    mttkrp_2step,
    mttkrp_baseline,
    random_factors,
    random_tensor,
)

from .util import row, time_fn

C = 25


def _dims(n: int, total: float) -> tuple[int, ...]:
    d = round(total ** (1.0 / n))
    return (d,) * n


def run(full: bool = False) -> list[str]:
    total = 750e6 if full else 16e6
    out = []
    for n_modes in (3, 4, 5, 6):
        shape = _dims(n_modes, total)
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        # reorder cost: what the straightforward approach pays before DGEMM
        for mode in range(n_modes):
            reorder = jax.jit(lambda t, m=mode: matricize(t, m))
            t_reorder = time_fn(reorder, x, reps=3)["median_s"]
            t_base = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_baseline(t, fs, m)), x, factors, reps=3
            )["median_s"]
            t_1step = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_1step(t, fs, m)), x, factors, reps=3
            )["median_s"]
            names = [
                (f"mttkrp_N{n_modes}_mode{mode}_baseline", t_base, f"reorder_s={t_reorder:.4f}"),
                (f"mttkrp_N{n_modes}_mode{mode}_1step", t_1step,
                 f"vs_baseline={t_base/t_1step:.2f}x"),
            ]
            if 0 < mode < n_modes - 1:
                t_2step = time_fn(
                    jax.jit(lambda t, fs, m=mode: mttkrp_2step(t, fs, m)), x, factors, reps=3
                )["median_s"]
                names.append(
                    (f"mttkrp_N{n_modes}_mode{mode}_2step", t_2step,
                     f"vs_baseline={t_base/t_2step:.2f}x")
                )
            out.extend(row(*t) for t in names)
    return out


if __name__ == "__main__":
    for line in run():
        print(line)
