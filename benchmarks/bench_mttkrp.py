"""Paper Figs. 5-6: 1-step vs 2-step vs reorder-baseline MTTKRP across modes.

The paper uses cubic tensors of ~750M entries with N in {3,4,5,6} and C=25.
Single-core default here is ~16M entries (--full restores paper scale); the
algorithmic comparisons (2-step beats baseline, 1-step pays the explicit-KRP
tax, baseline pays the reorder copy the paper's methods avoid) are
size-stable.  We additionally time the baseline's reorder (transpose) cost
separately -- the paper's DGEMM baseline *excludes* it, so we report both.

Each shape is also planned through ``repro.plan.plan_sweep``; the measured
rows carry the planner's predicted seconds so perf JSONs record
predicted-vs-measured, and ``--json`` emits the full ``SweepPlan.describe()``
next to the measurements.  ``--smoke`` shrinks to tiny shapes with one rep
(the CI artifact path).

The JSON additionally carries an ``overlap`` section (per-mode
predicted-vs-measured efficiency of the communication-hiding executors on a
small sharded problem) and a ``schedule`` section (per-NODE
predicted-vs-measured seconds of the auto-chosen contraction schedule on an
order-4 sharded problem -- the tree the planner argmin'd over flat / binary
/ chain shapes).  Measurements need >1 device -- run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` as CI does;
predicted rows are emitted either way (planning is pure arithmetic).

``--calibrate`` fits per-executor ``serial_fraction`` constants from the
overlap section's measured rows (the unhidable share of the smaller
roofline term implied by each measured sharded/overlapped pair), records
them in the JSON, and re-plans through
``plan_sweep(..., serial_fractions=...)`` so the artifact also carries the
calibrated predictions -- closing the model-calibration loop.

``--autotune`` runs the measured-cost loop of ``repro.plan.autotune`` on
the first benchmark shape: candidate Pallas tilings and every candidate
plan node are timed on the attached device (wall-clock capped by
``--budget-ms``), the winners persist in the tuning cache named by
``--tuning-cache`` (in-memory when omitted; CI uploads the file as an
artifact), and the JSON gains an ``autotune`` section with tuned-vs-default
tile rows plus the measured-vs-predicted node rows of the resulting
``plan_sweep(strategy="autotune")`` plan.  The first CPU-smoke baseline is
committed in-tree as ``benchmarks/BENCH_autotune.json``.

``--pp`` adds a ``pp`` section: a >=20-sweep CP-ALS run on a planted
low-rank tensor, exact vs pairwise perturbation (``Problem.pp_tol``),
reporting end-to-end amortized per-sweep seconds for both, the measured
exact-sweep fraction (``CPState.pp_exact_sweeps / n_iters``) next to the
planner's ``PP_EXACT_FRACTION`` assumption, and the fit gap.  The first
CPU-smoke baseline is committed in-tree as ``benchmarks/BENCH_pp.json``.

``--hierarchical`` adds a ``hierarchical`` section on a 2x4 node mesh:
per mode, the modeled intra/inter bytes and predicted seconds of the flat
ring vs the two-level reduce-scatter/psum/all-gather decomposition, the
Ballard-Knight-Rouse communication lower bound with the planner's
mesh-mapping rows and ``certified`` verdict, and -- when 8 devices are
attached -- measured flat-vs-hierarchical ``dist_mttkrp`` seconds.  The
first CPU-smoke baseline is committed in-tree as
``benchmarks/BENCH_hierarchical.json``.

    PYTHONPATH=src python -m benchmarks.bench_mttkrp --smoke --calibrate \
        --autotune --budget-ms 2000 --json out.json
"""

from __future__ import annotations

import argparse
import json

import jax

from repro.core import (
    matricize,
    mttkrp,
    mttkrp_1step,
    mttkrp_2step,
    mttkrp_baseline,
    random_factors,
    random_tensor,
)
from repro.plan import Problem, enumerate_schedules, make_executor, plan_sweep

from .util import row, time_fn

C = 25
DEFAULT_TOTAL = 16e6  # ~16M entries: single-core scale
FULL_TOTAL = 750e6  # the paper's scale (--full)
SMOKE_TOTAL = 4096  # tiny CI-artifact scale (--smoke)

# sharded problem of the overlap section: mode 0 rides the single mesh axis,
# so every other mode's MTTKRP psums over it (the hidable collective)
OVERLAP_SHAPE = (8, 32, 8)
OVERLAP_RANK = 8

# order-4 problem of the schedule section: big enough for the planner to
# enumerate flat / binary@{1,2,3} / chain and pick a real tree
SCHEDULE_SHAPE = (8, 6, 4, 4)
SCHEDULE_RANK = 8

# per-problem tensor of the batched section: deliberately small -- a fleet
# of these is the regime where batch-parallel beats mode-parallel sharding
BATCHED_SHAPE = (16, 16, 16)
BATCHED_RANK = 8
BATCHED_ITERS = 3

# hierarchical section: the CI node mesh -- 2 nodes x 4 devices, mode 0 on
# the inter-node axis, mode 2 on the fast intra-node axis
HIER_SHAPE = (8, 6, 4, 5)
HIER_RANK = 7
HIER_NODES = 2
HIER_DEVICES_PER_NODE = 4

# pp section: big enough that the correction sweep's O(sum I_n*I_m*C) work
# is clearly cheaper than the exact MTTKRP's O(prod I * C); a planted
# low-rank tensor keeps the drift small so most sweeps ride the cache
PP_SHAPE = (128, 128, 128)
PP_RANK = 32
PP_ITERS = 40
PP_TOL = 0.05
PP_INIT_NOISE = 0.05  # refinement regime: init = planted factors + noise


def overlap_section(reps: int) -> dict:
    """Predicted-vs-measured overlap efficiency of the sharded executors.

    Predictions come straight from the bounded-overlap cost model (computed
    even without devices -- capacity-planning style, assuming 8 shards when
    no multi-device runtime is attached).  Measurements time the plain vs
    overlapped dist_mttkrp per mode when the runtime has >1 device.
    """
    from repro.dist.dist_mttkrp import (
        dist_mttkrp,
        dist_mttkrp_overlapped,
        shard_problem,
    )

    n_dev = jax.device_count()
    shards = n_dev if n_dev > 1 and OVERLAP_SHAPE[0] % n_dev == 0 else 8
    mode_axes = {0: "shard"}
    problem = Problem(
        shape=OVERLAP_SHAPE, rank=OVERLAP_RANK,
        mode_axes=mode_axes, axis_sizes={"shard": shards},
    )
    # flat schedule: these are per-MODE rows (tree shapes get their own
    # per-node section below)
    plans = {
        ex: plan_sweep(problem, schedule="flat", executor=ex)
        for ex in ("sharded", "overlapping", "compressed")
    }
    rows = []
    for n in range(len(OVERLAP_SHAPE)):
        sh, ov = plans["sharded"].modes[n], plans["overlapping"].modes[n]
        pred_sh, pred_ov = sh.cost.predicted_s, ov.cost.predicted_s
        rows.append({
            "mode": n,
            "algorithm": ov.algorithm,
            # model internals: fraction of the hidable (smaller) term hidden
            "predicted_overlap_efficiency": ov.cost.predicted_overlap_efficiency,
            # the directly measurable quantity the saving rows compare against
            "predicted_saving_vs_sharded": (pred_sh - pred_ov) / pred_sh,
            "predicted_s_sharded": pred_sh,
            "predicted_s_overlapping": pred_ov,
            "predicted_s_compressed": plans["compressed"].modes[n].cost.predicted_s,
            "measured_s_sharded": None,
            "measured_s_overlapping": None,
            "measured_saving_vs_sharded": None,
        })
    measured = n_dev > 1 and OVERLAP_SHAPE[0] % n_dev == 0
    if measured:
        mesh = jax.make_mesh((n_dev,), ("shard",))
        x = random_tensor(jax.random.PRNGKey(2), OVERLAP_SHAPE)
        factors = random_factors(jax.random.PRNGKey(3), OVERLAP_SHAPE, OVERLAP_RANK)
        xs, fs = shard_problem(x, factors, mode_axes, mesh)
        for r in rows:
            n = r["mode"]
            t_sh = time_fn(
                jax.jit(lambda t, fl, m=n: dist_mttkrp(t, fl, m, mode_axes, mesh)),
                xs, fs, reps=reps,
            )["median_s"]
            t_ov = time_fn(
                jax.jit(lambda t, fl, m=n: dist_mttkrp_overlapped(t, fl, m, mode_axes, mesh)),
                xs, fs, reps=reps,
            )["median_s"]
            r["measured_s_sharded"] = t_sh
            r["measured_s_overlapping"] = t_ov
            # realized saving as a fraction of the no-overlap time -- the
            # same quantity predicted_saving_vs_sharded models
            r["measured_saving_vs_sharded"] = (t_sh - t_ov) / t_sh if t_sh > 0 else None
    return {
        "shape": list(OVERLAP_SHAPE),
        "rank": OVERLAP_RANK,
        "shards": shards,
        "measured": measured,
        "selected_executor": plan_sweep(problem).executor,
        "modes": rows,
    }


def schedule_section(reps: int) -> dict:
    """Predicted-vs-measured seconds per contraction-schedule NODE.

    Plans the order-4 sharded problem with the full joint argmin (tree
    shape x executor), then -- when the runtime has a matching multi-device
    mesh -- walks the chosen schedule exactly like the sweep engine does,
    timing each node's ``executor.contract`` against its ``NodePlan``
    prediction.  Internal nodes' outputs are cached so children time the
    real reuse path.
    """
    n_dev = jax.device_count()
    shards = n_dev if n_dev > 1 and SCHEDULE_SHAPE[0] % n_dev == 0 else 8
    mode_axes = {0: "shard"}
    problem = Problem(
        shape=SCHEDULE_SHAPE, rank=SCHEDULE_RANK,
        mode_axes=mode_axes, axis_sizes={"shard": shards},
    )
    plan = plan_sweep(problem)
    sched = plan.resolved_schedule
    rows = [
        {
            "node": np_.node.id,
            "parent": np_.node.parent,
            "modes": list(np_.node.modes),
            "contracted": list(np_.node.contracted),
            "reduce_axes": list(np_.node.reduce_axes),
            "algorithm": np_.algorithm,
            "predicted_s": np_.cost.predicted_s,
            "measured_s": None,
        }
        for np_ in plan.nodes
    ]
    measured = n_dev > 1 and SCHEDULE_SHAPE[0] % n_dev == 0
    if measured:
        from repro.dist.dist_mttkrp import shard_problem

        mesh = jax.make_mesh((n_dev,), ("shard",))
        x = random_tensor(jax.random.PRNGKey(4), SCHEDULE_SHAPE)
        factors = random_factors(jax.random.PRNGKey(5), SCHEDULE_SHAPE, SCHEDULE_RANK)
        xs, fs = shard_problem(x, factors, mode_axes, mesh)
        executor = make_executor(plan.executor, mesh, mode_axes)
        # carry-bearing executors (compressed) must be measured through their
        # carry path -- plain contract() would silently time the exact psum
        carry = (
            executor.init_carry(plan, xs, fs)
            if hasattr(executor, "init_carry")
            else None
        )
        cache = {sched.root.id: xs}
        for r, node in zip(rows, sched.walk()):
            src = cache[node.parent]
            alg = r["algorithm"]
            if carry is not None:
                fn = jax.jit(
                    lambda s, f, c, node=node, alg=alg: executor.contract_carry(
                        node, s, f, alg, c
                    )
                )
                r["measured_s"] = time_fn(fn, src, fs, carry, reps=reps)["median_s"]
                out, carry = fn(src, fs, carry)
            else:
                fn = jax.jit(
                    lambda s, f, node=node, alg=alg: executor.contract(node, s, f, alg)
                )
                r["measured_s"] = time_fn(fn, src, fs, reps=reps)["median_s"]
                out = fn(src, fs)
            if not node.is_leaf:
                cache[node.id] = out
    return {
        "shape": list(SCHEDULE_SHAPE),
        "rank": SCHEDULE_RANK,
        "shards": shards,
        "measured": measured,
        "schedule": sched.name,
        "executor": plan.executor,
        "n_candidates": len(enumerate_schedules(problem)),
        "nodes": rows,
    }


def batched_section(batch: int, reps: int) -> dict:
    """Problems/sec of one fused batched ``cp_als`` dispatch over a fleet.

    Plans a fleet of ``batch`` same-shaped small tensors *given* a
    mode-parallel sharding and records the planner's placement argmin (for a
    small-tensor fleet it should re-place batch-parallel: B independent
    problems need zero reduce traffic, vs psum volume x B mode-parallel) --
    the ``placements`` rows carry both candidates' predicted seconds and
    collective bytes straight from ``SweepPlan.describe()``.  Then times the
    batched driver end-to-end (one compiled dispatch per sweep chunk,
    ``sweeps_per_sync`` = all sweeps) and reports amortized per-problem ms
    and problems/sec; when the runtime has a matching device fleet the
    batch-parallel ``shard_map`` run is timed alongside the local one.
    """
    import time as _time

    from repro.core.tensor_ops import random_factors as _rf
    from repro.plan import cp_als

    n_dev = jax.device_count()
    shards = n_dev if n_dev > 1 and batch % n_dev == 0 else 8
    given = Problem(
        shape=BATCHED_SHAPE, rank=BATCHED_RANK, batch=batch,
        mode_axes={0: "shard"}, axis_sizes={"shard": shards},
    )
    plan = plan_sweep(given)
    desc = plan.describe()

    def _time_run(x, run_plan, executor=None):
        init = _rf(jax.random.PRNGKey(9), BATCHED_SHAPE, BATCHED_RANK, batch=batch)
        # warmup compiles; timed runs then measure steady-state dispatches
        cp_als(x, run_plan, executor=executor, n_iters=BATCHED_ITERS, tol=0.0,
               init_factors=init, sweeps_per_sync=BATCHED_ITERS)
        best = None
        for _ in range(max(1, reps)):
            t0 = _time.perf_counter()
            cp_als(x, run_plan, executor=executor, n_iters=BATCHED_ITERS, tol=0.0,
                   init_factors=init, sweeps_per_sync=BATCHED_ITERS)
            dt = _time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best

    x = random_tensor(jax.random.PRNGKey(8), (batch,) + BATCHED_SHAPE)
    local_plan = plan_sweep(Problem(shape=BATCHED_SHAPE, rank=BATCHED_RANK, batch=batch))
    t_local = _time_run(x, local_plan)
    out = {
        "batch": batch,
        "shape": list(BATCHED_SHAPE),
        "rank": BATCHED_RANK,
        "n_iters": BATCHED_ITERS,
        "placement": desc["placement"],
        "placements": desc["placements"],
        "local": {
            "total_s": t_local,
            "problems_per_s": batch / t_local,
            "amortized_ms_per_problem": 1e3 * t_local / batch,
        },
        "batch_parallel": None,
    }
    if n_dev > 1 and batch % n_dev == 0:
        mesh = jax.make_mesh((n_dev,), ("shard",))
        bp = Problem(
            shape=BATCHED_SHAPE, rank=BATCHED_RANK, batch=batch,
            batch_axes=("shard",), axis_sizes={"shard": n_dev},
        )
        bp_plan = plan_sweep(bp)
        executor = make_executor(bp_plan.executor, mesh, {}, batch_axes=("shard",))
        t_bp = _time_run(x, bp_plan, executor=executor)
        out["batch_parallel"] = {
            "devices": n_dev,
            "total_s": t_bp,
            "problems_per_s": batch / t_bp,
            "amortized_ms_per_problem": 1e3 * t_bp / batch,
        }
    return out


def pp_section(reps: int) -> dict:
    """Exact vs pairwise-perturbation CP-ALS on a planted low-rank tensor.

    Times both drivers end-to-end over ``PP_ITERS`` (>= 20) sweeps with the
    hot loop fully sync-free (``sweeps_per_sync`` = all sweeps) and reports
    amortized per-sweep seconds, the *measured* exact-sweep fraction
    (``CPState.pp_exact_sweeps / n_iters``) next to the planner's
    ``PP_EXACT_FRACTION`` planning assumption and its full analytic pricing
    row (``SweepPlan.describe()["pp"]``), and the final-fit gap between the
    two runs -- the accuracy cost of approximating most sweeps.
    """
    import time as _time

    from repro.core import cp_full
    from repro.plan import PP_EXACT_FRACTION, cp_als

    true = random_factors(jax.random.PRNGKey(11), PP_SHAPE, PP_RANK)
    x = cp_full(None, true)
    x = x + 1e-3 * random_tensor(jax.random.PRNGKey(12), PP_SHAPE)
    # start inside the convergence basin (planted factors + small noise):
    # PP targets the refinement phase, where ALS steps settle quickly and
    # nearly every sweep can ride the cached pairwise contractions
    pert = random_factors(jax.random.PRNGKey(13), PP_SHAPE, PP_RANK)
    init = [t + PP_INIT_NOISE * p for t, p in zip(true, pert)]

    exact_plan = plan_sweep(Problem(shape=PP_SHAPE, rank=PP_RANK))
    pp_prob = Problem(shape=PP_SHAPE, rank=PP_RANK, pp_tol=PP_TOL)
    pp_plan = plan_sweep(pp_prob, strategy="pp")

    def _run(run_plan, key):
        state = best = None
        cache: dict = {}
        # first call compiles into the dispatch cache; timed calls reuse
        # the compiled chunk and measure steady-state sweep dispatches
        for i in range(max(1, reps) + 1):
            t0 = _time.perf_counter()
            state = cp_als(
                x, run_plan, n_iters=PP_ITERS, tol=0.0,
                init_factors=list(init), sweeps_per_sync=PP_ITERS,
                dispatch_cache=cache, dispatch_key=key,
            )
            dt = _time.perf_counter() - t0
            if i > 0:
                best = dt if best is None else min(best, dt)
        return state, best

    st_exact, t_exact = _run(exact_plan, "exact")
    st_pp, t_pp = _run(pp_plan, pp_prob.signature())
    exact_fraction = st_pp.pp_exact_sweeps / PP_ITERS
    return {
        "shape": list(PP_SHAPE),
        "rank": PP_RANK,
        "n_iters": PP_ITERS,
        "pp_tol": PP_TOL,
        "exact": {
            "total_s": t_exact,
            "per_sweep_s": t_exact / PP_ITERS,
            "fit": float(st_exact.fit),
        },
        "pp": {
            "total_s": t_pp,
            "per_sweep_s": t_pp / PP_ITERS,
            "fit": float(st_pp.fit),
            "exact_sweeps": int(st_pp.pp_exact_sweeps),
            "exact_fraction_measured": exact_fraction,
            "exact_fraction_assumed": PP_EXACT_FRACTION,
        },
        "speedup": t_exact / t_pp,
        "fit_gap": abs(float(st_exact.fit) - float(st_pp.fit)),
        "plan_pp_info": dict(pp_plan.describe()["pp"]),
    }


def hierarchical_section(reps: int) -> dict:
    """Flat vs hierarchical collectives per mode: measured ms + modeled bytes.

    Plans the order-4 problem on the two-level ``(2 nodes x 4 devices)``
    mesh with ``intra_axes=("device",)`` and records, per mode, the cost
    model's intra/inter byte split under both collectives, the planner's
    per-node pick, the per-mode Ballard-Knight-Rouse lower-bound term, and
    -- when the runtime has the matching 8-device mesh -- the measured
    flat-vs-hierarchical ``dist_mttkrp`` milliseconds head-to-head.  The
    section also carries the plan-level certification verdict and the
    mapping-enumeration rows straight from ``SweepPlan.describe()``.
    """
    from repro.dist.dist_mttkrp import dist_mttkrp, shard_problem
    from repro.plan import mode_cost

    n_dev = jax.device_count()
    mode_axes = {0: "node", 2: "device"}
    problem = Problem(
        shape=HIER_SHAPE, rank=HIER_RANK, mode_axes=mode_axes,
        axis_sizes={"node": HIER_NODES, "device": HIER_DEVICES_PER_NODE},
        intra_axes=("device",),
    )
    # flat schedule: per-MODE rows, one leaf per mode (tree shapes would
    # interleave partial contractions into the comparison)
    plan = plan_sweep(problem, executor="auto", schedule="flat")
    desc = plan.describe()
    rows = []
    for np_ in plan.nodes:
        n = np_.node.mode
        flat_c = mode_cost(problem, n, np_.algorithm)
        hier_c = mode_cost(problem, n, np_.algorithm, collective="hierarchical")
        rows.append({
            "mode": n,
            "algorithm": np_.algorithm,
            "collective": np_.collective,  # the planner's per-node pick
            "lower_bound_bytes": np_.lower_bound_bytes,
            "flat": {
                "intra_bytes": flat_c.intra_bytes,
                "inter_bytes": flat_c.inter_bytes,
                "predicted_s": flat_c.predicted_s,
                "measured_s": None,
            },
            "hierarchical": {
                "intra_bytes": hier_c.intra_bytes,
                "inter_bytes": hier_c.inter_bytes,
                "predicted_s": hier_c.predicted_s,
                "measured_s": None,
            },
        })
    measured = n_dev == HIER_NODES * HIER_DEVICES_PER_NODE
    if measured:
        from repro.launch.mesh import make_node_mesh

        mesh = make_node_mesh(HIER_NODES, HIER_DEVICES_PER_NODE)
        x = random_tensor(jax.random.PRNGKey(14), HIER_SHAPE)
        factors = random_factors(jax.random.PRNGKey(15), HIER_SHAPE, HIER_RANK)
        xs, fs = shard_problem(x, factors, mode_axes, mesh)
        for r in rows:
            n = r["mode"]
            r["flat"]["measured_s"] = time_fn(
                jax.jit(lambda t, fl, m=n: dist_mttkrp(t, fl, m, mode_axes, mesh)),
                xs, fs, reps=reps,
            )["median_s"]
            r["hierarchical"]["measured_s"] = time_fn(
                jax.jit(
                    lambda t, fl, m=n: dist_mttkrp(
                        t, fl, m, mode_axes, mesh,
                        collective="hierarchical", node_axis="device",
                    )
                ),
                xs, fs, reps=reps,
            )["median_s"]
    return {
        "shape": list(HIER_SHAPE),
        "rank": HIER_RANK,
        "mesh": {"nodes": HIER_NODES, "devices_per_node": HIER_DEVICES_PER_NODE},
        "mode_axes": {str(k): v for k, v in mode_axes.items()},
        "measured": measured,
        "executor": plan.executor,
        "lower_bound_bytes": desc["lower_bound_bytes"],
        "certified": desc["certified"],
        "mappings": desc["mappings"],
        "modes": rows,
    }


def calibrate_serial_fractions(overlap: dict) -> dict:
    """Fit per-executor ``serial_fraction`` from measured overlap rows.

    The bounded-overlap model says ``t_sharded - t_overlapped =
    (1 - f) * min(compute_s, collective_s)``: each measured mode pair gives
    one estimate of the overlapping executor's unhidable fraction ``f``
    (clamped to [0, 1]; on CPU test fleets the collective is a memcpy and
    the fit mostly documents noise -- on real ICI it is the constant the
    model needs).  Returns ``{executor: fitted}`` with the plain sharded
    executor pinned at its defining 1.0; empty when nothing was measured.
    """
    fits = []
    for r in overlap["modes"]:
        t_sh, t_ov = r.get("measured_s_sharded"), r.get("measured_s_overlapping")
        if t_sh is None or t_ov is None:
            continue
        # recover the model's hidable term min(compute, collective) from the
        # two predictions: pred_sh = max + min and pred_ov = max + f*min, so
        # pred_sh - pred_ov = (1 - f) * min -- and (1 - f) is exactly the
        # row's predicted_overlap_efficiency
        efficiency = r["predicted_overlap_efficiency"]
        if efficiency <= 0.0:
            continue
        min_term = (r["predicted_s_sharded"] - r["predicted_s_overlapping"]) / efficiency
        if min_term <= 0.0:
            continue
        f = 1.0 - (t_sh - t_ov) / min_term
        fits.append(min(1.0, max(0.0, f)))
    if not fits:
        return {}
    fits.sort()
    fitted = fits[len(fits) // 2]  # median: robust to one noisy mode
    return {"sharded": 1.0, "overlapping": fitted}


def _dims(n: int, total: float) -> tuple[int, ...]:
    d = round(total ** (1.0 / n))
    return (d,) * n


def autotune_section(
    total: float, reps: int, budget_ms: float, cache_path: str | None
) -> dict:
    """Tuned-vs-default tile rows + measured-vs-predicted autotune-plan rows.

    Runs :func:`repro.plan.autotune.tune` on the order-3 benchmark shape
    (tile candidates for both Pallas kernels, then every node of every
    candidate (schedule x executor) plan, budget-capped), persists the
    winners in ``cache_path`` when given, and re-plans through
    ``plan_sweep(strategy="autotune")`` so the section records exactly what
    the measured argmin chose -- per node, with the analytic prediction
    kept alongside the measurement.
    """
    from repro.plan.autotune import TuningCache, problem_key, tune

    shape = _dims(3, total)
    cache = TuningCache(cache_path)
    x = random_tensor(jax.random.PRNGKey(6), shape)
    factors = random_factors(jax.random.PRNGKey(7), shape, C)
    entry = tune(x, C, factors=factors, cache=cache, budget_ms=budget_ms, reps=reps)
    problem = Problem(shape=shape, rank=C, dtype="float32")
    plan = plan_sweep(problem, strategy="autotune", tuning_cache=cache)
    tile_rows = {
        kernel: {
            "tuned": {
                k: v for k, v in info.items() if k in ("block_i", "block_b")
            },
            "default_s": info["default_s"],
            "tuned_s": info["tuned_s"],
            "speedup_vs_default": info["speedup_vs_default"],
            "rows": info["rows"],
        }
        for kernel, info in entry["tiles"].items()
    }
    node_rows = [
        {
            "node": np_.node.id,
            "modes": list(np_.node.modes),
            "algorithm": np_.algorithm,
            "tiles": dict(np_.tiles) if np_.tiles else None,
            "predicted_s": np_.cost.predicted_s,
            "measured_s": np_.cost.measured_s,
        }
        for np_ in plan.nodes
    ]
    return {
        "shape": list(shape),
        "rank": C,
        "budget_ms": budget_ms,
        "elapsed_ms": entry["elapsed_ms"],
        "cache_key": problem_key(problem),
        "cache_path": cache_path,
        "n_measured_nodes": len(entry["nodes"]),
        "serial_fractions": entry["serial_fractions"],
        "tiles": tile_rows,
        "plan": {
            "strategy": "autotune",
            "schedule": plan.resolved_schedule.name,
            "executor": plan.executor,
            "nodes": node_rows,
        },
    }


def collect(
    full: bool = False,
    smoke: bool = False,
    calibrate: bool = False,
    autotune: bool = False,
    budget_ms: float = 2000.0,
    tuning_cache: str | None = None,
    batch: int = 0,
    pp: bool = False,
    hierarchical: bool = False,
) -> dict:
    """Measure all shapes; returns {"plans": [...], "results": [...]}."""
    if full and smoke:
        raise ValueError("--full and --smoke are mutually exclusive")
    total = FULL_TOTAL if full else (SMOKE_TOTAL if smoke else DEFAULT_TOTAL)
    reps = 1 if smoke else 3
    plans: list[dict] = []
    results: list[dict] = []

    def rec(name: str, seconds: float, derived: str = "") -> None:
        results.append({"name": name, "median_s": seconds, "derived": derived})

    for n_modes in (3, 4, 5, 6):
        shape = _dims(n_modes, total)
        # flat schedule: the rows below time per-mode MTTKRP algorithms
        # head-to-head (tree schedules get the dedicated section)
        plan = plan_sweep(Problem(shape=shape, rank=C, dtype="float32"), schedule="flat")
        plans.append(plan.describe())
        x = random_tensor(jax.random.PRNGKey(0), shape)
        factors = random_factors(jax.random.PRNGKey(1), shape, C)
        # reorder cost: what the straightforward approach pays before DGEMM
        for mp in plan.modes:
            mode = mp.mode
            reorder = jax.jit(lambda t, m=mode: matricize(t, m))
            t_reorder = time_fn(reorder, x, reps=reps)["median_s"]
            t_base = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_baseline(t, fs, m)),
                x, factors, reps=reps,
            )["median_s"]
            t_1step = time_fn(
                jax.jit(lambda t, fs, m=mode: mttkrp_1step(t, fs, m)),
                x, factors, reps=reps,
            )["median_s"]
            rec(f"mttkrp_N{n_modes}_mode{mode}_baseline", t_base,
                f"reorder_s={t_reorder:.4f}")
            rec(f"mttkrp_N{n_modes}_mode{mode}_1step", t_1step,
                f"vs_baseline={t_base/t_1step:.2f}x")
            t_2step = None
            if 0 < mode < n_modes - 1:
                t_2step = time_fn(
                    jax.jit(lambda t, fs, m=mode: mttkrp_2step(t, fs, m)),
                    x, factors, reps=reps,
                )["median_s"]
                rec(f"mttkrp_N{n_modes}_mode{mode}_2step", t_2step,
                    f"vs_baseline={t_base/t_2step:.2f}x")
            # the planner's pick, with its prediction alongside the measurement
            # (reuse the timing above when the pick is a variant already timed:
            # auto's 2step order equals mttkrp_2step's own order rule)
            if mp.algorithm == "1step":
                t_plan = t_1step
            elif mp.algorithm.startswith("2step") and t_2step is not None:
                t_plan = t_2step
            else:
                t_plan = time_fn(
                    jax.jit(lambda t, fs, m=mode, a=mp.algorithm: mttkrp(t, fs, m, method=a)),
                    x, factors, reps=reps,
                )["median_s"]
            rec(f"mttkrp_N{n_modes}_mode{mode}_planned", t_plan,
                f"alg={mp.algorithm};predicted_s={mp.cost.predicted_s:.3e}")
    overlap = overlap_section(reps)
    for r in overlap["modes"]:
        if r["measured_saving_vs_sharded"] is not None:
            rec(
                f"dist_mttkrp_overlap_mode{r['mode']}",
                r["measured_s_overlapping"],
                f"measured_saving={r['measured_saving_vs_sharded']:.2f};"
                f"predicted_saving={r['predicted_saving_vs_sharded']:.2f}",
            )
    schedule = schedule_section(reps)
    for r in schedule["nodes"]:
        if r["measured_s"] is not None:
            rec(
                f"schedule_{schedule['schedule']}_node{r['node']}",
                r["measured_s"],
                f"alg={r['algorithm']};predicted_s={r['predicted_s']:.3e}",
            )
    data = {
        "smoke": smoke, "full": full, "rank": C,
        "plans": plans, "results": results, "overlap": overlap,
        "schedule": schedule,
    }
    if batch > 1:
        bt = batched_section(batch, reps)
        rec(
            f"batched_cp_als_B{batch}_local",
            bt["local"]["total_s"],
            f"problems_per_s={bt['local']['problems_per_s']:.1f};"
            f"amortized_ms={bt['local']['amortized_ms_per_problem']:.3f};"
            f"placement={bt['placement']}",
        )
        if bt["batch_parallel"] is not None:
            rec(
                f"batched_cp_als_B{batch}_batch_parallel",
                bt["batch_parallel"]["total_s"],
                f"problems_per_s={bt['batch_parallel']['problems_per_s']:.1f};"
                f"amortized_ms={bt['batch_parallel']['amortized_ms_per_problem']:.3f}",
            )
        data["batched"] = bt
    if hierarchical:
        hs = hierarchical_section(reps)
        for r in hs["modes"]:
            if r["hierarchical"]["measured_s"] is not None:
                rec(
                    f"dist_mttkrp_hier_mode{r['mode']}",
                    r["hierarchical"]["measured_s"],
                    f"flat_s={r['flat']['measured_s']:.3e};"
                    f"picked={r['collective']};"
                    f"inter_bytes={r['hierarchical']['inter_bytes']:.0f}"
                    f"_vs_{r['flat']['inter_bytes']:.0f}",
                )
        data["hierarchical"] = hs
    if pp:
        ps = pp_section(reps)
        rec(
            "cp_als_exact_sweep", ps["exact"]["per_sweep_s"],
            f"fit={ps['exact']['fit']:.5f}",
        )
        rec(
            "cp_als_pp_sweep_amortized", ps["pp"]["per_sweep_s"],
            f"vs_exact={ps['speedup']:.2f}x;"
            f"exact_fraction={ps['pp']['exact_fraction_measured']:.3f};"
            f"fit_gap={ps['fit_gap']:.2e}",
        )
        data["pp"] = ps
    if autotune:
        at = autotune_section(total, reps, budget_ms, tuning_cache)
        for kernel, info in at["tiles"].items():
            rec(
                f"autotune_{kernel}_tuned",
                info["tuned_s"],
                f"tiles={info['tuned']};default_s={info['default_s']:.3e};"
                f"speedup={info['speedup_vs_default']:.2f}x",
            )
        for r in at["plan"]["nodes"]:
            if r["measured_s"] is not None:
                rec(
                    f"autotune_plan_node{r['node']}",
                    r["measured_s"],
                    f"alg={r['algorithm']};predicted_s={r['predicted_s']:.3e}",
                )
        data["autotune"] = at
    if calibrate:
        fitted = calibrate_serial_fractions(overlap)
        calibration = {"serial_fractions": fitted, "source": "overlap.modes measured rows"}
        if fitted:
            # the acceptance loop: fitted constants feed straight back into
            # the planner and the calibrated predictions land in the artifact
            problem = Problem(
                shape=tuple(overlap["shape"]), rank=overlap["rank"],
                mode_axes={0: "shard"}, axis_sizes={"shard": overlap["shards"]},
            )
            replanned = plan_sweep(
                problem, schedule="flat", executor="overlapping",
                serial_fractions=fitted,
            )
            calibration["replanned"] = {
                "executor": replanned.executor,
                "serial_fractions": dict(replanned.serial_fractions),
                "predicted_s_overlapping_fitted": [
                    m.cost.predicted_s for m in replanned.modes
                ],
            }
        data["calibration"] = calibration
    return data


def run(full: bool = False, smoke: bool = False) -> list[str]:
    data = collect(full, smoke)
    return [row(r["name"], r["median_s"], r["derived"]) for r in data["results"]]


def main() -> None:
    ap = argparse.ArgumentParser()
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--full", action="store_true", help="paper-scale shapes")
    scale.add_argument("--smoke", action="store_true", help="tiny shapes, 1 rep")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit per-executor serial_fraction from the measured "
                         "overlap rows and record it (with calibrated "
                         "re-predictions) in the JSON")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured-cost loop (tile + plan-node "
                         "timings via repro.plan.autotune.tune) and record "
                         "tuned-vs-default / measured-vs-predicted rows")
    ap.add_argument("--budget-ms", type=float, default=2000.0, metavar="MS",
                    help="wall-clock cap for --autotune measurements "
                         "(compile time included; default 2000)")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="persist --autotune winners in this TuningCache "
                         "file (in-memory when omitted)")
    ap.add_argument("--batch", type=int, default=0, metavar="B",
                    help="time one fused batched cp_als dispatch over a fleet "
                         "of B small tensors (problems/sec + amortized "
                         "per-problem ms; records the planner's "
                         "batch-vs-mode placement argmin in the JSON)")
    ap.add_argument("--pp", action="store_true",
                    help="time a >=20-sweep exact-vs-pairwise-perturbation "
                         "cp_als run (amortized per-sweep seconds, measured "
                         "exact-sweep fraction, fit gap)")
    ap.add_argument("--hierarchical", action="store_true",
                    help="price + (on an 8-device mesh) time flat vs "
                         "hierarchical two-level collectives per mode, with "
                         "the BKR lower bound and mapping certification")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write measurements + SweepPlan.describe() as JSON")
    args = ap.parse_args()
    data = collect(
        full=args.full, smoke=args.smoke, calibrate=args.calibrate,
        autotune=args.autotune, budget_ms=args.budget_ms,
        tuning_cache=args.tuning_cache, batch=args.batch, pp=args.pp,
        hierarchical=args.hierarchical,
    )
    for r in data["results"]:
        print(row(r["name"], r["median_s"], r["derived"]))
    if args.calibrate:
        fitted = data["calibration"]["serial_fractions"]
        print(f"# calibrated serial_fractions: {fitted or 'n/a (no measurements)'}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(data, f, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
