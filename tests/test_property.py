"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.configs import get_config
from repro.launch import mesh as meshlib
from repro.models import build_model, cross_entropy
from repro.models.common import mask_vocab_pad, rms_norm, vocab_padded


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


def test_causality_future_tokens_do_not_affect_past(host_mesh):
    """Perturbing token j must leave logits at positions < j unchanged."""
    cfg = get_config("qwen3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer

    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab, jnp.int32)
    h1, _, _ = transformer.forward(params, cfg, tokens)
    l1 = np.asarray(transformer.lm_logits(params, cfg, h1), np.float32)
    j = 7
    tokens2 = tokens.at[0, j].set((tokens[0, j] + 1) % cfg.vocab)
    h2, _, _ = transformer.forward(params, cfg, tokens2)
    l2 = np.asarray(transformer.lm_logits(params, cfg, h2), np.float32)
    np.testing.assert_allclose(l1[:, :j], l2[:, :j], rtol=1e-5, atol=1e-5)
    assert np.abs(l1[:, j:] - l2[:, j:]).max() > 0  # and the future DID change


def test_causality_ssm(host_mesh):
    cfg = get_config("falcon-mamba-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer

    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, cfg.vocab, jnp.int32)
    h1, _, _ = transformer.forward(params, cfg, tokens)
    tokens2 = tokens.at[0, 6].set((tokens[0, 6] + 3) % cfg.vocab)
    h2, _, _ = transformer.forward(params, cfg, tokens2)
    np.testing.assert_allclose(
        np.asarray(h1[:, :6], np.float32), np.asarray(h2[:, :6], np.float32),
        rtol=1e-5, atol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 100.0))
def test_rmsnorm_scale_invariance(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16)) + 0.1
    w = jnp.ones((16,))
    a = np.asarray(rms_norm(x, w))
    b = np.asarray(rms_norm(x * scale, w))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_cross_entropy_uniform_and_onehot():
    v = 64
    logits = jnp.zeros((2, 3, v))
    labels = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    loss, _ = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(v), rtol=1e-5)
    strong = jax.nn.one_hot(labels, v) * 100.0
    loss2, acc2 = cross_entropy(strong, labels)
    assert float(loss2) < 1e-3 and float(acc2) == 1.0


@settings(max_examples=30, deadline=None)
@given(v=st.integers(1, 200_000))
def test_vocab_padded_properties(v):
    p = vocab_padded(v)
    assert p >= v and p % 128 == 0 and p - v < 128


def test_mask_vocab_pad_blocks_pads():
    logits = jnp.ones((2, 2, 256))
    masked = mask_vocab_pad(logits, 200)
    assert float(masked[..., 199].min()) == 1.0
    assert float(masked[..., 200].max()) <= -1e8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mttkrp_scaling_in_factor(seed):
    """MTTKRP is linear in each non-target factor column-wise scale."""
    from repro.core import mttkrp, random_factors, random_tensor

    x = random_tensor(jax.random.PRNGKey(seed), (4, 5, 3))
    factors = random_factors(jax.random.PRNGKey(seed + 1), (4, 5, 3), 4)
    base = np.asarray(mttkrp(x, factors, 1))
    scaled = list(factors)
    scaled[0] = scaled[0] * 2.0
    out = np.asarray(mttkrp(x, scaled, 1))
    np.testing.assert_allclose(out, 2.0 * base, rtol=1e-4, atol=1e-5)


def test_moe_combine_weights_are_convex(host_mesh):
    """Per-token routing weights are a softmax over the top-k: sum <= 1."""
    import jax.numpy as jnp

    from repro.models.moe import moe_apply

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # extract one layer's moe params
    moe_p = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_apply(moe_p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 0.0
