"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, settings, st  # shared optional-dep shim

from repro.configs import get_config
from repro.launch import mesh as meshlib
from repro.models import build_model, cross_entropy
from repro.models.common import mask_vocab_pad, rms_norm, vocab_padded


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


def test_causality_future_tokens_do_not_affect_past(host_mesh):
    """Perturbing token j must leave logits at positions < j unchanged."""
    cfg = get_config("qwen3-8b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer

    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab, jnp.int32)
    h1, _, _ = transformer.forward(params, cfg, tokens)
    l1 = np.asarray(transformer.lm_logits(params, cfg, h1), np.float32)
    j = 7
    tokens2 = tokens.at[0, j].set((tokens[0, j] + 1) % cfg.vocab)
    h2, _, _ = transformer.forward(params, cfg, tokens2)
    l2 = np.asarray(transformer.lm_logits(params, cfg, h2), np.float32)
    np.testing.assert_allclose(l1[:, :j], l2[:, :j], rtol=1e-5, atol=1e-5)
    assert np.abs(l1[:, j:] - l2[:, j:]).max() > 0  # and the future DID change


def test_causality_ssm(host_mesh):
    cfg = get_config("falcon-mamba-7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer

    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, cfg.vocab, jnp.int32)
    h1, _, _ = transformer.forward(params, cfg, tokens)
    tokens2 = tokens.at[0, 6].set((tokens[0, 6] + 3) % cfg.vocab)
    h2, _, _ = transformer.forward(params, cfg, tokens2)
    np.testing.assert_allclose(
        np.asarray(h1[:, :6], np.float32), np.asarray(h2[:, :6], np.float32),
        rtol=1e-5, atol=1e-5,
    )


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 100.0))
def test_rmsnorm_scale_invariance(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 16)) + 0.1
    w = jnp.ones((16,))
    a = np.asarray(rms_norm(x, w))
    b = np.asarray(rms_norm(x * scale, w))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


def test_cross_entropy_uniform_and_onehot():
    v = 64
    logits = jnp.zeros((2, 3, v))
    labels = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    loss, _ = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(v), rtol=1e-5)
    strong = jax.nn.one_hot(labels, v) * 100.0
    loss2, acc2 = cross_entropy(strong, labels)
    assert float(loss2) < 1e-3 and float(acc2) == 1.0


@settings(max_examples=30, deadline=None)
@given(v=st.integers(1, 200_000))
def test_vocab_padded_properties(v):
    p = vocab_padded(v)
    assert p >= v and p % 128 == 0 and p - v < 128


def test_mask_vocab_pad_blocks_pads():
    logits = jnp.ones((2, 2, 256))
    masked = mask_vocab_pad(logits, 200)
    assert float(masked[..., 199].min()) == 1.0
    assert float(masked[..., 200].max()) <= -1e8


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mttkrp_scaling_in_factor(seed):
    """MTTKRP is linear in each non-target factor column-wise scale."""
    from repro.core import mttkrp, random_factors, random_tensor

    x = random_tensor(jax.random.PRNGKey(seed), (4, 5, 3))
    factors = random_factors(jax.random.PRNGKey(seed + 1), (4, 5, 3), 4)
    base = np.asarray(mttkrp(x, factors, 1))
    scaled = list(factors)
    scaled[0] = scaled[0] * 2.0
    out = np.asarray(mttkrp(x, scaled, 1))
    np.testing.assert_allclose(out, 2.0 * base, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------- pairwise perturbation
@settings(max_examples=5, deadline=None)
@given(
    shape=st.lists(st.integers(3, 8), min_size=3, max_size=4).map(tuple),
    rank=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_pp_tol_zero_bitwise_on_generated_problems(shape, rank, seed):
    """Hypothesis sweep of the exact-fallback invariant: for arbitrary
    shapes/ranks, ``pp_tol=0`` iterates are bitwise equal to exact ALS."""
    from repro.core import random_factors, random_tensor
    from repro.plan import Problem, cp_als, plan_sweep

    x = random_tensor(jax.random.PRNGKey(seed), shape)
    init = random_factors(jax.random.PRNGKey(seed + 1), shape, rank)
    a = cp_als(x, plan_sweep(Problem(shape=shape, rank=rank)),
               n_iters=4, tol=0.0, init_factors=list(init))
    b = cp_als(x, plan_sweep(Problem(shape=shape, rank=rank, pp_tol=0.0)),
               n_iters=4, tol=0.0, init_factors=list(init))
    assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))
    for fa, fb in zip(a.factors, b.factors):
        assert np.array_equal(np.asarray(fa), np.asarray(fb))


def test_pp_tol_zero_is_bitwise_exact():
    """``pp_tol=0`` is classic exact ALS, bit for bit: the PP state is never
    built, the sweep graph is untouched, and the signature does not change."""
    from repro.core import random_factors, random_tensor
    from repro.plan import Problem, cp_als, plan_sweep

    shape, rank = (8, 7, 6), 4
    x = random_tensor(jax.random.PRNGKey(10), shape)
    init = random_factors(jax.random.PRNGKey(11), shape, rank)

    p_exact = Problem(shape=shape, rank=rank)
    p_zero = Problem(shape=shape, rank=rank, pp_tol=0.0)
    assert p_zero.signature() == p_exact.signature()  # backward-compatible key

    a = cp_als(x, plan_sweep(p_exact), n_iters=8, tol=0.0, init_factors=list(init))
    b = cp_als(x, plan_sweep(p_zero), n_iters=8, tol=0.0, init_factors=list(init))
    assert a.pp_exact_sweeps is None and b.pp_exact_sweeps is None
    assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))
    for fa, fb in zip(a.factors, b.factors):
        assert np.array_equal(np.asarray(fa), np.asarray(fb))
    assert np.array_equal(np.asarray(a.fit), np.asarray(b.fit))


def test_pp_exact_sweep_cadence():
    """The drift gate controls the exact/approximate cadence at both extremes.
    The pair cache is only rebuilt once an exact sweep's own step settles
    under ``pp_tol``, so a vanishing tolerance never leaves the exact regime
    (every sweep is exact ALS); a huge tolerance materializes after the very
    first sweep and approximates everything after."""
    from repro.core import random_factors, random_tensor
    from repro.plan import Problem, cp_als, plan_sweep

    shape, rank, n_iters = (8, 7, 6), 4, 6
    x = random_tensor(jax.random.PRNGKey(10), shape)
    init = random_factors(jax.random.PRNGKey(11), shape, rank)

    tiny = cp_als(
        x, plan_sweep(Problem(shape=shape, rank=rank, pp_tol=1e-12), strategy="pp"),
        n_iters=n_iters, tol=0.0, init_factors=list(init),
    )
    assert tiny.pp_exact_sweeps == n_iters  # the cache is never rebuilt

    huge = cp_als(
        x, plan_sweep(Problem(shape=shape, rank=rank, pp_tol=1e9), strategy="pp"),
        n_iters=n_iters, tol=0.0, init_factors=list(init),
    )
    assert huge.pp_exact_sweeps == 1


def test_pp_correction_error_is_second_order():
    """The first-order PP approximation of MTTKRP has O(drift^2) error:
    halving the factor perturbation quarters the approximation error."""
    from repro.core import mttkrp, random_factors, random_tensor
    from repro.plan import LocalExecutor, Problem

    shape, rank = (6, 5, 4, 3), 3
    x = random_tensor(jax.random.PRNGKey(20), shape)
    ref = random_factors(jax.random.PRNGKey(21), shape, rank)
    direction = random_factors(jax.random.PRNGKey(22), shape, rank)
    problem = Problem(shape=shape, rank=rank, pp_tol=0.5)
    pairs = {
        k: np.asarray(v, np.float64)
        for k, v in LocalExecutor().pp_pairs(problem, x, ref).items()
    }

    # pairs are stored rank-major: M_{n,m}[c, i_n, i_m]
    def mean_rel_err(eps):
        cur = [r + eps * d for r, d in zip(ref, direction)]
        errs = []
        for n in range(len(shape)):
            m0 = 1 if n == 0 else 0
            if n < m0:
                approx = np.einsum("cab,bc->ac", pairs[(n, m0)], np.asarray(ref[m0]))
            else:
                approx = np.einsum("cab,ac->bc", pairs[(m0, n)], np.asarray(ref[m0]))
            for m in range(len(shape)):
                if m == n:
                    continue
                du = np.asarray(cur[m] - ref[m], np.float64)
                if n < m:
                    approx = approx + np.einsum("cab,bc->ac", pairs[(n, m)], du)
                else:
                    approx = approx + np.einsum("cab,ac->bc", pairs[(m, n)], du)
            exact = np.asarray(mttkrp(x, cur, n), np.float64)
            errs.append(np.linalg.norm(approx - exact) / np.linalg.norm(exact))
        return float(np.mean(errs))

    e1, e2 = mean_rel_err(0.1), mean_rel_err(0.05)
    assert e1 > 1e-6  # the approximation is genuinely approximate at eps=0.1
    assert 3.0 < e1 / e2 < 5.0  # quadratic: halving eps quarters the error


def test_pp_gate_restricted_carry_bitwise_iterates():
    """The PP gate carries only the sweep-mutable payload (the pair cache
    crosses a single rebuild cond instead of riding the per-sweep gate);
    this must not change a single bit: drive the same problem with the
    cond-gated ``als_sweep`` and with a reference loop that picks the
    exact/approximate/rebuild phases in host Python from the same drift
    quantities, and compare every sweep's iterates bitwise."""
    from dataclasses import replace as dc_replace

    from repro.core import cp_full, random_factors, random_tensor, tensor_norm
    from repro.plan import LocalExecutor, Problem, SweepState, als_sweep, plan_sweep
    from repro.plan import sweep as sweeplib

    shape, rank, n_sweeps = (10, 8, 6), 3, 16
    true = random_factors(jax.random.PRNGKey(40), shape, rank)
    x = cp_full(None, true) + 1e-3 * random_tensor(jax.random.PRNGKey(41), shape)
    init = random_factors(jax.random.PRNGKey(42), shape, rank)
    problem = Problem(shape=shape, rank=rank, pp_tol=0.08)
    plan = plan_sweep(problem, strategy="pp")
    ex = LocalExecutor()

    def initial_state():
        return SweepState(
            x=x, factors=list(init), weights=jnp.ones((rank,), x.dtype),
            norm_x=tensor_norm(x).astype(x.dtype), it=jnp.asarray(0),
            grams=sweeplib.grams(init), pp=sweeplib._pp_init(problem, x, init),
        )

    gated = initial_state()
    ref = initial_state()
    saw_pp = saw_exact = False
    for _ in range(n_sweeps):
        gated = als_sweep(problem, plan, ex, gated)

        # reference: the same phases, chosen by host control flow
        use_pp = bool(np.max(np.asarray(ref.pp.drift)) < problem.pp_tol)
        saw_pp |= use_pp
        saw_exact |= not use_pp
        if use_pp:
            ref = sweeplib._pp_sweep(problem, plan, ref)
        else:
            out = sweeplib._exact_sweep(problem, plan, ex, ref)
            step = sweeplib._pp_drift(out.factors, ref.factors)
            if float(jnp.max(step)) < problem.pp_tol:
                pp = sweeplib._pp_materialize(
                    problem, ex, out.x, out.factors, ref.pp.n_exact + 1
                )
            else:
                pp = sweeplib.PPState(
                    ref=ref.pp.ref, pairs=ref.pp.pairs, base=ref.pp.base,
                    drift=jnp.full_like(ref.pp.drift, jnp.inf),
                    n_exact=ref.pp.n_exact + 1,
                )
            ref = dc_replace(out, pp=pp)

        assert int(gated.pp.n_exact) == int(ref.pp.n_exact)
        assert np.array_equal(np.asarray(gated.weights), np.asarray(ref.weights))
        for fa, fb in zip(gated.factors, ref.factors):
            assert np.array_equal(np.asarray(fa), np.asarray(fb))
        assert np.array_equal(
            np.asarray(gated.pp.drift), np.asarray(ref.pp.drift)
        )
        gated = dc_replace(gated, it=gated.it + 1)
        ref = dc_replace(ref, it=ref.it + 1)
    # the run actually exercised both regimes, or the comparison is vacuous
    assert saw_pp and saw_exact


def test_pp_final_fit_matches_exact():
    """On a planted low-rank tensor a PP run (mostly approximated sweeps)
    converges to the same fit as exact ALS, while actually skipping exact
    re-materializations."""
    from repro.core import cp_full, random_factors, random_tensor
    from repro.plan import Problem, cp_als, plan_sweep

    shape, rank, n_iters = (12, 10, 8), 4, 40
    true = random_factors(jax.random.PRNGKey(30), shape, rank)
    x = cp_full(None, true)
    x = x + 1e-3 * random_tensor(jax.random.PRNGKey(31), shape)
    init = random_factors(jax.random.PRNGKey(32), shape, rank)

    exact = cp_als(
        x, plan_sweep(Problem(shape=shape, rank=rank)),
        n_iters=n_iters, tol=0.0, init_factors=list(init),
    )
    pp = cp_als(
        x, plan_sweep(Problem(shape=shape, rank=rank, pp_tol=0.003), strategy="pp"),
        n_iters=n_iters, tol=0.0, init_factors=list(init),
    )
    # a majority of sweeps were approximated, yet the fit agrees
    assert 0 < pp.pp_exact_sweeps < n_iters // 2
    assert abs(float(exact.fit) - float(pp.fit)) < 1e-3


def test_moe_combine_weights_are_convex(host_mesh):
    """Per-token routing weights are a softmax over the top-k: sum <= 1."""
    import jax.numpy as jnp

    from repro.models.moe import moe_apply

    cfg = get_config("qwen2-moe-a2.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # extract one layer's moe params
    moe_p = jax.tree.map(lambda x: x[0], params["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_apply(moe_p, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 0.0
