"""Contraction-schedule IR: builders, validity, costing, and the invariant
that ANY valid schedule reproduces the flat ALS iterates on LocalExecutor."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_factors, random_tensor, tensor_norm
from repro.plan import (
    LocalExecutor,
    Problem,
    Schedule,
    SweepState,
    als_sweep,
    binary_schedule,
    build_schedule,
    chain_schedule,
    dimtree_mode_cost,
    enumerate_schedules,
    flat_schedule,
    node_cost,
    plan_sweep,
    select_executor,
    validate_executor,
)


# ------------------------------------------------------------- IR / builders
def test_builders_and_degenerate_shapes():
    p = Problem(shape=(6, 5, 4, 3), rank=3)
    flat = flat_schedule(p)
    assert flat.is_flat and flat.split is None
    assert [leaf.mode for leaf in flat.leaves()] == [0, 1, 2, 3]
    assert all(node.from_root for node in flat.walk())

    b = binary_schedule(p, 2)
    assert not b.is_flat and b.split == 2
    # two internal halves + four leaves, leaves in increasing mode order
    assert len(b.walk()) == 6
    assert [leaf.mode for leaf in b.leaves()] == [0, 1, 2, 3]
    # the left half contracts the right modes from the raw tensor
    left = b.nodes[b.root.children[0]]
    assert left.modes == (0, 1) and left.contracted == (2, 3) and left.from_root

    chain = chain_schedule(p)
    assert not chain.is_flat and chain.split is None
    # the chain reuses each partial: every internal node contracts ONE mode
    internals = [n for n in chain.walk() if not n.is_leaf]
    assert all(len(n.contracted) == 1 for n in internals)

    # size-1 halves degenerate to leaves off the root
    b1 = binary_schedule(Problem(shape=(4, 4, 4), rank=2), 1)
    assert b1.leaf_for_mode(0).from_root
    assert not b1.leaf_for_mode(2).from_root


def test_enumerate_schedules_counts():
    """Acceptance: an order-4 problem enumerates >= 3 distinct tree shapes."""
    order4 = enumerate_schedules(Problem(shape=(8, 8, 8, 8), rank=4))
    names = [s.name for s in order4]
    assert len(set(names)) == len(names)
    trees = [s for s in order4 if not s.is_flat]
    assert len(trees) >= 3, names  # binary@1..3 + chain
    assert any(s.name == "chain" for s in order4)
    order3 = enumerate_schedules(Problem(shape=(8, 8, 8), rank=4))
    assert sum(not s.is_flat for s in order3) >= 2


def test_build_schedule_rejects_invalid_specs():
    p = Problem(shape=(4, 4, 4, 4), rank=2)
    with pytest.raises(ValueError):  # gap / wrong order
        build_schedule(p, [[0, 2], [1, 3]])
    with pytest.raises(ValueError):  # missing a mode
        build_schedule(p, [0, 1, 2])
    with pytest.raises(ValueError):  # single-child internal node
        build_schedule(p, [[0, 1, 2, 3]])
    with pytest.raises(ValueError):  # duplicated mode breaks contiguity
        build_schedule(p, [0, 0, 1, 2, 3])


def test_node_metadata_matches_placement():
    p = Problem(
        shape=(8, 6, 4, 4), rank=3,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    b = binary_schedule(p, 2)
    left = b.nodes[b.root.children[0]]  # keeps {0,1}, contracts {2,3}
    assert left.reduce_axes == ("model",) and left.psum_participants == 4
    assert left.local_shape == (4, 6, 3)
    right = b.nodes[b.root.children[1]]  # keeps {2,3}, contracts {0,1}
    assert right.reduce_axes == ("data",) and right.psum_participants == 2
    # leaf 0 contracts mode 1 (unmapped) from the left partial: no psum
    assert b.leaf_for_mode(0).reduce_axes == ()
    # leaf 1 contracts mode 0 (mapped): psum over its axis
    assert b.leaf_for_mode(1).reduce_axes == ("data",)
    assert left.psum_bytes > 0.0 and b.leaf_for_mode(0).psum_bytes == 0.0


# ------------------------------------------------------------------- costing
def test_dimtree_mode_cost_folds_over_node_cost():
    """Summing the per-mode back-compat view == summing node_cost over the
    binary schedule: one coster, two projections."""
    p = Problem(
        shape=(8, 6, 4, 4), rank=3,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    for split in (1, 2, 3):
        sched = binary_schedule(p, split)
        node_total = sum(
            node_cost(p, node).predicted_s for node in sched.walk()
        )
        mode_total = sum(
            dimtree_mode_cost(p, n, split).predicted_s for n in range(4)
        )
        assert node_total == pytest.approx(mode_total)
    # the old special-case raise is gone: "dimtree" is a costed algorithm
    from repro.plan import mode_cost

    assert mode_cost(p, 1, "dimtree").predicted_s > 0.0


def test_validate_executor_is_the_single_predicate():
    sharded = Problem(
        shape=(4, 4), rank=2, mode_axes={0: "data"}, axis_sizes={"data": 2}
    )
    local = Problem(shape=(4, 4), rank=2)
    validate_executor(sharded, "sharded")  # no raise
    validate_executor(local, "local")
    msgs = []
    for problem, executor in ((sharded, "local"), (local, "overlapping"), (local, "compressed")):
        with pytest.raises(ValueError, match="cannot run this problem") as ei:
            validate_executor(problem, executor)
        msgs.append(str(ei.value))
    assert all("cannot run this problem" in m for m in msgs)
    with pytest.raises(ValueError, match="unknown executor"):
        validate_executor(local, "nope")


def test_serial_fractions_thread_through_plan():
    """Calibrated constants override the analytic defaults everywhere."""
    p = Problem(
        shape=(8, 16, 16), rank=5,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    base = plan_sweep(p, schedule="flat", executor="overlapping")
    fitted = plan_sweep(
        p, schedule="flat", executor="overlapping",
        serial_fractions={"overlapping": 0.5},
    )
    for mb, mf in zip(base.modes, fitted.modes):
        assert mf.cost.serial_fraction == pytest.approx(0.5)
        assert mf.cost.predicted_s > mb.cost.predicted_s  # 0.5 > 1/4 default
    assert fitted.serial_fractions == {"overlapping": 0.5}
    assert fitted.describe()["serial_fractions"] == {"overlapping": 0.5}
    # and fitted "sharded" fractions bend the exact executor's prediction
    sh = plan_sweep(p, schedule="flat", executor="sharded",
                    serial_fractions={"sharded": 0.9})
    assert all(m.cost.serial_fraction == pytest.approx(0.9) for m in sh.modes)
    with pytest.raises(ValueError):
        plan_sweep(p, serial_fractions={"nope": 0.5})
    with pytest.raises(ValueError):
        plan_sweep(p, serial_fractions={"overlapping": 1.5})


# ------------------------------------------------------- planner integration
def test_auto_enumerates_trees_and_can_pick_overlapping_dimtree():
    """Acceptance: order-4 bench shape -> >= 3 tree candidates; a dimtree
    schedule can land on the overlapping executor."""
    bench = Problem(shape=(63, 63, 63, 63), rank=25)
    assert sum(not s.is_flat for s in enumerate_schedules(bench)) >= 3
    # on the order-4 bench shape the binary tree's two X-reads beat the
    # flat sweep's four by far more than the 10% near-tie margin
    plan = plan_sweep(bench)
    assert plan.kind == "dimtree", plan.resolved_schedule.name
    # sharded order-3 with an 8-way psum: compression is at wire parity
    # (p=8) so the argmin lands on the exact overlapping executor -- for
    # the dimtree schedule too
    p = Problem(
        shape=(8, 32, 8), rank=8, mode_axes={0: "shard"}, axis_sizes={"shard": 8}
    )
    assert select_executor(p, "dimtree") == "overlapping"
    plan = plan_sweep(p, strategy="dimtree")
    assert plan.kind == "dimtree" and plan.executor == "overlapping"


def test_plan_sweep_accepts_explicit_and_named_schedules():
    p = Problem(shape=(5, 4, 6, 3), rank=3)
    custom = build_schedule(p, [0, [1, 2], 3], name="mixed")
    plan = plan_sweep(p, schedule=custom)
    assert plan.resolved_schedule is custom and plan.kind == "dimtree"
    assert plan_sweep(p, schedule="chain").resolved_schedule.name == "chain"
    assert plan_sweep(p, schedule="binary", split=1).split == 1
    with pytest.raises(ValueError, match="different Problem"):
        plan_sweep(Problem(shape=(5, 4, 6, 3), rank=4), schedule=custom)
    with pytest.raises(ValueError, match="unknown schedule"):
        plan_sweep(p, schedule="nope")


def test_legacy_wrappers_keep_flat_and_binary_shapes():
    """The frozen wrappers must not silently adopt tree schedules."""
    from repro.plan import legacy_sweep  # noqa: F401  (the bridge they share)

    x = random_tensor(jax.random.PRNGKey(0), (6, 5, 4))
    factors = random_factors(jax.random.PRNGKey(1), x.shape, 3)
    w = jnp.ones((3,), x.dtype)
    norm_x = tensor_norm(x)
    from repro.core.cpals import als_sweep as core_sweep
    from repro.core.dimtree import dimtree_sweep

    f1, w1, fit1 = core_sweep(
        x, list(factors), w, norm_x, jnp.asarray(0), method="auto", normalize=True
    )
    f2, w2, fit2 = dimtree_sweep(x, list(factors), w, norm_x, jnp.asarray(0))
    for a, b in zip(f1, f2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(fit1), float(fit2), atol=1e-4)


# ----------------------------------------- any schedule == flat ALS iterates
def _reference(x, factors, w, norm_x, problem, sweeps=2):
    plan = plan_sweep(problem, schedule="flat")
    fs, ws = list(factors), w
    for it in range(sweeps):
        st = SweepState(x=x, factors=fs, weights=ws, norm_x=norm_x, it=jnp.asarray(it))
        out = als_sweep(problem, plan, LocalExecutor(), st)
        fs, ws = out.factors, out.weights
    return fs, ws, out.fit


def _run_schedule(x, factors, w, norm_x, problem, sched, sweeps=2):
    plan = plan_sweep(problem, schedule=sched)
    fs, ws = list(factors), w
    for it in range(sweeps):
        st = SweepState(x=x, factors=fs, weights=ws, norm_x=norm_x, it=jnp.asarray(it))
        out = als_sweep(problem, plan, LocalExecutor(), st)
        fs, ws = out.factors, out.weights
    return fs, ws, out.fit


def _assert_matches_flat(shape, sched_or_spec, seed=0):
    rank = 3
    x = random_tensor(jax.random.PRNGKey(seed), shape)
    factors = random_factors(jax.random.PRNGKey(seed + 1), shape, rank)
    w = jnp.ones((rank,), x.dtype)
    norm_x = tensor_norm(x)
    problem = Problem.from_tensor(x, rank)
    sched = (
        sched_or_spec
        if isinstance(sched_or_spec, Schedule)
        else build_schedule(problem, sched_or_spec)
    )
    f_ref, w_ref, fit_ref = _reference(x, factors, w, norm_x, problem)
    f_s, w_s, fit_s = _run_schedule(x, factors, w, norm_x, problem, sched)
    for a, b in zip(f_s, f_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(fit_s), float(fit_ref), atol=1e-3)


@pytest.mark.parametrize(
    "shape,spec",
    [
        ((5, 6, 7), [[0, 1], 2]),
        ((4, 5, 6, 3), [[0, 1], [2, 3]]),
        ((4, 5, 6, 3), [[[0, 1], 2], 3]),
        ((3, 4, 2, 3, 4), [[0, 1], [2, [3, 4]]]),
        ((3, 4, 2, 3, 4), [0, [[1, 2], [3, 4]]]),
        ((3, 3, 2, 2, 3, 2), [[[0, 1], [2, 3]], [4, 5]]),
    ],
)
def test_schedules_match_flat_iterates(shape, spec):
    """Deterministic spot checks across orders 3..6 and tree depths."""
    _assert_matches_flat(shape, spec)


def test_every_enumerated_schedule_matches_flat_on_order4():
    problem = Problem(shape=(4, 5, 6, 3), rank=3)
    for sched in enumerate_schedules(problem):
        _assert_matches_flat((4, 5, 6, 3), sched)


# --------------------------------------------- hypothesis: random tree shapes
from conftest import given, settings, st  # noqa: E402  (shared optional-dep shim)


@st.composite
def _spec(draw, lo, hi):
    """A random valid nested spec over modes [lo, hi)."""
    if hi - lo == 1:
        return lo
    k = draw(st.integers(2, hi - lo))
    cuts = sorted(
        draw(
            st.sets(
                st.integers(lo + 1, hi - 1), min_size=k - 1, max_size=k - 1
            )
        )
    )
    bounds = [lo, *cuts, hi]
    return [
        a if b - a == 1 else draw(_spec(a, b))
        for a, b in zip(bounds[:-1], bounds[1:])
    ]


@st.composite
def _problem_and_spec(draw):
    order = draw(st.integers(3, 6))
    shape = tuple(draw(st.integers(2, 5)) for _ in range(order))
    spec = draw(_spec(0, order))
    return shape, spec


@settings(max_examples=15, deadline=None)
@given(case=_problem_and_spec())
def test_random_schedule_matches_flat_iterates(case):
    """Property (the ALS-exactness invariant of the IR): ANY valid tree
    over a random order-3..6 shape reproduces the flat sweep."""
    shape, spec = case
    _assert_matches_flat(shape, spec, seed=11)
