"""Shared pytest helpers.

THE one hypothesis-availability shim (repo convention: the property-based
dependency is optional, and its absence must degrade to *visible per-test
skips* -- never a module-level ``importorskip`` that silently drops a whole
file, and never per-file copies of the try/except boilerplate).  Test
modules use it as a drop-in import:

    from conftest import HAVE_HYPOTHESIS, given, settings, st

With hypothesis installed these are the real ``given``/``settings``/
``strategies``.  Without it, ``given(...)`` swaps the test for a zero-arg
stub marked ``skip(reason="hypothesis not installed")`` (keeping the test's
name and docstring, so the skip is attributed to the right test in reports),
``settings`` is an identity decorator, and ``st`` absorbs any strategy
construction -- calls and attribute lookups alike return the absorber, so
module-level strategy expressions (including ``@st.composite`` builders)
evaluate harmlessly without ever running.
"""

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AbsorbingStrategy:
        """Stands in for ``hypothesis.strategies`` when it isn't installed:
        every call and attribute access returns the absorber itself, so any
        strategy expression type-checks at collection time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AbsorbingStrategy()

    def settings(*args, **kwargs):
        """Identity decorator standing in for ``hypothesis.settings``."""
        return lambda fn: fn

    def given(*args, **kwargs):
        """Replace the decorated property test with a visible skip stub."""

        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass  # pragma: no cover - never executes

            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub

        return deco
