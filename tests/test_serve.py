"""Serving engine tests: greedy generation determinism, engine batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import mesh as meshlib
from repro.models import build_model
from repro.serve.engine import GenerationConfig, Request, ServeEngine, generate


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


def _setup(arch="olmo-1b"):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_greedy_deterministic(host_mesh):
    cfg, model, params = _setup()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab, jnp.int32)
    }
    gen = GenerationConfig(max_new_tokens=6, temperature=0.0)
    a = generate(model, params, batch, gen)
    b = generate(model, params, batch, gen)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)
    assert (a >= 0).all() and (a < cfg.vocab).all()


def test_generate_temperature_valid(host_mesh):
    cfg, model, params = _setup()
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab, jnp.int32)
    }
    out = generate(model, params, batch, GenerationConfig(max_new_tokens=5, temperature=1.0))
    assert out.shape == (2, 5)
    assert (out >= 0).all() and (out < cfg.vocab).all()


def test_generate_matches_decode_consistency(host_mesh):
    """Greedy generate continuation must equal manual prefill+decode argmax."""
    cfg, model, params = _setup()
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0, cfg.vocab, jnp.int32)
    gen_out = generate(model, params, {"tokens": tokens}, GenerationConfig(max_new_tokens=4))

    cache, logits = model.prefill(params, {"tokens": tokens}, max_len=13)
    toks = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(4):
        toks.append(int(tok[0, 0]))
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    np.testing.assert_array_equal(gen_out[0], np.asarray(toks))


def test_engine_serves_queue(host_mesh):
    cfg, model, params = _setup()
    eng = ServeEngine(model, params, GenerationConfig(max_new_tokens=3), batch_size=2)
    rids = [eng.submit(np.full((5,), i + 1, np.int32)) for i in range(5)]
    results = eng.flush()
    assert sorted(results) == sorted(rids)
    for r in results.values():
        assert r.shape == (3,)


def test_engine_ssm_arch(host_mesh):
    cfg, model, params = _setup("falcon-mamba-7b")
    eng = ServeEngine(model, params, GenerationConfig(max_new_tokens=2), batch_size=2)
    rid = eng.submit(np.asarray([1, 2, 3], np.int32))
    out = eng.flush()[rid]
    assert out.shape == (2,)
