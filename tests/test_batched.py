"""Batched CP end-to-end: one compiled dispatch amortized over a fleet.

Covers the leading-batch-dimension path through every layer: batched
``cp_als`` against a per-tensor Python loop (and B=1 bitwise against the
unbatched path), batch-parallel ``shard_map`` execution against the local
run, the planner's batch-vs-mode placement argmin, the sync-free driver's
one-dispatch-per-chunk guarantee at B >= 64, property sweeps over
(order, B, ragged batch chunk), and the tuning cache's backward-compatible
batch key field.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mttkrp_einsum, random_factors, random_tensor
from repro.core.mttkrp import mttkrp_batched
from repro.plan import Problem, cp_als, make_executor, plan_sweep
from repro.plan.autotune import TuningCache, problem_key

ON_CPU = jax.default_backend() == "cpu"
N_DEV = jax.device_count()


def _fleet(batch, shape, rank, seed=0):
    x = random_tensor(jax.random.PRNGKey(seed), (batch,) + shape)
    init = random_factors(jax.random.PRNGKey(seed + 1), shape, rank, batch=batch)
    return x, init


# ----------------------------------------------------------- driver numerics
def test_batched_cp_als_matches_per_tensor_loop():
    """Acceptance: batched cp_als over B stacked tensors matches running
    the unbatched driver on each tensor with the same init, allclose at
    highest precision; the fit is per-problem."""
    B, shape, rank = 6, (8, 9, 10), 4
    x, init = _fleet(B, shape, rank)
    prob = Problem.from_tensor(x, rank, batch=B)
    plan = plan_sweep(prob)
    st = cp_als(x, plan, n_iters=5, tol=0.0, init_factors=init)
    assert st.fit.shape == (B,)
    assert all(u.shape == (B, d, rank) for u, d in zip(st.factors, shape))
    for b in range(B):
        plb = plan_sweep(Problem.from_tensor(x[b], rank))
        stb = cp_als(
            x[b], plb, n_iters=5, tol=0.0, init_factors=[u[b] for u in init]
        )
        for u_batched, u_loop in zip(st.factors, stb.factors):
            np.testing.assert_allclose(
                np.asarray(u_batched[b]), np.asarray(u_loop), rtol=2e-4, atol=2e-5
            )
        np.testing.assert_allclose(
            float(st.fit[b]), float(stb.fit), rtol=1e-4, atol=1e-5
        )


def test_batch_one_bitwise_identical_to_unbatched():
    """B=1 problems keep arrays with no batch axis and run the exact old
    code path -- factors, weights, and fit are bitwise identical."""
    shape, rank = (8, 9, 10), 4
    x = random_tensor(jax.random.PRNGKey(3), shape)
    st1 = cp_als(x, plan_sweep(Problem.from_tensor(x, rank, batch=1)),
                 n_iters=5, tol=0.0, seed=2)
    st0 = cp_als(x, plan_sweep(Problem.from_tensor(x, rank)),
                 n_iters=5, tol=0.0, seed=2)
    for a, b in zip(st1.factors, st0.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(st1.weights), np.asarray(st0.weights))
    assert float(st1.fit) == float(st0.fit)


def test_batched_dimtree_schedule_matches_flat():
    """Tree schedules walk the same batched contractions: dimtree iterates
    equal the flat schedule's on a batched problem."""
    B, shape, rank = 4, (6, 7, 8, 5), 3
    x, init = _fleet(B, shape, rank, seed=5)
    prob = Problem.from_tensor(x, rank, batch=B)
    st_flat = cp_als(x, plan_sweep(prob, schedule="flat"),
                     n_iters=4, tol=0.0, init_factors=init)
    st_tree = cp_als(x, plan_sweep(prob, strategy="dimtree"),
                     n_iters=4, tol=0.0, init_factors=init)
    for a, b in zip(st_flat.factors, st_tree.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


# ------------------------------------------------------- sharded placements
@pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device runtime")
def test_batch_parallel_shard_map_equals_local():
    """Acceptance: a batch-sharded run (batch_axes over the mesh, no mode
    sharding, zero collectives) produces the local iterates."""
    B, shape, rank = 2 * N_DEV, (8, 8, 6), 4
    x, init = _fleet(B, shape, rank, seed=7)
    mesh = jax.make_mesh((N_DEV,), ("b",))
    prob = Problem(
        shape=shape, rank=rank, batch=B,
        batch_axes=("b",), axis_sizes={"b": N_DEV},
    )
    plan = plan_sweep(prob)
    assert plan.executor == "sharded"
    ex = make_executor(plan.executor, mesh, {}, batch_axes=("b",))
    st_sh = cp_als(x, plan, executor=ex, n_iters=4, tol=0.0, init_factors=init)
    st_lo = cp_als(x, plan_sweep(Problem.from_tensor(x, rank, batch=B)),
                   n_iters=4, tol=0.0, init_factors=init)
    for a, b in zip(st_sh.factors, st_lo.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        np.asarray(st_sh.fit), np.asarray(st_lo.fit), rtol=1e-4, atol=1e-5
    )


@pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device runtime")
def test_mode_parallel_batched_equals_local():
    """Mode-parallel sharding with the batch replicated: dist_mttkrp* accept
    the leading batch axis inside shard_map and reproduce the local run."""
    B, shape, rank = 4, (8, 8, 6), 4
    x, init = _fleet(B, shape, rank, seed=9)
    mesh = jax.make_mesh((N_DEV,), ("s",))
    mode_axes = {0: "s"}
    prob = Problem(
        shape=shape, rank=rank, batch=B,
        mode_axes=mode_axes, axis_sizes={"s": N_DEV},
    )
    st_lo = cp_als(x, plan_sweep(Problem.from_tensor(x, rank, batch=B)),
                   n_iters=3, tol=0.0, init_factors=init)
    for kind in ("sharded", "overlapping"):
        plan = plan_sweep(prob, executor=kind)
        ex = make_executor(kind, mesh, mode_axes)
        st = cp_als(x, plan, executor=ex, n_iters=3, tol=0.0, init_factors=init)
        for a, b in zip(st.factors, st_lo.factors):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5,
                err_msg=f"executor={kind}",
            )


@pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device runtime")
def test_compressed_batched_tracks_fit():
    """The int8 error-feedback collective threads batched residuals: the
    compressed run's per-problem fits track the exact ones."""
    B, shape, rank = 4, (8, 8, 6), 4
    x, init = _fleet(B, shape, rank, seed=11)
    mesh = jax.make_mesh((N_DEV,), ("s",))
    mode_axes = {0: "s"}
    prob = Problem(
        shape=shape, rank=rank, batch=B,
        mode_axes=mode_axes, axis_sizes={"s": N_DEV},
    )
    plan = plan_sweep(prob, executor="compressed")
    ex = make_executor("compressed", mesh, mode_axes)
    st = cp_als(x, plan, executor=ex, n_iters=4, tol=0.0, init_factors=init)
    st_lo = cp_als(x, plan_sweep(Problem.from_tensor(x, rank, batch=B)),
                   n_iters=4, tol=0.0, init_factors=init)
    np.testing.assert_allclose(
        np.asarray(st.fit), np.asarray(st_lo.fit), rtol=0.05, atol=0.05
    )


# -------------------------------------------------------- placement argmin
def test_plan_sweep_selects_batch_parallel_for_fleet():
    """Acceptance: for a fleet of small tensors given mode-parallel, the
    placement argmin re-places batch-parallel -- zero reduce traffic beats
    psum volume x B -- and describe() records both candidates' costs."""
    prob = Problem(
        shape=(16, 16, 16), rank=8, batch=64,
        mode_axes={0: "s"}, axis_sizes={"s": 8},
    )
    plan = plan_sweep(prob)
    desc = plan.describe()
    assert desc["placement"] == "batch-parallel"
    assert plan.problem.mode_axes == {}
    assert plan.problem.batch_axes == ("s",)
    rows = {r["placement"]: r for r in desc["placements"]}
    assert rows["batch-parallel"]["selected"]
    assert not rows["mode-parallel"]["selected"]
    assert rows["batch-parallel"]["collective_bytes"] == 0.0
    assert rows["mode-parallel"]["collective_bytes"] > 0.0
    assert rows["batch-parallel"]["predicted_s"] < rows["mode-parallel"]["predicted_s"]


def test_plan_sweep_keeps_explicit_batch_parallel():
    """A problem given batch-parallel stays as-given (no placement rows:
    there is nothing to argmin against)."""
    prob = Problem(
        shape=(16, 16, 16), rank=8, batch=64,
        batch_axes=("s",), axis_sizes={"s": 8},
    )
    plan = plan_sweep(prob)
    assert plan.describe()["placement"] == "batch-parallel"
    assert plan.describe()["placements"] == []
    assert plan.executor == "sharded"


def test_problem_batch_validation():
    """Batch fields validate: dual-role axes and indivisible batches raise,
    and the batch folds into the problem hash."""
    with pytest.raises(ValueError, match="cannot shard both"):
        Problem(shape=(8, 8), rank=2, batch=8,
                mode_axes={0: "s"}, batch_axes=("s",), axis_sizes={"s": 2})
    with pytest.raises(ValueError, match="divisible"):
        Problem(shape=(8, 8), rank=2, batch=3,
                batch_axes=("s",), axis_sizes={"s": 2})
    a = Problem(shape=(8, 8), rank=2)
    b = Problem(shape=(8, 8), rank=2, batch=4)
    assert hash(a) != hash(b)


# ------------------------------------------------------ one fused dispatch
def test_batched_one_dispatch_per_chunk(monkeypatch):
    """Acceptance: cp_als on Problem(batch=64) runs as ONE compiled
    dispatch per sweep chunk -- the host blocks once per chunk regardless
    of B (counted at the driver's single sync point)."""
    import repro.plan.sweep as sweeplib

    B, shape, rank = 64, (6, 6, 6), 3
    x, init = _fleet(B, shape, rank, seed=13)
    plan = plan_sweep(Problem.from_tensor(x, rank, batch=B))
    counts = {"n": 0}
    real = jax.block_until_ready

    def counting(tree):
        counts["n"] += 1
        return real(tree)

    monkeypatch.setattr(sweeplib, "_block_until_ready", counting)
    cp_als(x, plan, n_iters=6, track_fit=False, init_factors=init,
           sweeps_per_sync=3)
    assert counts["n"] == 2  # two chunks of 3 sweeps, B=64 notwithstanding
    counts["n"] = 0
    cp_als(x, plan, n_iters=6, track_fit=False, init_factors=init,
           sweeps_per_sync=6)
    assert counts["n"] == 1  # the whole run in one dispatch


def test_batched_convergence_stops_all_problems():
    """Convergence requires every problem's fit delta below tol; the chunk
    loop stops once the batch-max delta clears it."""
    B, shape, rank = 3, (6, 6, 6), 3
    x, init = _fleet(B, shape, rank, seed=15)
    plan = plan_sweep(Problem.from_tensor(x, rank, batch=B))
    fits = []
    st = cp_als(x, plan, n_iters=40, tol=1e-6, init_factors=init,
                callback=lambda it, fit, dt: fits.append(fit))
    assert st.it < 40  # actually converged
    assert len(fits) == st.it  # callback once per sweep, batch-mean fit
    assert st.fit.shape == (B,)


# ------------------------------------------------------- property sweeps
def _check_mttkrp_batched(order, batch, mode, method="auto", tiles=None):
    mode = mode % order
    shape = tuple(5 + k for k in range(order))
    rank = 3
    x = random_tensor(jax.random.PRNGKey(order * 13 + batch), (batch,) + shape)
    factors = random_factors(
        jax.random.PRNGKey(order * 29 + batch), shape, rank, batch=batch
    )
    if batch == 1:  # the kernel-level API always takes an explicit lead axis
        factors = [u[None] for u in factors]
    got = mttkrp_batched(x, factors, mode, method=method, tiles=tiles)
    want = jnp.stack([
        mttkrp_einsum(x[b], [u[b] for u in factors], mode) for b in range(batch)
    ])
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5
    )


@pytest.mark.parametrize("order", [3, 4])
@pytest.mark.parametrize("batch", [1, 3, 5])
def test_mttkrp_batched_ragged_grid(order, batch):
    """mttkrp_batched == per-item mttkrp_einsum over (order, B) including
    ragged batches; the fused kernel's batch grid axis pads + slices
    (block_batch=2 never divides B=1,3,5)."""
    for mode in range(order):
        _check_mttkrp_batched(order, batch, mode)
    _check_mttkrp_batched(
        order, batch, 0, method="fused",
        tiles={"block_i": 4, "block_b": 8, "block_batch": 2},
    )
    _check_mttkrp_batched(
        order, batch, order - 1, method="fused",
        tiles={"block_i": 4, "block_b": 8, "block_batch": 2},
    )


from conftest import given, settings, st  # noqa: E402  (shared optional-dep shim)


@settings(max_examples=12, deadline=None)
@given(
    order=st.integers(min_value=3, max_value=4),
    batch=st.integers(min_value=1, max_value=5),
    mode=st.integers(min_value=0, max_value=3),
    fused=st.booleans(),
)
def test_mttkrp_batched_property(order, batch, mode, fused):
    """Hypothesis sweep over (order, B, mode, kernel) -- small B forces
    the ragged last chunk of the batch grid axis."""
    if fused:
        _check_mttkrp_batched(
            order, batch, mode, method="fused",
            tiles={"block_i": 4, "block_b": 8, "block_batch": 2},
        )
    else:
        _check_mttkrp_batched(order, batch, mode)


# --------------------------------------------------------- tuning cache
def test_tuning_cache_batch_key_backward_compat(tmp_path):
    """Old 5-field cache keys (written before the batch dimension existed)
    keep resolving for B=1 problems; batched problems get a distinct
    ``|b{B}`` key that round-trips through the on-disk cache."""
    p1 = Problem(shape=(16, 16, 16), rank=8)
    p1b = Problem(shape=(16, 16, 16), rank=8, batch=1)
    pB = Problem(shape=(16, 16, 16), rank=8, batch=64)
    k1 = problem_key(p1, backend="cpu")
    assert problem_key(p1b, backend="cpu") == k1  # B=1 == historical layout
    assert "|b" not in k1
    kB = problem_key(pB, backend="cpu")
    assert kB == k1 + "|b64"

    path = os.fspath(tmp_path / "tuning.json")
    cache = TuningCache(path)
    # an entry written under the old (pre-batch) key format...
    cache.put(k1, {"tiles": {}, "nodes": [], "serial_fractions": {}})
    cache.put(kB, {"tiles": {}, "nodes": [], "serial_fractions": {"sharded": 1.0}})
    reloaded = TuningCache(path)
    # ...still resolves for today's B=1 problem, and the batched entry is
    # separate (a fleet's measured timings never shadow the single-tensor's)
    assert reloaded.get(problem_key(p1b, backend="cpu")) is not None
    got = reloaded.get(problem_key(pB, backend="cpu"))
    assert got is not None and got["serial_fractions"] == {"sharded": 1.0}
