"""Tests for the jax-version compat shim and the single-device degenerate
paths of the distributed MTTKRP subsystem (runs on the default 1-device
CPU backend -- the multi-device paths live in test_dist.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mttkrp, mttkrp_einsum, random_factors, random_tensor
from repro.core.cpals import als_sweep
from repro.core.tensor_ops import tensor_norm
from repro.dist.collectives import compressed_psum, init_error_state
from repro.dist.dist_mttkrp import (
    dist_als_sweep,
    dist_dimtree_sweep,
    dist_mttkrp,
    shard_problem,
)
from repro.launch import mesh as meshlib


@pytest.fixture(scope="module")
def mesh1():
    return meshlib.make_host_mesh(1, 1)


# ------------------------------------------------------------------ compat
def test_auto_axis_types_matches_installed_jax():
    types = compat.auto_axis_types(3)
    if compat.HAS_AXIS_TYPE:
        assert types == (jax.sharding.AxisType.Auto,) * 3
    else:
        assert types is None  # pre-0.6: kwarg must be dropped entirely


def test_make_mesh_accepts_axis_types_on_any_jax():
    m = compat.make_mesh((1, 1), ("data", "model"), axis_types=compat.auto_axis_types(2))
    assert dict(m.shape) == {"data": 1, "model": 1}


def test_mesh_from_devices():
    m = compat.mesh_from_devices(
        np.asarray(jax.devices()[:1]).reshape(1, 1),
        ("data", "model"),
        axis_types=compat.auto_axis_types(2),
    )
    assert m.axis_names == ("data", "model")


def test_public_shard_map_alias_installed():
    # importing repro.compat guarantees the >= 0.6 surface exists
    assert hasattr(jax, "shard_map")


@pytest.mark.parametrize("flag_name", ["check_vma", "check_rep"])
def test_shard_map_accepts_both_flag_spellings(mesh1, flag_name):
    def f(x):
        return jax.lax.psum(x, "data")

    out = compat.shard_map(
        f,
        mesh=mesh1,
        in_specs=P("data"),
        out_specs=P("data"),
        **{flag_name: False},
    )(jnp.arange(4.0))
    np.testing.assert_array_equal(np.asarray(out), np.arange(4.0))


def test_host_mesh_routes_through_compat(mesh1):
    # regression for jax.sharding.AxisType usage on jax < 0.6
    assert dict(mesh1.shape) == {"data": 1, "model": 1}


# ------------------------------------- dist degenerate paths (1-device mesh)
def test_shard_problem_preserves_values_and_layout(mesh1):
    x = random_tensor(jax.random.PRNGKey(0), (4, 3, 2))
    fs = random_factors(jax.random.PRNGKey(1), x.shape, 5)
    xs, fss = shard_problem(x, fs, {0: "data", 1: "model"}, mesh1)
    np.testing.assert_array_equal(np.asarray(xs), np.asarray(x))
    for a, b in zip(fs, fss):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_shard_problem_validates_mapping(mesh1):
    x = random_tensor(jax.random.PRNGKey(0), (4, 3, 2))
    fs = random_factors(jax.random.PRNGKey(1), x.shape, 2)
    with pytest.raises(ValueError):  # same mesh axis mapped twice
        shard_problem(x, fs, {0: "data", 1: "data"}, mesh1)
    with pytest.raises(ValueError):  # unknown mesh axis
        shard_problem(x, fs, {0: "pod"}, mesh1)
    with pytest.raises(ValueError):  # mode out of range
        shard_problem(x, fs, {7: "data"}, mesh1)


@pytest.mark.parametrize("mode_axes", [{}, {0: "data"}, {0: "data", 2: "model"}])
@pytest.mark.parametrize("method", ["auto", "1step", "2step"])
def test_dist_mttkrp_size1_mesh_reduces_to_core(mesh1, mode_axes, method):
    """Mesh of size 1: dist_mttkrp must equal repro.core.mttkrp exactly."""
    x = random_tensor(jax.random.PRNGKey(2), (4, 3, 2, 3))
    fs = random_factors(jax.random.PRNGKey(3), x.shape, 5)
    xs, fss = shard_problem(x, fs, mode_axes, mesh1)
    for n in range(x.ndim):
        out = dist_mttkrp(xs, fss, n, mode_axes, mesh1, method=method)
        ref = mttkrp(x, fs, n, method=method)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(mttkrp_einsum(x, fs, n)), rtol=1e-4, atol=1e-4
        )


def test_dist_sweeps_match_single_device_sweep(mesh1):
    """als + dimtree distributed sweeps == core als_sweep on a size-1 mesh."""
    mode_axes = {0: "data", 1: "model"}
    x = random_tensor(jax.random.PRNGKey(4), (6, 4, 4))
    fs = random_factors(jax.random.PRNGKey(5), x.shape, 3)
    xs, fss = shard_problem(x, fs, mode_axes, mesh1)
    w = jnp.ones((3,), x.dtype)
    norm_x = tensor_norm(x)

    f_ref, w_ref, fit_ref = als_sweep(
        x, list(fs), w, norm_x, jnp.asarray(0), method="2step", normalize=True
    )
    f_als, _, fit_als = dist_als_sweep(
        xs, fss, w, norm_x, jnp.asarray(0), mode_axes, mesh1, method="2step"
    )
    f_dt, _, fit_dt = dist_dimtree_sweep(
        xs, fss, w, norm_x, jnp.asarray(0), mode_axes, mesh1
    )
    for a, b, c in zip(f_ref, f_als, f_dt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(fit_ref), float(fit_als), atol=1e-5)
    np.testing.assert_allclose(float(fit_ref), float(fit_dt), atol=1e-5)


def test_compressed_psum_size1_axis_and_error_bound(mesh1):
    x = jnp.linspace(-2.0, 3.0, 16).reshape(4, 4)
    err0 = jnp.zeros_like(x)

    def f(x_blk, e_blk):
        return compressed_psum(x_blk, "data", e_blk)

    s, ne = compat.shard_map(
        f, mesh=mesh1, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False
    )(x, err0)
    step = float(jnp.max(jnp.abs(x))) / 127.0
    np.testing.assert_allclose(np.asarray(s), np.asarray(x), atol=step / 2 + 1e-6)
    assert float(jnp.max(jnp.abs(ne))) <= step / 2 + 1e-6
    # second round with carried residual stays bounded (error feedback)
    s2, ne2 = compat.shard_map(
        f, mesh=mesh1, in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False
    )(x, ne)
    assert float(jnp.max(jnp.abs(ne2))) <= float(jnp.max(jnp.abs(x + ne))) / 254.0 + 1e-6


def test_init_error_state_shapes():
    params = {"a": jnp.zeros((3, 2)), "b": {"c": jnp.zeros((4,))}}
    err = init_error_state(params, n_shards=2)
    assert err["a"].shape == (2, 3, 2)
    assert err["b"]["c"].shape == (2, 4)
    assert all(e.dtype == jnp.float32 for e in jax.tree.leaves(err))
