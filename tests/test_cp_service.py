"""CP serving engine: signature buckets, padded batches, one compile each.

Covers the serving layer end-to-end: packed-batch results allclose to the
direct per-tensor ``cp_als`` with shared init (mixed-signature stream),
padded partial batches (masked dummies cannot perturb real results), the
one-compile-per-signature guarantee, FIFO + priority scheduling, bounded
queue backpressure, warm-plan (TuningCache) hit counting, and the shared
:mod:`repro.serve.queue` scheduler's ordering rules.
"""

import jax
import numpy as np
import pytest

from repro.core.tensor_ops import random_factors, random_tensor
from repro.plan import Problem, cp_als, plan_sweep
from repro.plan.autotune import TuningCache, problem_key
from repro.serve import CPService, QueueFull, RequestQueue

N_DEV = jax.device_count()

RANK = 3
N_ITERS = 5


def _request(shape, seed):
    x = random_tensor(jax.random.PRNGKey(seed), shape)
    init = random_factors(jax.random.PRNGKey(1000 + seed), shape, RANK)
    return x, init


def _direct(x, init):
    """The per-tensor reference: same init, same sweep budget, tol=0."""
    plan = plan_sweep(Problem.from_tensor(x, RANK))
    return cp_als(x, plan, n_iters=N_ITERS, tol=0.0, init_factors=init)


def _assert_matches_direct(fut, x, init):
    res = fut.result()
    ref = _direct(x, init)
    for a, b in zip(res.factors, ref.factors):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5
        )
    np.testing.assert_allclose(
        np.asarray(res.weights), np.asarray(ref.weights), rtol=2e-4, atol=2e-5
    )
    np.testing.assert_allclose(res.fit, float(ref.fit), rtol=1e-4, atol=1e-5)
    assert res.sweeps == N_ITERS


# ------------------------------------------------------------ service numerics
def test_mixed_signature_stream_matches_per_tensor():
    """Acceptance: a mixed-signature request stream (two shapes interleaved,
    full + partial batches) returns decompositions allclose to the direct
    per-tensor cp_als with the same init, with exactly one compile per
    signature."""
    svc = CPService(batch_size=4, n_iters=N_ITERS)
    shapes = [(8, 9, 10), (6, 6, 6)]
    reqs = []
    for i in range(10):  # 5 per signature: one full batch + one padded each
        x, init = _request(shapes[i % 2], seed=i)
        reqs.append((x, init, svc.submit(x, RANK, init_factors=init)))
    done = svc.flush()
    assert len(done) == len(reqs) and all(f.done() for _, _, f in reqs)
    for x, init, fut in reqs:
        _assert_matches_direct(fut, x, init)
    stats = svc.stats()
    assert stats["signatures"] == 2
    assert stats["compiles"] == 2  # exactly one compiled dispatch per signature
    assert stats["batches"] == 4
    assert stats["completed"] == 10 and stats["queue_depth"] == 0


def test_padded_partial_batch_is_exact():
    """Masked dummy slots (real requests cycled into the padding) cannot
    perturb the real problems: a 3-request batch in an 8-slot dispatch
    matches the per-tensor reference exactly as a full batch would."""
    svc = CPService(batch_size=8, n_iters=N_ITERS)
    reqs = [_request((7, 6, 5), seed=20 + i) for i in range(3)]
    futs = [svc.submit(x, RANK, init_factors=init) for x, init in reqs]
    svc.flush()
    for (x, init), fut in zip(reqs, futs):
        _assert_matches_direct(fut, x, init)
    stats = svc.stats()
    assert stats["padded_slots"] == 5
    assert stats["batch_occupancy"] == pytest.approx(3 / 8)


def test_one_compile_per_signature_across_flushes():
    """Re-submitting a served signature reuses its compiled dispatch: the
    compile counter stays put across flushes and only a genuinely new
    signature bumps it."""
    svc = CPService(batch_size=2, n_iters=N_ITERS)
    for round_ in range(3):
        x, init = _request((6, 5, 4), seed=30 + round_)
        svc.submit(x, RANK, init_factors=init)
        svc.flush()
        assert svc.stats()["compiles"] == 1
    x, _ = _request((5, 5, 5), seed=40)  # new shape -> new signature
    svc.submit(x, RANK)
    svc.flush()
    assert svc.stats()["compiles"] == 2
    # update options are part of the signature: a different sweep budget
    # must NOT share the tuned dispatch (chunk length is compiled in)
    x, _ = _request((5, 5, 5), seed=41)
    svc.submit(x, RANK, n_iters=N_ITERS + 1)
    svc.flush()
    assert svc.stats()["signatures"] == 3 and svc.stats()["compiles"] == 3


def test_pp_requests_bucket_separately():
    """A pairwise-perturbation request cannot share a bucket (or a compiled
    dispatch) with the exact request for the same tensor: ``pp_tol`` is part
    of ``Problem.signature()``, so it is part of the batch key too."""
    svc = CPService(batch_size=2, n_iters=N_ITERS)
    x, init = _request((6, 5, 4), seed=77)
    sig_exact = svc.signature_of(x, RANK)
    sig_pp = svc.signature_of(x, RANK, pp_tol=0.25)
    assert "|pp" not in sig_exact and "|pp0.25" in sig_pp

    svc.submit(x, RANK, init_factors=init)
    svc.submit(x, RANK, init_factors=init, pp_tol=0.25)
    svc.flush()
    stats = svc.stats()
    assert stats["signatures"] == 2 and stats["compiles"] == 2

    # a repeat exact submit reuses the exact bucket's dispatch
    svc.submit(x, RANK, init_factors=init)
    svc.flush()
    assert svc.stats()["compiles"] == 2


# ---------------------------------------------------------------- scheduling
def test_fifo_within_signature_and_priority_across():
    """step() serves the bucket owning the most urgent request; within a
    bucket, higher priority first and FIFO (submission order) on ties."""
    svc = CPService(batch_size=2, n_iters=2)
    xa, _ = _request((6, 6, 6), seed=50)
    xb, _ = _request((7, 7, 7), seed=51)
    fa1 = svc.submit(xa, RANK)                  # bucket A, prio 0
    fb1 = svc.submit(xb, RANK, priority=5)      # bucket B, prio 5
    fa2 = svc.submit(xa, RANK, priority=3)      # bucket A, prio 3
    fa3 = svc.submit(xa, RANK)                  # bucket A, prio 0

    first = svc.step()  # B owns the globally most urgent request
    assert [f.rid for f in first] == [fb1.rid]
    second = svc.step()  # A: prio 3 first, then the oldest prio-0 request
    assert [f.rid for f in second] == [fa2.rid, fa1.rid]
    third = svc.step()
    assert [f.rid for f in third] == [fa3.rid]
    assert svc.step() == []


def test_request_queue_ordering_and_buckets():
    """The shared scheduler: priority-descending, FIFO within, per-key
    buckets, next_key() = bucket of the globally most urgent request."""
    q = RequestQueue()
    a0 = q.submit("a0", key="A")
    b0 = q.submit("b0", key="B", priority=2)
    a1 = q.submit("a1", key="A", priority=2)
    a2 = q.submit("a2", key="A")
    assert len(q) == q.depth == 4
    assert q.next_key() == "B"  # b0 is the oldest of the top-priority pair
    assert q.keys() == ["B", "A"]
    assert [r.payload for r in q] == ["b0", "a1", "a0", "a2"]
    assert q.take(10, "A") == [a1, a0, a2]
    assert q.take(10) == [b0]
    assert q.take(10) == [] and q.next_key() is None
    with pytest.raises(ValueError, match="batch_size"):
        q.take(0)


def test_bounded_queue_backpressure():
    """A full queue rejects submission with QueueFull (counted), and
    capacity frees up after a flush."""
    svc = CPService(batch_size=2, n_iters=2, max_pending=2)
    x, _ = _request((6, 6, 6), seed=60)
    svc.submit(x, RANK)
    svc.submit(x, RANK)
    with pytest.raises(QueueFull, match="max_pending=2"):
        svc.submit(x, RANK)
    assert svc.stats()["rejected"] == 1
    assert svc.stats()["queue_depth"] == 2
    svc.flush()
    svc.submit(x, RANK)  # drained: accepted again
    assert svc.stats()["queue_depth"] == 1
    with pytest.raises(ValueError, match="max_pending"):
        RequestQueue(0)


# ----------------------------------------------------------------- warm plans
def test_warm_plan_hits_from_tuning_cache(tmp_path):
    """The persistent TuningCache doubles as the warm-plan store keyed by
    the same signature: a signature tuned on disk counts a warm_plan_hit,
    an untuned one plans analytically (no hit)."""
    shape, B = (6, 5, 4), 2
    cache = TuningCache(tmp_path / "tuning.json")
    tuned = Problem(shape=shape, rank=RANK, batch=B)
    cache.put(
        problem_key(tuned),
        {"nodes": [], "tiles": {}, "serial_fractions": {}},
    )
    svc = CPService(batch_size=B, n_iters=2, strategy="autotune",
                    tuning_cache=TuningCache(tmp_path / "tuning.json"))
    x, _ = _request(shape, seed=70)
    svc.submit(x, RANK)
    svc.flush()
    assert svc.stats()["warm_plan_hits"] == 1
    y, _ = _request((8, 8, 8), seed=71)  # never tuned
    svc.submit(y, RANK)
    svc.flush()
    stats = svc.stats()
    assert stats["signatures"] == 2 and stats["warm_plan_hits"] == 1


def test_service_signature_is_the_canonical_problem_signature():
    """The batch bucket key extends Problem.signature()/problem_key (the
    tuning-cache key) with the update options -- one key construction."""
    svc = CPService(batch_size=4, n_iters=7, tol=0.0)
    x = random_tensor(jax.random.PRNGKey(0), (6, 5, 4))
    sig = svc.signature_of(x, RANK)
    base = problem_key(Problem(shape=(6, 5, 4), rank=RANK, batch=4))
    assert sig == f"{base}|i7|t0"
    assert svc.signature_of(x, RANK, n_iters=9) == f"{base}|i9|t0"


# ------------------------------------------------------------- sharded serving
@pytest.mark.skipif(N_DEV < 2, reason="needs a multi-device runtime")
def test_batch_parallel_service_matches_local():
    """A mesh-backed service (batch axis sharded over every device, zero
    collective traffic) returns the local service's results."""
    mesh = jax.make_mesh((N_DEV,), ("b",))
    svc_sh = CPService(batch_size=N_DEV, n_iters=N_ITERS, mesh=mesh)
    svc_lo = CPService(batch_size=N_DEV, n_iters=N_ITERS)
    reqs = [_request((8, 8, 6), seed=80 + i) for i in range(N_DEV)]
    futs_sh = [svc_sh.submit(x, RANK, init_factors=init) for x, init in reqs]
    futs_lo = [svc_lo.submit(x, RANK, init_factors=init) for x, init in reqs]
    svc_sh.flush()
    svc_lo.flush()
    for fs, fl in zip(futs_sh, futs_lo):
        a, b = fs.result(), fl.result()
        for ua, ub in zip(a.factors, b.factors):
            np.testing.assert_allclose(
                np.asarray(ua), np.asarray(ub), rtol=2e-4, atol=2e-5
            )
        np.testing.assert_allclose(a.fit, b.fit, rtol=1e-4, atol=1e-5)
    assert svc_sh.stats()["compiles"] == 1


def test_submit_validation_and_future_protocol():
    """Bad submissions fail loudly; futures refuse to resolve early."""
    svc = CPService(batch_size=2, n_iters=2)
    with pytest.raises(ValueError, match="order"):
        svc.submit(np.zeros((4,)), RANK)
    x = random_tensor(jax.random.PRNGKey(0), (5, 4, 3))
    with pytest.raises(ValueError, match="init_factors"):
        svc.submit(x, RANK, init_factors=[np.zeros((5, RANK))] * 3)
    fut = svc.submit(x, RANK)
    assert not fut.done()
    with pytest.raises(RuntimeError, match="pending"):
        fut.result()
    svc.flush()
    assert fut.done() and fut.result().rid == fut.rid
    with pytest.raises(ValueError, match="batch_size"):
        CPService(batch_size=0)
    if N_DEV > 1:
        with pytest.raises(ValueError, match="divisible"):
            CPService(batch_size=N_DEV + 1, mesh=jax.make_mesh((N_DEV,), ("b",)))
