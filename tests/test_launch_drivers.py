"""Launch-driver smoke tests (subprocess CLIs: train.py / serve.py)."""

import os
import subprocess
import sys

import pytest


def _run_module(mod: str, *argv: str, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, "-m", mod, *argv],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"{mod} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout + proc.stderr


def test_train_driver(tmp_path):
    out = _run_module(
        "repro.launch.train",
        "--arch", "olmo-1b", "--reduced", "--steps", "6", "--batch", "2",
        "--seq", "32", "--ckpt-every", "3", "--ckpt-dir", str(tmp_path),
    )
    assert "done: step=6" in out
    assert any(p.startswith("step_") for p in os.listdir(tmp_path))


def test_serve_driver_restores_checkpoint(tmp_path):
    _run_module(
        "repro.launch.train",
        "--arch", "olmo-1b", "--reduced", "--steps", "4", "--batch", "2",
        "--seq", "32", "--ckpt-every", "2", "--ckpt-dir", str(tmp_path),
    )
    out = _run_module(
        "repro.launch.serve",
        "--arch", "olmo-1b", "--reduced", "--requests", "2",
        "--new-tokens", "4", "--ckpt-dir", str(tmp_path),
    )
    assert "restored step" in out
    assert "served 2 requests" in out


def test_serve_cp_driver(tmp_path):
    out = _run_module(
        "repro.launch.serve_cp",
        "--requests", "4", "--batch-size", "2", "--dim", "6",
        "--n-iters", "2", "--tuning-cache", str(tmp_path / "tuning.json"),
    )
    assert "served 4 problems" in out
    assert "signatures=2 compiles=2" in out
