"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and no NaNs (brief requirement (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, list_archs
from repro.launch import mesh as meshlib
from repro.models import build_model

BATCH, SEQ = 2, 16


def _batch(cfg, seq=SEQ, batch=BATCH):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab, jnp.int32)
    out = {"tokens": tokens}
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
        out["positions"] = jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    if cfg.is_encdec:
        out["frames"] = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
    return out


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


@pytest.mark.parametrize("arch", list_archs())
def test_loss_forward(arch, host_mesh):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss_fn)(params, _batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert np.isfinite(float(metrics["ce"]))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch, host_mesh):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))

    @jax.jit
    def step(p, batch):
        loss, grads = jax.value_and_grad(lambda q: model.loss_fn(q, batch)[0])(p)
        p2 = jax.tree.map(lambda a, g: a - 1e-3 * g, p, grads)
        return loss, p2, grads

    loss, params2, grads = step(params, _batch(cfg))
    assert np.isfinite(float(loss)), arch
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: NaN grads"
    # at least the embedding moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, params2
    )
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode(arch, host_mesh):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    batch = _batch(cfg, seq=8)
    cache, logits = jax.jit(lambda p, b: model.prefill(p, b, max_len=24))(params, batch)
    assert logits.shape == (BATCH, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for _ in range(3):
        logits, cache = jax.jit(model.decode_step)(params, tok, cache)
        assert logits.shape == (BATCH, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]


def test_decode_matches_forward_dense(host_mesh):
    """Teacher-forced decode step-by-step must match the parallel forward."""
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    tokens = jax.random.randint(jax.random.PRNGKey(5), (1, 10), 0, cfg.vocab, jnp.int32)

    from repro.models import transformer

    h, _, _ = transformer.forward(params, cfg, tokens)
    full_logits = transformer.lm_logits(params, cfg, h)

    cache = model.init_cache(batch=1, max_len=16)
    step_logits = []
    for i in range(10):
        logits, cache = model.decode_step(params, tokens[:, i : i + 1], cache)
        step_logits.append(np.asarray(logits[:, 0], np.float32))
    step_logits = np.stack(step_logits, 1)
    np.testing.assert_allclose(
        step_logits, np.asarray(full_logits, np.float32), rtol=2e-3, atol=2e-3
    )


def test_decode_matches_forward_swa(host_mesh):
    """Sliding-window ring cache must agree with windowed parallel attention."""
    cfg = get_config("h2o-danube-3-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(6))
    n = 3 * cfg.sliding_window  # exercise ring wrap-around
    tokens = jax.random.randint(jax.random.PRNGKey(7), (1, n), 0, cfg.vocab, jnp.int32)

    from repro.models import transformer

    h, _, _ = transformer.forward(params, cfg, tokens)
    full_logits = np.asarray(transformer.lm_logits(params, cfg, h), np.float32)

    cache = model.init_cache(batch=1, max_len=n)
    dec = jax.jit(model.decode_step)
    step_logits = []
    for i in range(n):
        logits, cache = dec(params, tokens[:, i : i + 1], cache)
        step_logits.append(np.asarray(logits[:, 0], np.float32))
    step_logits = np.stack(step_logits, 1)
    np.testing.assert_allclose(step_logits, full_logits, rtol=3e-3, atol=3e-3)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    for name, cfg in ARCHS.items():
        assert cfg.source, f"{name} missing provenance"


def test_param_counts_full_configs():
    """Full (non-reduced) configs must have plausible param counts."""
    from repro.analysis.flops import param_count

    expect = {
        "qwen2-vl-7b": (6e9, 9e9),
        "dbrx-132b": (110e9, 140e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "deepseek-coder-33b": (30e9, 36e9),
        "qwen3-8b": (7e9, 9.5e9),
        "h2o-danube-3-4b": (3e9, 5e9),
        "recurrentgemma-2b": (2e9, 3.6e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "whisper-base": (0.03e9, 0.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_count(get_config(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
