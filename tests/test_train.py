"""Training substrate tests: optimizer math, loss decrease, fault tolerance,
straggler detection, grad accumulation equivalence."""

import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch import mesh as meshlib
from repro.models import build_model
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import (
    OptConfig,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    schedule,
)
from repro.train.train_step import make_train_step


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


def _tiny_model():
    return build_model(get_config("olmo-1b").reduced())


def _data(cfg, batch=4, seq=16, seed=0):
    return SyntheticLM(DataConfig(cfg.vocab, seq, batch, seed=seed))


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(schedule(cfg, jnp.asarray(110))) - 0.1) < 1e-3


def test_clip_by_global_norm():
    tree = {"a": jnp.full((3,), 4.0), "b": jnp.full((4,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - np.sqrt(3 * 16 + 4 * 9)) < 1e-4
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-4


def test_adamw_matches_reference_step():
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.1, 0.3])}
    cfg = OptConfig(
        lr=1e-2, weight_decay=0.0, clip_norm=1e9, warmup_steps=0, total_steps=100_000
    )
    st = init_opt_state(p)
    p2, st2, _ = adamw_update(p, g, st, cfg)
    # after 1 step with zero-init moments: mhat = g, vhat = g^2 -> delta = sign(g)
    # (cosine decay at step 1 of 100k is ~1.0)
    expect = np.asarray([1.0, -2.0]) - 1e-2 * np.sign([0.1, 0.3])
    np.testing.assert_allclose(np.asarray(p2["w"]), expect, rtol=1e-3)
    assert int(st2.step) == 1


def test_loss_decreases(host_mesh):
    model = _tiny_model()
    # small data vocab (tokens < model vocab) => learnable bigram structure
    data = SyntheticLM(DataConfig(vocab=32, seq_len=32, global_batch=8, seed=0))
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=3e-3, warmup_steps=5, total_steps=200, clip_norm=1.0)
    step = jax.jit(make_train_step(model, opt_cfg))
    opt_state = init_opt_state(params)
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


def test_grad_accum_equivalence(host_mesh):
    model = _tiny_model()
    data = _data(model.cfg, batch=8)
    params = model.init(jax.random.PRNGKey(1))
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10, clip_norm=1e9)
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

    s1 = make_train_step(model, opt_cfg, accum_steps=1)
    s2 = make_train_step(model, opt_cfg, accum_steps=4)
    p1, _, m1 = jax.jit(s1)(params, init_opt_state(params), batch)
    p2, _, m2 = jax.jit(s2)(params, init_opt_state(params), batch)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3  # same data, same update direction


def test_fault_tolerant_loop_recovers(tmp_path, host_mesh):
    model = _tiny_model()
    data = _data(model.cfg)
    boom = {"armed": True}

    def fault_hook(step):
        if step == 7 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    res = train_loop(
        model,
        data,
        OptConfig(lr=1e-3, warmup_steps=0, total_steps=12),
        LoopConfig(total_steps=12, ckpt_every=5, ckpt_dir=str(tmp_path), max_failures=2),
        fault_hook=fault_hook,
    )
    assert res.step == 12
    assert res.failures == 1
    # replay: steps 5..7 were re-run from the step-5 checkpoint
    steps = [m["step"] for m in res.metrics_history]
    assert steps.count(6) == 2 and steps[-1] == 12


def test_fault_budget_exhausted(tmp_path, host_mesh):
    model = _tiny_model()
    data = _data(model.cfg)

    def always_fail(step):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError):
        train_loop(
            model,
            data,
            OptConfig(total_steps=4),
            LoopConfig(total_steps=4, ckpt_every=2, ckpt_dir=str(tmp_path), max_failures=2),
            fault_hook=always_fail,
        )


def test_straggler_watchdog(tmp_path, host_mesh):
    import time

    model = _tiny_model()
    data = _data(model.cfg)

    def slow_step(step):
        if step == 10:
            time.sleep(1.5)

    res = train_loop(
        model,
        data,
        OptConfig(total_steps=12),
        LoopConfig(
            total_steps=12, ckpt_every=50, ckpt_dir=str(tmp_path), straggler_factor=3.0
        ),
        fault_hook=slow_step,
    )
    assert 10 in res.straggler_steps


def test_prefetcher_orders_batches():
    data = _data(get_config("olmo-1b").reduced(), batch=2, seq=8)
    pf = Prefetcher(data, start=3, depth=2)
    try:
        idx0, b0 = next(pf)
        idx1, b1 = next(pf)
        assert (idx0, idx1) == (3, 4)
        np.testing.assert_array_equal(b0["tokens"], data.batch(3)["tokens"])
    finally:
        pf.close()


def test_data_determinism_and_host_sharding():
    cfg = DataConfig(vocab=97, seq_len=12, global_batch=8, seed=5)
    full = SyntheticLM(cfg).batch(11)["tokens"]
    parts = []
    for host in range(4):
        c = DataConfig(vocab=97, seq_len=12, global_batch=8, seed=5, host_id=host, host_count=4)
        parts.append(SyntheticLM(c).batch(11)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)
