"""Multi-device distributed tests (8 fake host devices via subprocess --
XLA device count is locked at first init, so each case gets its own process)."""

import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "dist_worker.py")


def _run(case: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run(
        [sys.executable, WORKER, case],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, f"{case} failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout


def test_dist_mttkrp_all_modes():
    out = _run("dist_mttkrp")
    assert "dist_mttkrp OK" in out


def test_matrix_free_sharded_matches_einsum():
    out = _run("matrix_free_sharded")
    assert "matrix_free_sharded OK" in out


def test_dist_cpals_recovers_planted():
    out = _run("dist_cpals")
    assert "dist_cpals OK" in out


def test_dist_dimtree_matches_standard_als():
    out = _run("dist_dimtree")
    assert "dist_dimtree OK" in out


def test_overlapping_executor_matches_sharded():
    out = _run("overlap_mttkrp")
    assert "overlap_mttkrp OK" in out


def test_schedule_overlapped_dimtree_bitwise_matches_sharded():
    out = _run("schedule_overlap")
    assert "schedule_overlap OK" in out


def test_compressed_cpals_reaches_exact_fit():
    out = _run("compressed_cpals")
    assert "compressed_cpals OK" in out


def test_compressed_psum_error_feedback():
    out = _run("compressed_psum")
    assert "compressed_psum OK" in out


def test_compressed_dp_trainer_tracks_exact():
    out = _run("compressed_dp")
    assert "compressed_dp OK" in out


def test_pp_sharded_matches_local():
    out = _run("pp_sharded")
    assert "pp_sharded OK" in out


def test_hierarchical_psum_matches_flat():
    out = _run("hierarchical_psum")
    assert "hierarchical_psum OK" in out


def test_elastic_restore_across_mesh_shapes():
    out = _run("elastic_restore")
    assert "elastic_restore OK" in out
