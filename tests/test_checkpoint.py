"""Checkpoint manager tests: roundtrip, atomicity, keep-k, elastic reshard."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "layers": [
            {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
            {"w": jax.random.normal(k, (4, 8)), "b": jnp.ones((8,))},
        ],
        "step_scalar": jnp.asarray(3, jnp.int32),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(10, tree)
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = _tree(1)
    mgr.save_async(7, tree)
    mgr.wait()
    assert mgr.latest_step() == 7
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(
        np.asarray(restored["layers"][0]["w"]), np.asarray(tree["layers"][0]["w"])
    )


def test_tmp_dirs_are_not_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert mgr.latest_step() is None


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((2, 2))})


def test_elastic_restore_new_mesh(tmp_path):
    """Save from one mesh layout, restore re-placed onto a different one."""
    from jax.sharding import PartitionSpec as P

    from repro.launch import mesh as meshlib

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(8, 2), "b": jnp.ones((8,))}
    mgr.save(5, tree)

    mesh = meshlib.make_host_mesh(1, 1)  # "new" mesh after elastic restart
    specs = {"w": P("data", None), "b": P()}
    restored, _ = mgr.restore(
        jax.tree.map(jnp.zeros_like, tree), mesh=mesh, specs=specs
    )
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding.spec == P("data", None)


def test_manifest_contents(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(3, {"x": jnp.zeros((2, 5), jnp.bfloat16)}, extra={"arch": "t"})
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    assert m["arch"] == "t"
    assert m["shapes"]["x"] == [2, 5]
    assert m["dtypes"]["x"] == "bfloat16"
