"""Public-API docstring coverage for ``repro.plan`` and ``repro.dist``.

Every name a package exports through ``__all__`` is a documented contract:
functions and classes must carry a non-empty docstring, and so must the
public methods/properties a class defines itself (inherited members are the
parent's responsibility).  Plain data exports (ALGORITHMS/STRATEGIES/...)
are covered by the module docstrings instead.
"""

import importlib
import inspect

import pytest


def _missing_docs(modname: str) -> list[str]:
    mod = importlib.import_module(modname)
    missing = []
    for name in mod.__all__:
        obj = getattr(mod, name)
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue  # data exports: documented in the module docstring
        if not (inspect.getdoc(obj) or "").strip():
            missing.append(f"{modname}.{name}")
        if inspect.isclass(obj):
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                fn = member.fget if isinstance(member, property) else member
                if not callable(fn):
                    continue
                if not (getattr(fn, "__doc__", None) or "").strip():
                    missing.append(f"{modname}.{name}.{mname}")
    return missing


@pytest.mark.parametrize("modname", ["repro.plan", "repro.dist", "repro.serve"])
def test_public_api_has_docstrings(modname):
    missing = _missing_docs(modname)
    assert not missing, f"undocumented public API: {missing}"


@pytest.mark.parametrize("modname", ["repro.plan", "repro.dist", "repro.serve"])
def test_all_names_resolve(modname):
    """__all__ must not advertise names the package fails to define."""
    mod = importlib.import_module(modname)
    for name in mod.__all__:
        assert hasattr(mod, name), f"{modname}.__all__ lists missing {name!r}"
