"""Multi-device worker executed in a subprocess with 8 fake host devices.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 python dist_worker.py <case>
Exits nonzero on assertion failure; stdout carries diagnostics.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import CPConfig, cp_als, cp_full, mttkrp_einsum, random_factors, random_tensor  # noqa: E402
from repro.dist.collectives import (  # noqa: E402
    compressed_psum,
    init_error_state,
    make_compressed_dp_step,
)
from repro.dist.dist_mttkrp import dist_cp_als, dist_mttkrp, shard_problem  # noqa: E402
from repro.launch import mesh as meshlib  # noqa: E402


def case_dist_mttkrp():
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    x = random_tensor(key, (8, 6, 4, 5))
    factors = random_factors(jax.random.PRNGKey(1), x.shape, 7)
    mode_axes = {0: "data", 2: "model"}
    xs, fs = shard_problem(x, factors, mode_axes, mesh)
    for n in range(4):
        out = dist_mttkrp(xs, fs, n, mode_axes, mesh)
        ref = mttkrp_einsum(x, factors, n)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=5e-4, atol=5e-4
        )
    # also exercise a 3-axis-style mapping over both mesh axes + the paper's
    # 1-step method explicitly
    out = dist_mttkrp(xs, fs, 1, mode_axes, mesh, method="1step")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(mttkrp_einsum(x, factors, 1)), rtol=5e-4, atol=5e-4
    )
    print("dist_mttkrp OK")


def case_matrix_free_sharded():
    """Matrix-free kernel inside the shard_map local contraction == einsum
    oracle: each worker streams its natural-layout shard through the Pallas
    kernel (interpret mode on CPU) and the psum stitches the full MTTKRP."""
    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = random_tensor(jax.random.PRNGKey(3), (8, 6, 4, 5))
    factors = random_factors(jax.random.PRNGKey(4), x.shape, 7)
    mode_axes = {0: "data", 2: "model"}
    xs, fs = shard_problem(x, factors, mode_axes, mesh)
    tiles = {"block_i": 4, "block_r": 2}
    for n in range(4):
        out = dist_mttkrp(xs, fs, n, mode_axes, mesh, method="matrix_free", tiles=tiles)
        ref = mttkrp_einsum(x, factors, n)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=5e-4, atol=5e-4, err_msg=f"mode {n}"
        )
    print("matrix_free_sharded OK")


def case_dist_cpals():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(2)
    planted = random_factors(key, (12, 8, 8), 3)
    x = cp_full(None, planted)
    mode_axes = {0: "data", 1: "model"}
    fs, w, fit = dist_cp_als(x, rank=3, mode_axes=mode_axes, mesh=mesh, n_iters=120, tol=1e-9)
    assert float(fit) > 0.99, float(fit)
    # cross-check against the single-device driver
    st = cp_als(x, CPConfig(rank=3, n_iters=120, tol=1e-9, seed=0))
    assert abs(float(fit) - float(st.fit)) < 5e-3, (float(fit), float(st.fit))
    print("dist_cpals OK fit=", float(fit))


def case_dist_dimtree():
    """Distributed dimension-tree sweep == single-device standard ALS sweep."""
    from repro.core.cpals import als_sweep
    from repro.core.tensor_ops import tensor_norm
    from repro.dist.dist_mttkrp import dist_dimtree_sweep, shard_problem

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(5)
    x = random_tensor(key, (8, 6, 8, 4))
    factors = random_factors(jax.random.PRNGKey(6), x.shape, 3)
    mode_axes = {0: "data", 2: "model"}
    xs, fs = shard_problem(x, factors, mode_axes, mesh)
    w = jnp.ones((3,), x.dtype)
    norm_x = tensor_norm(x)

    f_ref, w_ref = list(factors), w
    f_dist, w_dist = fs, w
    for it in range(3):
        f_ref, w_ref, fit_ref = als_sweep(
            x, f_ref, w_ref, norm_x, jnp.asarray(it), method="2step", normalize=True
        )
        f_dist, w_dist, fit_dist = dist_dimtree_sweep(
            xs, f_dist, w_dist, norm_x, jnp.asarray(it), mode_axes, mesh
        )
        for a, b in zip(f_ref, f_dist):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4
            )
        np.testing.assert_allclose(float(fit_ref), float(fit_dist), atol=1e-4)
    print("dist_dimtree OK fit=", float(fit_dist))


def case_elastic_restore():
    """Save sharded state from a (4,2) mesh, restore onto (2,4) -- the
    elastic-restart path (pod loss / mesh reshape) end to end."""
    import tempfile

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import CheckpointManager

    mesh_a = jax.make_mesh((4, 2), ("data", "model"))
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))
    specs = {"w": P("data", "model"), "b": P("model")}
    tree = {
        "w": jax.device_put(
            jnp.arange(64.0).reshape(8, 8), NamedSharding(mesh_a, specs["w"])
        ),
        "b": jax.device_put(jnp.arange(8.0), NamedSharding(mesh_a, specs["b"])),
    }
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, tree)
        template = jax.tree.map(jnp.zeros_like, tree)
        restored, _ = mgr.restore(template, mesh=mesh_b, specs=specs)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(tree[k]))
        assert restored[k].sharding.mesh.shape == dict(mesh_b.shape)
    print("elastic_restore OK")


def case_overlap_mttkrp():
    """OverlappingExecutor == ShardedExecutor: chunked per-slab psums cover
    disjoint output rows, so overlap changes the schedule, not the result."""
    from repro.core.tensor_ops import tensor_norm
    from repro.dist.dist_mttkrp import dist_mttkrp, dist_mttkrp_overlapped
    from repro.plan import (
        OverlappingExecutor,
        Problem,
        ShardedExecutor,
        SweepState,
        als_sweep,
        plan_sweep,
    )

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = random_tensor(jax.random.PRNGKey(0), (8, 6, 4, 5))
    factors = random_factors(jax.random.PRNGKey(1), x.shape, 7)
    mode_axes = {0: "data", 2: "model"}
    xs, fs = shard_problem(x, factors, mode_axes, mesh)
    # per-mode MTTKRP: overlapped == plain for every mode and chunk count
    for n in range(4):
        ref = dist_mttkrp(xs, fs, n, mode_axes, mesh)
        for n_chunks in (1, 2, 3, 8):
            out = dist_mttkrp_overlapped(xs, fs, n, mode_axes, mesh, n_chunks=n_chunks)
            np.testing.assert_allclose(
                np.asarray(out), np.asarray(ref), rtol=1e-6, atol=1e-6
            )
    # full ALS sweeps: iterates stay matched across several sweeps
    problem = Problem.from_tensor(x, 7, mode_axes=mode_axes, mesh=mesh)
    plan = plan_sweep(problem, executor="overlapping")
    assert plan.executor == "overlapping"
    assert any(m.cost.predicted_overlap_efficiency > 0 for m in plan.modes)
    w = jnp.ones((7,), x.dtype)
    norm_x = tensor_norm(x)
    f_sh, f_ov = list(fs), list(fs)
    w_sh = w_ov = w
    for it in range(3):
        st_sh = SweepState(x=xs, factors=f_sh, weights=w_sh, norm_x=norm_x, it=jnp.asarray(it))
        st_ov = SweepState(x=xs, factors=f_ov, weights=w_ov, norm_x=norm_x, it=jnp.asarray(it))
        out_sh = als_sweep(problem, plan, ShardedExecutor(mesh, mode_axes), st_sh)
        out_ov = als_sweep(problem, plan, OverlappingExecutor(mesh, mode_axes, n_chunks=3), st_ov)
        f_sh, w_sh = out_sh.factors, out_sh.weights
        f_ov, w_ov = out_ov.factors, out_ov.weights
        for a, b in zip(f_sh, f_ov):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(out_sh.fit), float(out_ov.fit), atol=1e-5)
    print("overlap_mttkrp OK")


def case_schedule_overlap():
    """Overlapped-dimtree == sharded-dimtree BITWISE: every node of the
    binary schedule is a partial contraction whose chunked per-slab psums
    cover disjoint rows of the same reduction, so overlap changes the
    schedule, never a bit of the result.  Also exercises the chain schedule
    and the compressed executor on tree partials (error-feedback carry)."""
    from repro.core.tensor_ops import tensor_norm
    from repro.plan import (
        CompressedShardedExecutor,
        OverlappingExecutor,
        Problem,
        ShardedExecutor,
        SweepState,
        als_sweep,
        enumerate_schedules,
        make_executor,
        plan_sweep,
        select_executor,
    )

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = random_tensor(jax.random.PRNGKey(0), (8, 6, 4, 4))
    factors = random_factors(jax.random.PRNGKey(1), x.shape, 5)
    mode_axes = {0: "data", 2: "model"}
    from repro.dist.dist_mttkrp import shard_problem as _shard

    xs, fs = _shard(x, factors, mode_axes, mesh)
    problem = Problem.from_tensor(x, 5, mode_axes=mode_axes, mesh=mesh)
    w = jnp.ones((5,), x.dtype)
    norm_x = tensor_norm(x)

    # the planner enumerates trees and may pair a dimtree schedule with any
    # executor; the restriction is gone
    assert sum(not s.is_flat for s in enumerate_schedules(problem)) >= 3
    assert select_executor(problem, "dimtree") in ("overlapping", "compressed")
    plan = plan_sweep(problem, strategy="dimtree", executor="overlapping")
    assert plan.executor == "overlapping" and plan.kind == "dimtree"

    # dimtree sweeps: overlapped == sharded bitwise, across several sweeps
    f_sh, f_ov = list(fs), list(fs)
    w_sh = w_ov = w
    for it in range(3):
        st_sh = SweepState(x=xs, factors=f_sh, weights=w_sh, norm_x=norm_x, it=jnp.asarray(it))
        st_ov = SweepState(x=xs, factors=f_ov, weights=w_ov, norm_x=norm_x, it=jnp.asarray(it))
        out_sh = als_sweep(problem, plan, ShardedExecutor(mesh, mode_axes), st_sh)
        out_ov = als_sweep(problem, plan, OverlappingExecutor(mesh, mode_axes, n_chunks=3), st_ov)
        f_sh, w_sh = out_sh.factors, out_sh.weights
        f_ov, w_ov = out_ov.factors, out_ov.weights
        for a, b in zip(f_sh, f_ov):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(out_sh.fit), np.asarray(out_ov.fit))

    # chain schedule on the overlapping executor matches sharded numerics
    # (its root leaf is a chunked full MTTKRP: equal reductions, fp-tight)
    chain_plan = plan_sweep(problem, schedule="chain", executor="overlapping")
    st_a = SweepState(x=xs, factors=list(fs), weights=w, norm_x=norm_x, it=jnp.asarray(0))
    st_b = SweepState(x=xs, factors=list(fs), weights=w, norm_x=norm_x, it=jnp.asarray(0))
    out_a = als_sweep(problem, chain_plan, ShardedExecutor(mesh, mode_axes), st_a)
    out_b = als_sweep(problem, chain_plan, OverlappingExecutor(mesh, mode_axes, n_chunks=3), st_b)
    for a, b in zip(out_a.factors, out_b.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)

    # compressed executor on the dimtree schedule: per-node residual carry
    # threads through the sweep and converges to the exact fit
    plan_c = plan_sweep(problem, strategy="dimtree", executor="compressed")
    ex_c = make_executor("compressed", mesh, mode_axes)
    assert isinstance(ex_c, CompressedShardedExecutor)
    carry = ex_c.init_carry(plan_c, xs, fs)
    assert carry  # at least one node needs a psum on this mapping
    st_c = SweepState(x=xs, factors=list(fs), weights=w, norm_x=norm_x, it=jnp.asarray(0), carry=carry)
    out_c = als_sweep(problem, plan_c, ex_c, st_c)
    assert out_c.carry is not carry  # residuals were updated
    for a, b in zip(out_c.factors, f_sh):
        assert np.all(np.isfinite(np.asarray(a)))
        assert np.asarray(a).shape == np.asarray(b).shape
    print("schedule_overlap OK")


def case_compressed_cpals():
    """Error-feedback convergence: CP-ALS with the compressed factor
    all-reduce reaches the uncompressed fit within tolerance on a fixed
    iteration budget (seeded planted problem)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    planted = random_factors(jax.random.PRNGKey(7), (8, 8, 8), 3)
    x = cp_full(None, planted)
    mode_axes = {0: "data", 1: "model"}
    budget = 40
    f_e, w_e, fit_exact = dist_cp_als(
        x, rank=3, mode_axes=mode_axes, mesh=mesh, n_iters=budget, tol=1e-9,
        executor="sharded",
    )
    f_c, w_c, fit_comp = dist_cp_als(
        x, rank=3, mode_axes=mode_axes, mesh=mesh, n_iters=budget, tol=1e-9,
        executor="compressed",
    )
    assert float(fit_comp) > 0.75, float(fit_comp)
    assert abs(float(fit_comp) - float(fit_exact)) < 2e-2, (
        float(fit_comp), float(fit_exact),
    )
    # selection surface: a few-participant, collective-bound problem picks
    # compressed; this planted shape keeps an exact executor
    from repro.plan import Problem, select_executor

    p2 = Problem(shape=(2, 64, 2), rank=4096, mode_axes={0: "data"}, axis_sizes={"data": 2})
    assert select_executor(p2) == "compressed", select_executor(p2)
    print("compressed_cpals OK", float(fit_comp), float(fit_exact))


def case_compressed_psum():
    mesh = jax.make_mesh((8,), ("data",))
    from jax import shard_map

    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 7.0

    def f(x_blk, err):
        s, ne = compressed_psum(x_blk[0], "data", err[0])
        return s[None], ne[None]

    from jax.sharding import PartitionSpec as P

    err0 = jnp.zeros((8, 8), jnp.float32)
    s, ne = shard_map(
        f, mesh=mesh, in_specs=(P("data"), P("data")), out_specs=(P("data"), P("data")),
        check_vma=False,
    )(x, err0)
    exact = jnp.sum(x, 0)
    # every replica row should approximate the exact sum within int8 step
    scale = float(jnp.max(jnp.abs(x))) / 127.0 * 8
    for r in range(8):
        np.testing.assert_allclose(np.asarray(s[r]), np.asarray(exact), atol=scale + 1e-5)
    # error feedback: residuals bounded by one quantization step
    assert float(jnp.max(jnp.abs(ne))) <= float(jnp.max(jnp.abs(x))) / 127.0 + 1e-6
    print("compressed_psum OK")


def case_compressed_dp_trainer():
    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.models import build_model
    from repro.train.optimizer import OptConfig, init_opt_state

    mesh = jax.make_mesh((8, 1), ("data", "model"))
    cfg = get_config("olmo-1b").reduced()
    model = build_model(cfg)
    with meshlib.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        data = SyntheticLM(DataConfig(vocab=32, seq_len=16, global_batch=8))
        opt_cfg = OptConfig(lr=3e-3, warmup_steps=0, total_steps=100)
        step_c = jax.jit(make_compressed_dp_step(model, opt_cfg, mesh, compress=True))
        step_e = jax.jit(make_compressed_dp_step(model, opt_cfg, mesh, compress=False))
        pc = pe = params
        oc = oe = init_opt_state(params)
        err = init_error_state(params)
        for i in range(8):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            pc, oc, err, mc = step_c(pc, oc, err, batch)
            pe, oe, _, me = step_e(pe, oe, jax.tree.map(jnp.zeros_like, err), batch)
        lc, le = float(mc["loss"]), float(me["loss"])
        assert np.isfinite(lc) and np.isfinite(le)
        assert abs(lc - le) < 0.3, (lc, le)  # compressed tracks exact closely
    print("compressed_dp OK", lc, le)


def case_pp_sharded():
    """Sharded pairwise perturbation == local pairwise perturbation.

    Covers ``dist_pp_pairs`` (pair build inside shard_map with the minimal
    psum, rank-major layout) and the PP correction sweeps running through
    the sharded executor end to end: same pair tensors, same exact-sweep
    cadence, allclose factors."""
    from repro.plan import LocalExecutor, Problem, make_executor, plan_sweep
    from repro.plan import cp_als as plan_cp_als

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    mode_axes = {0: "data", 1: "model"}
    shape, rank = (12, 8, 8), 3
    planted = random_factors(jax.random.PRNGKey(7), shape, rank)
    x = cp_full(None, planted) + 1e-3 * random_tensor(jax.random.PRNGKey(8), shape)
    init = random_factors(jax.random.PRNGKey(9), shape, rank)

    prob_lo = Problem(shape=shape, rank=rank, pp_tol=0.05)
    prob_sh = Problem(
        shape=shape, rank=rank, pp_tol=0.05,
        mode_axes=mode_axes, axis_sizes={"data": 2, "model": 4},
    )
    ex = make_executor("sharded", mesh, mode_axes)

    # the pair cache itself: dist build == local build, pair by pair
    pairs_lo = LocalExecutor().pp_pairs(prob_lo, x, list(init))
    pairs_sh = ex.pp_pairs(prob_sh, x, list(init))
    assert set(pairs_lo) == set(pairs_sh), (set(pairs_lo), set(pairs_sh))
    for k in pairs_lo:
        np.testing.assert_allclose(
            np.asarray(pairs_sh[k]), np.asarray(pairs_lo[k]),
            rtol=5e-4, atol=5e-5, err_msg=f"pair {k}",
        )

    st_lo = plan_cp_als(
        x, plan_sweep(prob_lo, strategy="pp"),
        n_iters=10, tol=0.0, init_factors=list(init),
    )
    st_sh = plan_cp_als(
        x, plan_sweep(prob_sh, strategy="pp"), executor=ex,
        n_iters=10, tol=0.0, init_factors=list(init),
    )
    assert st_sh.pp_exact_sweeps == st_lo.pp_exact_sweeps, (
        st_sh.pp_exact_sweeps, st_lo.pp_exact_sweeps,
    )
    for a, b in zip(st_sh.factors, st_lo.factors):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4
        )
    np.testing.assert_allclose(float(st_sh.fit), float(st_lo.fit), atol=1e-4)
    print("pp_sharded OK exact_sweeps=", int(st_sh.pp_exact_sweeps))


def case_hierarchical_psum():
    """Hierarchical two-level collectives == flat psum on a 2x4 node mesh.

    ``hierarchical_psum`` (reduce-scatter within the node + cross-node psum
    of the shard + all-gather back) is an exact regrouping of the same sum,
    so every exact path -- the raw collective, ``dist_mttkrp``, and the
    overlapped variant -- must match its flat twin allclose; the compressed
    variant keeps its error-feedback carry semantics (residual shape and
    bound) while compressing only the cross-node stage.  Ends with the
    acceptance sweep: a ``plan_sweep(executor="auto")`` plan over the
    two-level problem executes hierarchical node collectives and matches
    the flat-psum plan's factors allclose.
    """
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import hierarchical_psum
    from repro.dist.dist_mttkrp import (
        dist_mttkrp_compressed,
        dist_mttkrp_overlapped,
        init_mttkrp_error_state,
    )
    from repro.launch.mesh import make_node_mesh
    from repro.plan import Problem, SweepState, als_sweep, make_executor, plan_sweep

    assert jax.device_count() == 8, jax.device_count()
    mesh = make_node_mesh(2, 4)  # ("node", "device"): 2 nodes x 4 devices
    mode_axes = {0: "node", 2: "device"}

    # raw collective: hierarchical == flat psum, elementwise, every replica
    v = jnp.arange(8 * 12, dtype=jnp.float32).reshape(8, 12) / 7.0

    def f(blk):
        blk = blk[0]
        flat = jax.lax.psum(blk, ("node", "device"))
        hier = hierarchical_psum(blk, ("node", "device"), mesh, node_axis="device")
        return flat[None], hier[None]

    flat, hier = shard_map(
        f, mesh=mesh, in_specs=P(("node", "device")),
        out_specs=(P(("node", "device")), P(("node", "device"))),
        check_vma=False,
    )(v)
    np.testing.assert_allclose(np.asarray(hier), np.asarray(flat), rtol=1e-6, atol=1e-6)

    x = random_tensor(jax.random.PRNGKey(0), (8, 6, 4, 5))
    factors = random_factors(jax.random.PRNGKey(1), x.shape, 7)
    xs, fs = shard_problem(x, factors, mode_axes, mesh)
    for n in range(4):
        ref = dist_mttkrp(xs, fs, n, mode_axes, mesh)
        out = dist_mttkrp(
            xs, fs, n, mode_axes, mesh, collective="hierarchical", node_axis="device"
        )
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6, err_msg=f"mode {n}"
        )
        ov = dist_mttkrp_overlapped(
            xs, fs, n, mode_axes, mesh, n_chunks=2,
            collective="hierarchical", node_axis="device",
        )
        np.testing.assert_allclose(
            np.asarray(ov), np.asarray(ref), rtol=1e-5, atol=1e-6, err_msg=f"ov mode {n}"
        )

    # compressed + hierarchical: intra-node stage exact, cross-node stage
    # int8 error-feedback -- output within one quantization step of exact,
    # residual carry keeps its shape and stays bounded across a second call
    n = 1
    err = init_mttkrp_error_state(x.shape, 7, mode_axes, mesh)[n]
    exact = dist_mttkrp(xs, fs, n, mode_axes, mesh)
    out_c, err1 = dist_mttkrp_compressed(
        xs, fs, n, mode_axes, mesh, err,
        collective="hierarchical", node_axis="device",
    )
    assert err1.shape == err.shape, (err1.shape, err.shape)
    scale = float(jnp.max(jnp.abs(exact))) / 127.0 * 8
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(exact), atol=scale + 1e-5)
    out_c2, err2 = dist_mttkrp_compressed(
        xs, fs, n, mode_axes, mesh, err1,
        collective="hierarchical", node_axis="device",
    )
    assert err2.shape == err.shape
    # error feedback: second pass corrects toward exact, residual bounded
    np.testing.assert_allclose(np.asarray(out_c2), np.asarray(exact), atol=scale + 1e-5)
    assert float(jnp.max(jnp.abs(err2))) <= 2.1 * float(jnp.max(jnp.abs(exact))) / 127.0 + 1e-6

    # acceptance sweep: auto plan on the two-level problem (hierarchical
    # node collectives) == the same plan forced flat, allclose factors
    problem = Problem.from_tensor(
        x, 7, mode_axes=mode_axes, mesh=mesh, intra_axes=("device",)
    )
    plan = plan_sweep(problem, executor="auto")
    assert any(np_.collective == "hierarchical" for np_ in plan.nodes), [
        np_.collective for np_ in plan.nodes
    ]
    assert plan.lower_bound_bytes is not None and plan.lower_bound_bytes > 0
    from repro.core.tensor_ops import tensor_norm

    flat_prob = Problem.from_tensor(x, 7, mode_axes=mode_axes, mesh=mesh)
    flat_plan = plan_sweep(
        flat_prob, executor=plan.executor, schedule=plan.resolved_schedule.name
    )
    assert all(np_.collective == "flat" for np_ in flat_plan.nodes)
    w = jnp.ones((7,), x.dtype)
    norm_x = tensor_norm(x)
    ex_h = make_executor(
        plan.executor, mesh, mode_axes, node_axis=problem.node_axis
    )
    ex_f = make_executor(flat_plan.executor, mesh, mode_axes)
    f_h, f_f = list(fs), list(fs)
    w_h = w_f = w
    for it in range(3):
        st_h = SweepState(x=xs, factors=f_h, weights=w_h, norm_x=norm_x, it=jnp.asarray(it))
        st_f = SweepState(x=xs, factors=f_f, weights=w_f, norm_x=norm_x, it=jnp.asarray(it))
        out_h = als_sweep(problem, plan, ex_h, st_h)
        out_f = als_sweep(flat_prob, flat_plan, ex_f, st_f)
        f_h, w_h = out_h.factors, out_h.weights
        f_f, w_f = out_f.factors, out_f.weights
        for a, b in zip(f_h, f_f):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(out_h.fit), float(out_f.fit), atol=1e-5)
    print("hierarchical_psum OK")


if __name__ == "__main__":
    {
        "dist_mttkrp": case_dist_mttkrp,
        "matrix_free_sharded": case_matrix_free_sharded,
        "dist_cpals": case_dist_cpals,
        "dist_dimtree": case_dist_dimtree,
        "elastic_restore": case_elastic_restore,
        "overlap_mttkrp": case_overlap_mttkrp,
        "schedule_overlap": case_schedule_overlap,
        "compressed_cpals": case_compressed_cpals,
        "compressed_psum": case_compressed_psum,
        "compressed_dp": case_compressed_dp_trainer,
        "pp_sharded": case_pp_sharded,
        "hierarchical_psum": case_hierarchical_psum,
    }[sys.argv[1]]()
