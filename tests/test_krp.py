"""Unit + property tests for the Khatri-Rao product algorithms (Alg. 1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import krp, krp_naive, krp_or_ones, krp_row_block, krp_rowwise_scan

jax.config.update("jax_enable_x64", False)


def np_krp(mats):
    """Oracle: row-wise definition, first factor slowest (paper convention)."""
    mats = [np.asarray(m) for m in mats]
    dims = [m.shape[0] for m in mats]
    c = mats[0].shape[1]
    out = np.empty((int(np.prod(dims)), c), mats[0].dtype)
    for j in range(out.shape[0]):
        idx = np.unravel_index(j, dims)
        row = np.ones((c,), mats[0].dtype)
        for m, i in zip(mats, idx):
            row = row * m[i]
        out[j] = row
    return out


def _mats(key, dims, c, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims))
    return [jax.random.normal(k, (d, c), dtype) for k, d in zip(keys, dims)]


@pytest.mark.parametrize("dims", [(3, 4), (2, 3, 4), (3, 2, 2, 3), (5, 1, 4)])
@pytest.mark.parametrize("c", [1, 7, 25])
def test_krp_matches_oracle(dims, c):
    mats = _mats(jax.random.PRNGKey(0), dims, c)
    np.testing.assert_allclose(np.asarray(krp(mats)), np_krp(mats), rtol=1e-6)


@pytest.mark.parametrize("dims", [(2, 3, 4), (3, 3, 3, 2)])
def test_krp_variants_agree(dims):
    mats = _mats(jax.random.PRNGKey(1), dims, 5)
    ref = np.asarray(krp(mats))
    np.testing.assert_allclose(np.asarray(krp_naive(mats)), ref, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(krp_rowwise_scan(mats)), ref, rtol=1e-5)


def test_krp_column_kron_identity():
    """Column c of the KRP is the Kronecker product of the factor columns."""
    mats = _mats(jax.random.PRNGKey(2), (3, 4, 2), 3)
    k = np.asarray(krp(mats))
    for c in range(3):
        kron = np.asarray(mats[0])[:, c]
        for m in mats[1:]:
            kron = np.kron(kron, np.asarray(m)[:, c])
        np.testing.assert_allclose(k[:, c], kron, rtol=1e-6)


def test_krp_empty_is_ones():
    out = krp_or_ones([], 4)
    np.testing.assert_array_equal(np.asarray(out), np.ones((1, 4), np.float32))


@pytest.mark.parametrize("start,length", [(0, 6), (5, 7), (17, 7), (23, 1)])
def test_krp_row_block(start, length):
    mats = _mats(jax.random.PRNGKey(3), (2, 3, 4), 6)
    full = np.asarray(krp(mats))
    blk = np.asarray(krp_row_block(mats, start, length))
    np.testing.assert_allclose(blk, full[start : start + length], rtol=1e-6)


def test_krp_row_blocks_tile_the_output():
    """Parallel decomposition (Sec. 4.1.2): contiguous blocks tile the rows."""
    mats = _mats(jax.random.PRNGKey(4), (3, 4, 5), 4)
    full = np.asarray(krp(mats))
    t = 4
    rows = full.shape[0]
    b = -(-rows // t)
    parts = [
        np.asarray(krp_row_block(mats, i * b, min(b, rows - i * b)))
        for i in range(t)
        if i * b < rows
    ]
    np.testing.assert_allclose(np.concatenate(parts, 0), full, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    dims=st.lists(st.integers(1, 5), min_size=2, max_size=4),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_krp_property_reuse_equals_naive(dims, c, seed):
    mats = _mats(jax.random.PRNGKey(seed), tuple(dims), c)
    np.testing.assert_allclose(
        np.asarray(krp(mats)), np.asarray(krp_naive(mats)), rtol=2e-5, atol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4), min_size=2, max_size=4),
    seed=st.integers(0, 2**31 - 1),
)
def test_krp_property_shapes_and_finite(dims, seed):
    mats = _mats(jax.random.PRNGKey(seed), tuple(dims), 3)
    out = krp(mats)
    assert out.shape == (int(np.prod(dims)), 3)
    assert bool(jnp.all(jnp.isfinite(out)))
