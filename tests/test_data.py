"""Data pipeline tests: memmap corpus, batch shapes, resume semantics."""

import numpy as np
import pytest

from repro.data.pipeline import DataConfig, MemmapCorpus, Prefetcher, SyntheticLM


def _write_corpus(tmp_path, n=4096, vocab=211, dtype=np.uint16):
    path = tmp_path / "corpus.bin"
    rng = np.random.default_rng(0)
    data = rng.integers(0, vocab, size=n, dtype=dtype)
    data.tofile(path)
    return str(path)


def test_memmap_corpus_batches(tmp_path):
    path = _write_corpus(tmp_path)
    cfg = DataConfig(vocab=211, seq_len=32, global_batch=4, seed=1)
    corpus = MemmapCorpus(path, cfg)
    b = corpus.batch(0)
    assert b["tokens"].shape == (4, 33)
    assert b["tokens"].dtype == np.int32
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 211).all()


def test_memmap_corpus_deterministic_resume(tmp_path):
    path = _write_corpus(tmp_path)
    cfg = DataConfig(vocab=211, seq_len=16, global_batch=2, seed=7)
    a = MemmapCorpus(path, cfg).batch(5)["tokens"]
    b = MemmapCorpus(path, cfg).batch(5)["tokens"]  # fresh instance, same index
    np.testing.assert_array_equal(a, b)
    c = MemmapCorpus(path, cfg).batch(6)["tokens"]
    assert not np.array_equal(a, c)


def test_memmap_corpus_host_sharding(tmp_path):
    path = _write_corpus(tmp_path)
    full = MemmapCorpus(path, DataConfig(211, 16, 8, seed=3)).batch(2)["tokens"]
    parts = [
        MemmapCorpus(path, DataConfig(211, 16, 8, seed=3, host_id=h, host_count=2)).batch(2)["tokens"]
        for h in range(2)
    ]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_memmap_corpus_too_short_raises(tmp_path):
    path = _write_corpus(tmp_path, n=8)
    with pytest.raises(ValueError):
        MemmapCorpus(path, DataConfig(vocab=211, seq_len=32, global_batch=1))


def test_synthetic_tokens_in_range():
    cfg = DataConfig(vocab=64, seq_len=20, global_batch=4, seed=2)
    b = SyntheticLM(cfg).batch(0)["tokens"]
    assert b.shape == (4, 21)
    assert (b >= 0).all() and (b < 64).all()


def test_prefetcher_with_memmap(tmp_path):
    path = _write_corpus(tmp_path)
    corpus = MemmapCorpus(path, DataConfig(211, 16, 2, seed=4))
    pf = Prefetcher(corpus, start=0, depth=2)
    try:
        idx, batch = next(pf)
        assert idx == 0 and batch["tokens"].shape == (2, 17)
    finally:
        pf.close()
