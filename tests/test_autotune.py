"""The measured-cost loop: TuningCache persistence/keying, tuned-tile
numerics, plan_sweep(strategy="autotune") stamping + analytic fallback, and
the sync-free cp_als chunked driver (bitwise iterates, one sync per chunk)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cp_full, random_factors, random_tensor
from repro.kernels import ops, ref
from repro.plan import (
    Problem,
    TuningCache,
    cp_als,
    lookup_measurements,
    plan_sweep,
    tune,
)
from repro.plan.autotune import node_key, problem_key

SHAPE, RANK = (8, 6, 4), 3


def _problem_arrays(shape=SHAPE, rank=RANK, seed=0):
    x = random_tensor(jax.random.PRNGKey(seed), shape)
    factors = random_factors(jax.random.PRNGKey(seed + 1), shape, rank)
    return x, factors


@pytest.fixture(scope="module")
def tuned_cache(tmp_path_factory):
    """One disk-backed cache tuned on the module's local problem (tuning
    compiles dozens of kernels; share it across the tests that read it)."""
    path = tmp_path_factory.mktemp("tuning") / "cache.json"
    x, factors = _problem_arrays()
    cache = TuningCache(path)
    entry = tune(x, RANK, factors=factors, cache=cache, budget_ms=None, reps=1)
    return path, cache, entry


# ------------------------------------------------------------------- cache
def test_tuning_cache_disk_roundtrip(tmp_path):
    path = tmp_path / "cache.json"
    c = TuningCache(path)
    assert c.get("k") is None
    c.put("k", {"nodes": [], "tiles": {"fused_mttkrp": {"block_i": 64}}})
    # a fresh cache object sees what the first one persisted
    c2 = TuningCache(path)
    assert c2.get("k")["tiles"]["fused_mttkrp"]["block_i"] == 64
    assert c2.keys() == ["k"]
    # memory-only caches never touch disk
    mem = TuningCache()
    mem.put("m", {"x": 1})
    assert mem.path is None and mem.get("m") == {"x": 1}


def test_problem_key_separates_backend_shape_dtype_devices():
    base = Problem(shape=SHAPE, rank=RANK)
    keys = {
        problem_key(base),
        problem_key(Problem(shape=SHAPE, rank=RANK, dtype=jnp.bfloat16)),
        problem_key(Problem(shape=SHAPE, rank=RANK + 1)),
        problem_key(Problem(shape=(8, 6, 8), rank=RANK)),
        problem_key(
            Problem(
                shape=SHAPE, rank=RANK, mode_axes={0: "d"}, axis_sizes={"d": 2}
            )
        ),
        problem_key(base, backend="tpu"),
    }
    assert len(keys) == 6  # every dimension of the key separates entries
    # a cache entry under a different dtype must not leak into lookups
    cache = TuningCache()
    cache.put(
        problem_key(Problem(shape=SHAPE, rank=RANK, dtype=jnp.bfloat16)),
        {"nodes": [{"key": "x", "measured_s": 1.0}]},
    )
    assert lookup_measurements(base, cache) is None


def test_lookup_resolves_entry_fields(tuned_cache):
    path, cache, entry = tuned_cache
    problem = Problem(shape=SHAPE, rank=RANK)
    m = lookup_measurements(problem, cache)
    assert m is not None
    assert set(m.tiles) == {"fused_mttkrp", "matrix_free", "multi_ttv"}
    assert set(m.kernel_tiles("fused_mttkrp")) == {
        "block_i", "block_b", "block_batch",
    }
    assert set(m.kernel_tiles("matrix_free")) == {
        "block_i", "block_r", "block_batch",
    }
    # every stored node row resolves through the node_s map
    assert len(m.node_s) == len(entry["nodes"]) > 0
    # and the same measurements come back through a fresh disk read
    m2 = lookup_measurements(problem, TuningCache(path))
    assert dict(m2.node_s) == dict(m.node_s)


# ---------------------------------------------------------- tuned numerics
def test_tuned_tiles_numerics_identical_to_defaults(tuned_cache):
    """Tile sizes change only the blocking, never the math: tuned configs
    must reproduce the default-tile results at HIGHEST matmul precision."""
    _, cache, entry = tuned_cache
    x, factors = _problem_arrays()
    tiles = entry["tiles"]["fused_mttkrp"]
    for n in range(len(SHAPE)):
        tuned = np.asarray(
            ops.fused_mttkrp(
                x, factors, n, block_i=tiles["block_i"], block_b=tiles["block_b"]
            )
        )
        default = np.asarray(ops.fused_mttkrp(x, factors, n))
        np.testing.assert_allclose(tuned, default, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            tuned, np.asarray(ref.fused_mttkrp_ref(x, factors, n)),
            rtol=1e-4, atol=1e-4,
        )
    # multi-TTV tile likewise
    bi = entry["tiles"]["multi_ttv"]["block_i"]
    t = jax.random.normal(jax.random.PRNGKey(5), (6, 32, 4))
    w = jax.random.normal(jax.random.PRNGKey(6), (6, 4))
    np.testing.assert_allclose(
        np.asarray(ops.multi_ttv(t, w, block_i=bi)),
        np.asarray(ops.multi_ttv(t, w)),
        rtol=1e-6, atol=1e-6,
    )


def test_planned_tiles_execute_through_the_engine(tuned_cache):
    """A tuned plan that picked the fused kernel carries its tiles and still
    produces reference ALS iterates through cp_als."""
    _, cache, _ = tuned_cache
    x, _ = _problem_arrays()
    plan = plan_sweep(
        Problem.from_tensor(x, RANK), strategy="autotune", tuning_cache=cache
    )
    st = cp_als(x, plan, n_iters=3, track_fit=False, seed=2)
    ref_plan = plan_sweep(Problem.from_tensor(x, RANK), schedule="flat")
    st_ref = cp_als(x, ref_plan, n_iters=3, track_fit=False, seed=2)
    for a, b in zip(st.factors, st_ref.factors):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-3, atol=1e-3
        )


# ------------------------------------------------------- planner semantics
def test_autotune_falls_back_to_analytic_on_empty_cache():
    """CI default: no measurements -> autotune IS auto (plus the strategy
    stamp), with no measured_s anywhere."""
    problem = Problem(shape=(8, 6, 4, 4), rank=3)
    auto = plan_sweep(problem)
    cold = plan_sweep(problem, strategy="autotune", tuning_cache=TuningCache())
    assert cold.strategy == "autotune"
    assert cold.resolved_schedule.name == auto.resolved_schedule.name
    assert cold.executor == auto.executor
    assert [n.algorithm for n in cold.nodes] == [n.algorithm for n in auto.nodes]
    assert all(n.cost.measured_s is None for n in cold.nodes)
    assert all(n.tiles is None for n in cold.nodes)
    for got, want in zip(cold.nodes, auto.nodes):
        assert got.cost.predicted_s == want.cost.predicted_s
        assert got.cost.expected_s == got.cost.predicted_s


def test_autotune_stamps_measured_node_times(tuned_cache):
    """Acceptance: the autotune plan's describe() carries the hardware
    measurement of every node the tuner covered."""
    path, cache, entry = tuned_cache
    problem = Problem(shape=SHAPE, rank=RANK)
    plan = plan_sweep(problem, strategy="autotune", tuning_cache=cache)
    d = plan.describe()
    assert d["strategy"] == "autotune"
    stamped = [n for n in d["nodes"] if n["measured_s"] is not None]
    assert len(stamped) == len(d["nodes"]) > 0  # full coverage on this problem
    for n in stamped:
        assert n["expected_s"] == n["measured_s"] > 0.0
        assert n["predicted_s"] != n["measured_s"]  # analytic kept alongside
    # the argmin ran over the measurements: the chosen leaf algorithms are
    # the measured-fastest candidates recorded by the tuner
    by_key = {r["key"]: r["measured_s"] for r in entry["nodes"]}
    for np_ in plan.nodes:
        if not (np_.node.from_root and np_.node.is_leaf):
            continue
        mine = by_key[node_key(np_.node, np_.algorithm, plan.executor)]
        topo = node_key(np_.node, np_.algorithm, plan.executor).split("|", 2)[2]
        rivals = [
            s
            for k, s in by_key.items()
            if k.startswith(f"{plan.executor}|") and k.split("|", 2)[2] == topo
        ]
        assert mine == min(rivals)
    # tuned tiles ride the plan when the fused kernel won a leaf
    for np_ in plan.nodes:
        if np_.algorithm == "fused":
            assert np_.tiles == {
                k: entry["tiles"]["fused_mttkrp"][k]
                for k in ("block_i", "block_b", "block_batch")
                if k in entry["tiles"]["fused_mttkrp"]
            }


def test_autotune_recalibrates_serial_fractions_from_cache():
    """Cached serial_fractions flow into the plan (explicit ones win)."""
    problem = Problem(
        shape=(8, 16, 16), rank=5,
        mode_axes={0: "data"}, axis_sizes={"data": 2},
    )
    cache = TuningCache()
    cache.put(
        problem_key(problem),
        {"nodes": [], "tiles": {}, "serial_fractions": {"sharded": 1.0, "overlapping": 0.5}},
    )
    plan = plan_sweep(problem, strategy="autotune", tuning_cache=cache)
    assert dict(plan.serial_fractions) == {"sharded": 1.0, "overlapping": 0.5}
    forced = plan_sweep(
        problem, strategy="autotune", tuning_cache=cache,
        serial_fractions={"overlapping": 0.25},
    )
    assert dict(forced.serial_fractions) == {"overlapping": 0.25}


# ------------------------------------------------- sync-free chunked driver
def _planted(shape=(10, 8, 6), rank=2, seed=4):
    planted = random_factors(jax.random.PRNGKey(seed), shape, rank)
    return cp_full(None, planted), rank


def test_sweeps_per_sync_bitwise_identical_iterates():
    """Acceptance: k sweeps per dispatch reproduce the per-sweep iterates
    bitwise -- factors, weights and fit -- for even and ragged chunkings."""
    x, rank = _planted()
    plan = plan_sweep(Problem.from_tensor(x, rank))
    base = cp_als(x, plan, n_iters=6, track_fit=False, seed=7)
    for k in (2, 3, 4):  # 4 exercises the ragged 4+2 remainder chunk
        chunked = cp_als(
            x, plan, n_iters=6, track_fit=False, seed=7, sweeps_per_sync=k
        )
        assert chunked.it == base.it == 6
        for a, b in zip(base.factors, chunked.factors):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(base.weights), np.asarray(chunked.weights)
        )
        assert float(base.fit) == float(chunked.fit)


def test_sweeps_per_sync_one_host_sync_per_chunk(monkeypatch):
    """Acceptance: the driver blocks on the host exactly once per chunk of
    k sweeps (counted at the module's single sync point)."""
    import repro.plan.sweep as sweeplib

    x, rank = _planted()
    plan = plan_sweep(Problem.from_tensor(x, rank))
    counts = {"n": 0}
    real = jax.block_until_ready

    def counting(tree):
        counts["n"] += 1
        return real(tree)

    monkeypatch.setattr(sweeplib, "_block_until_ready", counting)
    cp_als(x, plan, n_iters=6, track_fit=False, seed=7)
    assert counts["n"] == 6  # k=1: one sync per sweep
    counts["n"] = 0
    cp_als(x, plan, n_iters=6, track_fit=False, seed=7, sweeps_per_sync=3)
    assert counts["n"] == 2  # two chunks of 3
    counts["n"] = 0
    cp_als(x, plan, n_iters=6, track_fit=False, seed=7, sweeps_per_sync=4)
    assert counts["n"] == 2  # ragged 4 + 2
    with pytest.raises(ValueError, match="sweeps_per_sync"):
        cp_als(x, plan, sweeps_per_sync=0)


def test_sweeps_per_sync_callback_and_convergence():
    """The callback fires once per executed sweep with in-order fits, and
    convergence still stops the loop (at a chunk boundary)."""
    x, rank = _planted()
    plan = plan_sweep(Problem.from_tensor(x, rank))
    fits1, fits3 = [], []
    st1 = cp_als(x, plan, n_iters=40, tol=1e-9, seed=5,
                 callback=lambda it, fit, dt: fits1.append((it, fit)))
    st3 = cp_als(x, plan, n_iters=40, tol=1e-9, seed=5, sweeps_per_sync=3,
                 callback=lambda it, fit, dt: fits3.append((it, fit)))
    assert len(fits1) == st1.it and len(fits3) == st3.it
    assert [it for it, _ in fits3] == list(range(st3.it))
    # chunked runs stop at the chunk containing the k=1 stopping sweep
    assert st1.it <= st3.it <= st1.it + 2
    assert float(st3.fit) > 0.99
    # identical per-sweep fits wherever both executed
    for (i1, f1), (i3, f3) in zip(fits1, fits3):
        assert i1 == i3 and f1 == f3


# ------------------------------------------------------------- gram carry
def test_grams_carried_across_sweeps_match_recompute():
    """SweepState.grams threading is exact: a sweep fed the previous sweep's
    Grams produces bitwise the state of one fed nothing (which recomputes),
    and the emitted Grams always equal grams(out.factors)."""
    from repro.core.cpals import grams
    from repro.core import tensor_norm
    from repro.plan import LocalExecutor, SweepState, als_sweep

    x, factors = _problem_arrays(seed=9)
    problem = Problem.from_tensor(x, RANK)
    plan = plan_sweep(problem)
    w = jnp.ones((RANK,), x.dtype)
    state = SweepState(
        x=x, factors=list(factors), weights=w,
        norm_x=tensor_norm(x), it=jnp.asarray(0),
    )
    out1 = als_sweep(problem, plan, LocalExecutor(), state)
    assert out1.grams is not None
    for g, u in zip(out1.grams, out1.factors):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(u.T @ u))
    # second sweep: carried grams vs. recompute-from-factors
    carried = als_sweep(problem, plan, LocalExecutor(), out1)
    recomputed = als_sweep(
        problem, plan, LocalExecutor(),
        SweepState(
            x=x, factors=list(out1.factors), weights=out1.weights,
            norm_x=state.norm_x, it=jnp.asarray(1),
        ),
    )
    for a, b in zip(carried.factors, recomputed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(carried.fit), np.asarray(recomputed.fit)
    )


# ------------------------------------------------------------ jitted 2step
def test_mttkrp_2step_kernel_jitted_and_tile_threaded():
    """The 2-step kernel entry point is jit'd (static mode/tile/interpret)
    and its multi-TTV tile is tunable without changing results."""
    assert hasattr(ops.mttkrp_2step_kernel, "lower")  # a jit-wrapped callable
    x, factors = _problem_arrays(shape=(9, 14, 11), seed=11, rank=5)
    for n in range(3):
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        got = np.asarray(ops.mttkrp_2step_kernel(x, factors, n))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        tiled = np.asarray(ops.mttkrp_2step_kernel(x, factors, n, block_i=64))
        np.testing.assert_allclose(tiled, got, rtol=1e-6, atol=1e-6)
