"""Two-level collectives in the cost model + planner: level-split bytes,
the Ballard-Knight-Rouse communication lower bound, mesh-mapping
enumeration, and bandwidth-optimality certification.  Pure plan metadata --
no mesh or devices needed (the executed-path twin lives in
``dist_worker.case_hierarchical_psum``)."""

import math

import pytest

from repro.plan import (
    Problem,
    collective_level_bytes,
    hierarchical_applicable,
    mode_cost,
    mttkrp_comm_lower_bound,
    node_cost,
    plan_sweep,
    ring_allreduce_bytes,
)

# the CI mesh: 2 nodes x 4 devices, "device" is the fast intra-node axis
AXIS_SIZES = {"node": 2, "device": 4}
INTRA = ("device",)


def _problem(mode_axes, shape=(8, 6, 4, 5), rank=7, intra=INTRA):
    return Problem(
        shape=shape, rank=rank, mode_axes=mode_axes,
        axis_sizes=AXIS_SIZES, intra_axes=intra,
    )


# ------------------------------------------------------- level-split bytes
def test_flat_problem_level_split_matches_legacy_ring():
    """Problems without intra_axes price exactly the old flat ring -- the
    two-level split must be invisible to every existing plan."""
    prob = Problem(
        shape=(8, 6, 4, 5), rank=7, mode_axes={0: "node", 2: "device"},
        axis_sizes=AXIS_SIZES,
    )
    b = 1000.0
    coll, inter = collective_level_bytes(prob, b, ("node", "device"))
    assert coll == ring_allreduce_bytes(b, 8)
    assert inter == 0.0


def test_hierarchical_level_split_prices_shard_crossing():
    """Hierarchical: full ring within the node (k devices) + a 1/k-shard
    ring across nodes; only the shard ring crosses the slow level."""
    prob = _problem({0: "node", 2: "device"})
    b = 1000.0
    coll, inter = collective_level_bytes(
        prob, b, ("node", "device"), collective="hierarchical"
    )
    expect_inter = ring_allreduce_bytes(b / 4, 2)
    assert inter == pytest.approx(expect_inter)
    assert coll == pytest.approx(ring_allreduce_bytes(b, 4) + expect_inter)
    # flat on the same two-level problem: one ring over all 8, all of it
    # counted as crossing the slow level (the ring spans both)
    coll_f, inter_f = collective_level_bytes(prob, b, ("node", "device"))
    assert coll_f == ring_allreduce_bytes(b, 8)
    assert inter_f == coll_f
    assert inter < inter_f  # the whole point


def test_hierarchical_applicable_needs_both_levels():
    prob = _problem({0: "node", 2: "device"})
    assert hierarchical_applicable(prob, ("node", "device"))
    assert not hierarchical_applicable(prob, ("device",))  # intra only
    assert not hierarchical_applicable(prob, ("node",))  # inter only
    assert not hierarchical_applicable(prob, ())


def test_mode_cost_inter_bytes_never_exceed_collective_bytes():
    prob = _problem({0: "node", 2: "device"})
    for n in range(4):
        for coll in ("flat", "hierarchical"):
            c = mode_cost(prob, n, "1step", collective=coll)
            assert 0.0 <= c.inter_bytes <= c.collective_bytes + 1e-9
            assert c.intra_bytes == pytest.approx(c.collective_bytes - c.inter_bytes)


# ------------------------------------------------------------- lower bound
def test_lower_bound_is_grid_minimum():
    """The bound is the min over integer node grids of the per-grid volume
    -- recompute it by brute force and compare."""
    shape, rank, P = (8, 6, 4, 5), 7, 8
    s = 4.0

    def grid_volume(grid):
        return sum(
            2.0 * (shape[n] / grid[n]) * rank * s * (1.0 - grid[n] / P)
            for n in range(len(shape))
        )

    def grids(n_modes, p):
        if n_modes == 1:
            yield (p,)
            return
        for d in range(1, p + 1):
            if p % d == 0:
                for rest in grids(n_modes - 1, p // d):
                    yield (d,) + rest

    brute = min(grid_volume(g) for g in grids(4, P))
    bound = mttkrp_comm_lower_bound(shape, rank, P, itemsize=s)
    assert bound == pytest.approx(brute)
    # per_mode returns the achieving grid and its per-mode terms
    total, terms, grid = mttkrp_comm_lower_bound(
        shape, rank, P, itemsize=s, per_mode=True
    )
    assert total == pytest.approx(bound)
    assert sum(terms) == pytest.approx(total)
    assert math.prod(grid) == P


def test_lower_bound_trivial_cases():
    assert mttkrp_comm_lower_bound((8, 6, 4), 7, 1) == 0.0  # one node: no comm
    # tuple mesh shape == its product
    assert mttkrp_comm_lower_bound((8, 6, 4, 5), 7, (2, 4)) == pytest.approx(
        mttkrp_comm_lower_bound((8, 6, 4, 5), 7, 8)
    )


@pytest.mark.parametrize(
    "mode_axes",
    [{0: "node", 2: "device"}, {1: "node", 2: "device"}, {2: "node", 0: "device"}],
    ids=["0n2d", "1n2d", "2n0d"],
)
def test_bound_below_modeled_inter_volume_of_every_candidate(mode_axes):
    """The certification invariant: the BKR bound never exceeds the modeled
    per-node inter-node volume of ANY enumerated mapping (it is a lower
    bound on what the model prices, by construction of the grid minimum)."""
    plan = plan_sweep(_problem(mode_axes), executor="auto")
    d = plan.describe()
    assert d["lower_bound_bytes"] is not None and d["lower_bound_bytes"] > 0
    assert d["mappings"], "two-level problems must report mapping rows"
    for row in d["mappings"]:
        assert row["lower_bound_bytes"] == pytest.approx(d["lower_bound_bytes"])
        assert row["inter_bytes_per_node"] >= row["lower_bound_bytes"] - 1e-9


def test_certification_on_known_optimal_mapping():
    """{0: node, 2: device} on (8,6,4,5) achieves the bound exactly: the
    plan certifies immediately, without enumerating alternatives."""
    plan = plan_sweep(_problem({0: "node", 2: "device"}), executor="auto")
    d = plan.describe()
    assert d["certified"] is True
    assert plan.certified_bandwidth_optimal
    rows = d["mappings"]
    assert len(rows) == 1 and rows[0]["selected"] and rows[0]["certified"]
    assert rows[0]["inter_bytes_per_node"] == pytest.approx(d["lower_bound_bytes"])
    # per-leaf stamping: every leaf NodePlan carries its mode's bound term
    leaf_bounds = [
        np_.lower_bound_bytes for np_ in plan.nodes if np_.node.is_leaf
    ]
    assert all(b is not None for b in leaf_bounds)
    assert sum(leaf_bounds) == pytest.approx(d["lower_bound_bytes"])
    # and at least one node runs the hierarchical collective
    assert any(np_.collective == "hierarchical" for np_ in plan.nodes)


def test_enumeration_stops_early_at_certified_mapping():
    """A bad as-given mapping fails certification; the planner enumerates
    alternatives (>= 2 rows), finds one within epsilon of the bound, stops,
    and selects it."""
    plan = plan_sweep(_problem({2: "node", 0: "device"}), executor="auto")
    d = plan.describe()
    rows = d["mappings"]
    assert len(rows) >= 2, rows
    assert rows[0]["certified"] is False  # the as-given mapping
    assert d["certified"] is True
    winner = [r for r in rows if r["selected"]]
    assert len(winner) == 1 and winner[0]["certified"]
    assert winner[0]["inter_bytes_per_node"] < rows[0]["inter_bytes_per_node"]


def test_certify_eps_gates_enumeration():
    """An infinite epsilon certifies the as-given mapping outright (no
    enumeration); epsilon 0 demands the bound exactly."""
    prob = _problem({2: "node", 0: "device"})
    lax = plan_sweep(prob, executor="auto", certify_eps=1e9)
    assert lax.certified_bandwidth_optimal
    assert len(lax.mappings) == 1
    strict = plan_sweep(
        _problem({0: "node", 2: "device"}), executor="auto", certify_eps=0.0
    )
    assert strict.certified_bandwidth_optimal  # 420 == bound exactly


def test_single_level_problem_has_no_bound_or_mappings():
    """Problems without intra_axes keep the legacy describe surface: no
    bound, no mapping rows, never certified, all collectives flat."""
    prob = Problem(
        shape=(8, 6, 4, 5), rank=7, mode_axes={0: "node", 2: "device"},
        axis_sizes=AXIS_SIZES,
    )
    plan = plan_sweep(prob, executor="auto")
    d = plan.describe()
    assert d["lower_bound_bytes"] is None
    assert d["certified"] is False
    assert d["mappings"] == []
    assert all(np_.collective == "flat" for np_ in plan.nodes)


def test_describe_totals_split_levels():
    plan = plan_sweep(_problem({0: "node", 2: "device"}), executor="auto")
    d = plan.describe()
    tot = d["totals"]
    assert tot["inter_bytes"] <= tot["collective_bytes"] + 1e-9
    assert tot["intra_bytes"] + tot["inter_bytes"] == pytest.approx(
        tot["collective_bytes"]
    )
    for row in d["nodes"]:
        assert "collective" in row and "inter_bytes" in row


def test_node_cost_collective_choice_is_cheaper_or_equal():
    """On a DCN-dominated node the hierarchical decomposition never models
    slower than flat (same compute, strictly less slow-level traffic)."""
    prob = _problem({0: "node", 2: "device"})
    plan = plan_sweep(prob, executor="auto")
    for np_ in plan.nodes:
        if np_.collective != "hierarchical":
            continue
        flat = node_cost(
            prob, np_.node, plan.executor,
            **({"algorithm": np_.algorithm} if np_.node.is_leaf else {}),
        )
        assert np_.cost.predicted_s <= flat.predicted_s + 1e-12
