"""CP-factorized layer tests: fit-from-dense + end-to-end training."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.cp_layers import (
    compress_ffn,
    factorize_expert_stack,
    factorize_linear,
    reconstruction_error,
)
from repro.launch import mesh as meshlib
from repro.models import build_model


@pytest.fixture(scope="module")
def host_mesh():
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)) as m:
        yield m


def test_factorize_linear_recovers_lowrank():
    key = jax.random.PRNGKey(0)
    a0 = jax.random.normal(key, (24, 3))
    b0 = jax.random.normal(jax.random.PRNGKey(1), (3, 16))
    w = a0 @ b0
    a, b = factorize_linear(w, rank=3, n_iters=120)
    assert a.shape == (24, 3) and b.shape == (3, 16)
    assert reconstruction_error(w, a, b) < 1e-3


def test_factorize_expert_stack_3way():
    from repro.core import cp_full, random_factors

    planted = random_factors(jax.random.PRNGKey(2), (4, 12, 10), 2)
    w = cp_full(None, planted)
    e, a, b = factorize_expert_stack(w, rank=2, n_iters=150)
    approx = jnp.einsum("er,ir,or->eio", e, a, b)
    rel = float(jnp.linalg.norm((w - approx).ravel()) / jnp.linalg.norm(w.ravel()))
    assert rel < 1e-2, rel


def test_cp_rank_model_trains(host_mesh):
    """cfg.cp_rank switches the FFN to CP factors; training must work."""
    cfg = dataclasses.replace(get_config("olmo-1b").reduced(), cp_rank=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    layer0 = jax.tree.map(lambda x: x[0], params["layers"])
    assert "gate_a" in layer0["mlp"] and "gate" not in layer0["mlp"]

    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0, cfg.vocab, jnp.int32)
    loss, grads = jax.jit(
        lambda p: jax.value_and_grad(lambda q: model.loss_fn(q, {"tokens": tokens})[0])(p)
    )(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    # factorized FFN params are smaller than dense for this rank
    from repro.analysis.flops import _defs_count

    dense = _defs_count(build_model(get_config("olmo-1b").reduced()).param_defs)
    fact = _defs_count(model.param_defs)
    assert fact < dense


def test_compress_ffn_roundtrip():
    key = jax.random.PRNGKey(5)
    d, f, r = 16, 32, 4
    a_g = jax.random.normal(key, (d, r)) @ jax.random.normal(jax.random.PRNGKey(6), (r, f))
    dense = {
        "gate": a_g,
        "up": jax.random.normal(jax.random.PRNGKey(7), (d, r))
        @ jax.random.normal(jax.random.PRNGKey(8), (r, f)),
        "down": jax.random.normal(jax.random.PRNGKey(9), (f, r))
        @ jax.random.normal(jax.random.PRNGKey(10), (r, d)),
    }
    comp = compress_ffn(dense, rank=r)
    assert set(comp) == {"gate_a", "gate_b", "up_a", "up_b", "down_a", "down_b"}
    assert reconstruction_error(dense["gate"], comp["gate_a"], comp["gate_b"]) < 1e-2
