"""Dimension-tree CP-ALS: exact equivalence with the standard sweep."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cpals import als_sweep
from repro.core.dimtree import (
    dimtree_sweep,
    mttkrp_from_partial,
    partial_mttkrp_left,
    partial_mttkrp_right,
)
from repro.core import mttkrp_einsum, random_factors, random_tensor, tensor_norm


def _problem(shape, c=4, seed=0):
    x = random_tensor(jax.random.PRNGKey(seed), shape)
    factors = random_factors(jax.random.PRNGKey(seed + 1), shape, c)
    return x, factors


@pytest.mark.parametrize("shape", [(5, 6, 7), (4, 5, 6, 3), (3, 4, 2, 3, 4)])
def test_partials_give_correct_mttkrps(shape):
    x, factors = _problem(shape)
    n_modes = len(shape)
    m = (n_modes + 1) // 2
    t_left = partial_mttkrp_right(x, factors[m:])
    for n in range(m):
        sib = [factors[k] for k in range(m) if k != n]
        out = np.asarray(mttkrp_from_partial(t_left, sib, n))
        ref = np.asarray(mttkrp_einsum(x, factors, n))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)
    t_right = partial_mttkrp_left(x, factors[:m])
    for n in range(m, n_modes):
        sib = [factors[k] for k in range(m, n_modes) if k != n]
        out = np.asarray(mttkrp_from_partial(t_right, sib, n - m))
        ref = np.asarray(mttkrp_einsum(x, factors, n))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(6, 5, 4), (4, 5, 3, 4)])
def test_dimtree_sweep_matches_standard_als_exactly(shape):
    """Same iterates: factor-by-factor agreement over multiple sweeps."""
    x, factors = _problem(shape, c=3, seed=7)
    w = jnp.ones((3,), x.dtype)
    norm_x = tensor_norm(x)
    f_a, w_a = list(factors), w
    f_b, w_b = list(factors), w
    for it in range(4):
        f_a, w_a, fit_a = als_sweep(x, f_a, w_a, norm_x, jnp.asarray(it),
                                    method="2step", normalize=True)
        f_b, w_b, fit_b = dimtree_sweep(x, f_b, w_b, norm_x, jnp.asarray(it))
        for ua, ub in zip(f_a, f_b):
            np.testing.assert_allclose(
                np.asarray(ua), np.asarray(ub), rtol=2e-3, atol=2e-4
            )
        np.testing.assert_allclose(float(fit_a), float(fit_b), atol=1e-4)


def test_dimtree_converges_on_planted():
    from repro.core import cp_full

    planted = random_factors(jax.random.PRNGKey(2), (8, 7, 6, 5), 2)
    x = cp_full(None, planted)
    factors = random_factors(jax.random.PRNGKey(3), x.shape, 2)
    w = jnp.ones((2,), x.dtype)
    norm_x = tensor_norm(x)
    fit = 0.0
    for it in range(60):
        factors, w, fit = dimtree_sweep(x, factors, w, norm_x, jnp.asarray(it))
    assert float(fit) > 0.99, float(fit)
