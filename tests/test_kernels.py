"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles.

All kernels run in interpret mode on CPU (the kernel body executes in Python);
the BlockSpec tiling/padding logic is exercised for divisible and
non-divisible dims alike.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (parametrize marks below)

from conftest import given, settings, st  # shared optional-dep shim

from repro.core import random_factors, random_tensor
from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4), jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


def _problem(shape, c, seed=0, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kx, kf = jax.random.split(key)
    x = random_tensor(kx, shape, dtype)
    factors = random_factors(kf, shape, c, dtype)
    return x, factors


SHAPES = [
    (16, 12, 20),          # 3-way, non-aligned dims
    (8, 8, 8, 8),          # 4-way
    (4, 6, 5, 3, 7),       # 5-way odd dims
    (130, 9, 257),         # exceeds default blocks -> multi-block + padding
    (3, 3, 3, 3, 3, 3),    # 6-way (paper's largest N)
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("c", [1, 25])
def test_fused_mttkrp_all_modes(shape, c):
    x, factors = _problem(shape, c)
    for n in range(len(shape)):
        out = np.asarray(ops.fused_mttkrp(x, factors, n))
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, **TOL[jnp.float32], err_msg=f"mode {n}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mttkrp_dtypes(dtype):
    x, factors = _problem((12, 10, 14), 8, dtype=dtype)
    for n in range(3):
        out = np.asarray(ops.fused_mttkrp(x, factors, n), np.float32)
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n), np.float32)
        np.testing.assert_allclose(out, want, **TOL[dtype], err_msg=f"mode {n}")


@pytest.mark.parametrize("blocks", [(8, 16), (16, 64), (128, 256), (1, 1)])
def test_fused_mttkrp_block_sweep(blocks):
    bi, bb = blocks
    x, factors = _problem((24, 10, 36), 5, seed=3)
    for n in range(3):
        out = np.asarray(ops.fused_mttkrp(x, factors, n, block_i=bi, block_b=bb))
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, **TOL[jnp.float32])


def test_fused_mttkrp_rank_padding():
    """pad_rank_to simulates the TPU 128-lane pad; result must be unchanged."""
    x, factors = _problem((10, 12, 9), 25, seed=5)
    out = np.asarray(ops.fused_mttkrp(x, factors, 1, pad_rank_to=128))
    want = np.asarray(ref.fused_mttkrp_ref(x, factors, 1))
    np.testing.assert_allclose(out, want, **TOL[jnp.float32])


@pytest.mark.parametrize("dims", [(7, 9), (16, 32), (5, 13, 11)])
@pytest.mark.parametrize("c", [4, 25])
def test_krp_materialize(dims, c):
    _, factors = _problem(tuple(dims) + (2,), c, seed=1)
    mats = factors[: len(dims)]
    out = np.asarray(ops.krp_materialize(mats))
    want = np.asarray(ref.krp_ref(mats))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("shape", [(6, 32, 4), (17, 100, 25), (3, 5, 1)])
def test_multi_ttv_kernel(shape):
    big_l, dim_i, c = shape
    key = jax.random.PRNGKey(2)
    t = jax.random.normal(key, (big_l, dim_i, c))
    w = jax.random.normal(jax.random.PRNGKey(3), (big_l, c))
    out = np.asarray(ops.multi_ttv(t, w))
    want = np.asarray(ref.multi_ttv_ref(t, w))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(9, 14, 11, 6)])
def test_mttkrp_2step_kernel_path(shape):
    x, factors = _problem(shape, 7, seed=4)
    for n in range(len(shape)):
        out = np.asarray(ops.mttkrp_2step_kernel(x, factors, n))
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, rtol=2e-4, atol=2e-4, err_msg=f"mode {n}")


@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(2, 9), min_size=3, max_size=5),
    c=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_fused_mttkrp_property(shape, c, seed, data):
    shape = tuple(shape)
    n = data.draw(st.integers(0, len(shape) - 1))
    x, factors = _problem(shape, c, seed=seed)
    out = np.asarray(ops.fused_mttkrp(x, factors, n))
    want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(out / scale, want / scale, rtol=1e-4, atol=1e-5)
