"""The plan front door: dispatch, cost-model agreement, plan-vs-direct
numerics for every algorithm, and sharded == local on a size-1 mesh."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    mttkrp_einsum,
    mttkrp_flops,
    random_factors,
    random_tensor,
    tensor_norm,
)
from repro.core.cpals import grams, hadamard_except, normalize_columns
from repro.core.tensor_ops import dims_split
from repro.plan import (
    LocalExecutor,
    Problem,
    ShardedExecutor,
    SweepState,
    als_sweep,
    cp_als,
    mode_cost,
    plan_sweep,
)

# paper bench shapes: cubic, N in {3..6}, default (~16M) and paper (~750M) scale
BENCH_SHAPES = [
    tuple([round(total ** (1.0 / n))] * n)
    for total in (16e6, 750e6)
    for n in (3, 4, 5, 6)
]


# ------------------------------------------------------------------ planner
@pytest.mark.parametrize(
    "shape", BENCH_SHAPES, ids=["x".join(map(str, s)) for s in BENCH_SHAPES]
)
def test_auto_reproduces_paper_dispatch_on_bench_shapes(shape):
    """Sec. 5.3.3: 1-step on external modes, 2-step on internal modes.

    The per-mode dispatch property lives on the flat schedule (tree plans
    replace most full MTTKRPs with partial contractions -- see
    test_schedule.py for those invariants)."""
    plan = plan_sweep(Problem(shape=shape, rank=25), schedule="flat")
    algs = [m.algorithm for m in plan.modes]
    assert algs[0] == "1step" and algs[-1] == "1step", algs
    assert all(a.startswith("2step") for a in algs[1:-1]), algs


def test_auto_2step_order_matches_smaller_second_step_rule():
    """Alg. 4 line 4: contract the bigger side first (left-first iff L > R)."""
    shape = (4, 6, 8, 2)
    plan = plan_sweep(Problem(shape=shape, rank=5), schedule="flat")
    for mp in plan.modes[1:-1]:
        L, _, R = dims_split(shape, mp.mode)
        expect = "2step-left" if L > R else "2step-right"
        assert mp.algorithm == expect, (mp.mode, mp.algorithm, L, R)


def test_cost_model_agrees_with_mttkrp_flops():
    """Acceptance: GEMM/KRP/second-step terms come straight from mttkrp_flops."""
    shape, rank = (12, 10, 8, 6), 7
    problem = Problem(shape=shape, rank=rank)
    for n in range(len(shape)):
        f = mttkrp_flops(shape, rank, n)
        one = mode_cost(problem, n, "1step")
        assert one.gemm_flops == f["gemm_flops"]
        assert one.krp_flops == f["krp_flops"]
        assert one.second_step_flops == 0.0
        if 0 < n < len(shape) - 1:
            two = mode_cost(problem, n, "2step")
            assert two.gemm_flops == f["gemm_flops"]
            # the cost-picked order contracts min(L, R) in the 2nd step
            assert two.second_step_flops == f["second_step_flops"]


def test_mttkrp_flops_dtype_threading():
    shape, rank, n = (8, 6, 4), 5, 1
    f32 = mttkrp_flops(shape, rank, n)
    bf16 = mttkrp_flops(shape, rank, n, dtype=jnp.bfloat16)
    f64 = mttkrp_flops(shape, rank, n, dtype="float64")
    for key in ("tensor_bytes", "krp_bytes"):
        assert bf16[key] * 2 == f32[key]
        assert f64[key] == f32[key] * 2
    for key in ("gemm_flops", "krp_flops", "second_step_flops"):
        assert bf16[key] == f32[key] == f64[key]
    # Problem carries the dtype into the planner's byte terms
    b16 = plan_sweep(Problem(shape=shape, rank=rank, dtype=jnp.bfloat16))
    b32 = plan_sweep(Problem(shape=shape, rank=rank))
    assert b16.modes[n].cost.bytes * 2 == b32.modes[n].cost.bytes


def test_describe_is_json_ready_and_totals_sum():
    problem = Problem(
        shape=(8, 6, 4, 4),
        rank=3,
        mode_axes={0: "data", 2: "model"},
        axis_sizes={"data": 2, "model": 4},
    )
    # totals sum over every schedule node, whatever tree auto picked
    plan = plan_sweep(problem)
    d = json.loads(json.dumps(plan.describe()))
    assert d["sharded"] and d["local_shape"] == [4, 6, 1, 4]
    assert len(d["modes"]) == 4
    assert len(d["nodes"]) >= 4  # the 4 leaves, plus any partials
    for key in ("flops", "bytes", "collective_bytes", "predicted_s"):
        assert d["totals"][key] == pytest.approx(sum(n[key] for n in d["nodes"]))
    # on the flat schedule the node rows ARE the per-mode rows, and every
    # mode psums over the *other* mapped mode's axis; none is free
    flat = json.loads(json.dumps(plan_sweep(problem, schedule="flat").describe()))
    assert flat["schedule"] == "flat" and len(flat["nodes"]) == 4
    for key in ("flops", "bytes", "collective_bytes", "predicted_s"):
        assert flat["totals"][key] == pytest.approx(sum(m[key] for m in flat["modes"]))
    assert all(m["collective_bytes"] > 0 for m in flat["modes"])
    # unsharded problems predict zero collective traffic
    local = plan_sweep(Problem(shape=(8, 6, 4, 4), rank=3)).describe()
    assert local["totals"]["collective_bytes"] == 0.0


def test_problem_validation_errors():
    with pytest.raises(ValueError):  # unknown axis size
        Problem(shape=(4, 4), rank=2, mode_axes={0: "data"})
    with pytest.raises(ValueError):  # not divisible
        Problem(shape=(5, 4), rank=2, mode_axes={0: "data"}, axis_sizes={"data": 2})
    with pytest.raises(ValueError):  # axis mapped twice
        Problem(
            shape=(4, 4), rank=2,
            mode_axes={0: "data", 1: "data"}, axis_sizes={"data": 2},
        )
    with pytest.raises(ValueError):
        plan_sweep(Problem(shape=(4, 4, 4), rank=2), strategy="nope")
    with pytest.raises(ValueError):  # split only for dimtree
        plan_sweep(Problem(shape=(4, 4, 4), rank=2), strategy="1step", split=1)


# ----------------------------------------------------- plan-vs-direct sweeps
def _reference_sweep(x, factors, weights, norm_x, it):
    """Independent oracle sweep: einsum MTTKRP + the textbook update algebra."""
    from repro.core.cpals import fit_from_last_mttkrp

    factors = list(factors)
    gs = grams(factors)
    m_last = None
    for n in range(len(factors)):
        m_last = mttkrp_einsum(x, factors, n)
        h = hadamard_except(gs, n)
        u = m_last @ jnp.linalg.pinv(h)
        u, weights = normalize_columns(u, it)
        factors[n] = u
        gs[n] = u.T @ u
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], norm_x)
    return factors, weights, fit


STRATEGIES_UNDER_TEST = [
    "auto", "1step", "2step", "2step-left", "2step-right",
    "einsum", "baseline", "dimtree", "fused",
]


@pytest.mark.parametrize("strategy", STRATEGIES_UNDER_TEST)
def test_planned_sweep_matches_reference_for_every_algorithm(strategy):
    shape, rank = (5, 4, 6, 3), 3
    x = random_tensor(jax.random.PRNGKey(0), shape)
    factors = random_factors(jax.random.PRNGKey(1), shape, rank)
    w = jnp.ones((rank,), x.dtype)
    norm_x = tensor_norm(x)
    problem = Problem.from_tensor(x, rank)
    plan = plan_sweep(problem, strategy=strategy)
    state = SweepState(
        x=x, factors=list(factors), weights=w, norm_x=norm_x, it=jnp.asarray(0)
    )
    out = als_sweep(problem, plan, LocalExecutor(), state)
    f_ref, w_ref, fit_ref = _reference_sweep(x, list(factors), w, norm_x, jnp.asarray(0))
    tol = dict(rtol=5e-3, atol=1e-3) if strategy == "fused" else dict(rtol=1e-3, atol=1e-4)
    for a, b in zip(out.factors, f_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **tol)
    np.testing.assert_allclose(float(out.fit), float(fit_ref), atol=1e-3)


def test_sharded_executor_equals_local_on_size1_mesh():
    """ShardedExecutor == LocalExecutor exactly when every axis has 1 device."""
    from repro.launch import mesh as meshlib

    mesh = meshlib.make_host_mesh(1, 1)
    mode_axes = {0: "data", 1: "model"}
    shape, rank = (6, 4, 4), 3
    x = random_tensor(jax.random.PRNGKey(4), shape)
    factors = random_factors(jax.random.PRNGKey(5), shape, rank)
    w = jnp.ones((rank,), x.dtype)
    norm_x = tensor_norm(x)
    problem = Problem.from_tensor(x, rank, mode_axes=mode_axes, mesh=mesh)
    assert problem.local_shape == shape  # size-1 axes shard nothing
    for strategy in ("auto", "dimtree"):
        plan = plan_sweep(problem, strategy=strategy)
        assert plan.total_cost()["collective_bytes"] == 0.0

        def state():
            return SweepState(
                x=x, factors=list(factors), weights=w,
                norm_x=norm_x, it=jnp.asarray(0),
            )

        sharded_ex = ShardedExecutor(mesh, mode_axes)
        xs, fss = sharded_ex.prepare(problem, x, factors)
        st_sharded = SweepState(
            x=xs, factors=fss, weights=w, norm_x=norm_x, it=jnp.asarray(0)
        )
        out_local = als_sweep(problem, plan, LocalExecutor(), state())
        out_sharded = als_sweep(problem, plan, sharded_ex, st_sharded)
        for a, b in zip(out_local.factors, out_sharded.factors):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(out_local.fit), np.asarray(out_sharded.fit)
        )


def test_plan_cp_als_driver_converges_with_dimtree():
    from repro.core import cp_full

    planted = random_factors(jax.random.PRNGKey(2), (10, 8, 6, 5), 2)
    x = cp_full(None, planted)
    fits = []
    plan = plan_sweep(Problem.from_tensor(x, 2), strategy="dimtree")
    st = cp_als(x, plan, n_iters=80, tol=1e-9, seed=3,
                callback=lambda it, fit, dt: fits.append(fit))
    assert float(st.fit) > 0.99, float(st.fit)
    assert len(fits) == st.it


def test_mode_letters_rejects_unsupported_order():
    from repro.core import mode_letters

    assert mode_letters(3) == "abd"
    with pytest.raises(ValueError, match="order"):
        mode_letters(13)
    with pytest.raises(ValueError, match="order"):
        mode_letters(0)


# ------------------------------------------------- executor selection / cost
def test_overlap_model_degenerates_to_additive_sum():
    """serial_fraction=1 (sharded/local): max + min == the old additive model."""
    from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS

    problem = Problem(
        shape=(8, 6, 4, 4), rank=3,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    for n in range(4):
        c = mode_cost(problem, n, "1step")
        assert c.serial_fraction == 1.0
        additive = c.flops / PEAK_FLOPS + c.bytes / HBM_BW + c.collective_bytes / ICI_BW
        assert c.predicted_s == pytest.approx(additive)
        assert c.predicted_s >= max(c.compute_s, c.collective_s)


def test_overlapping_cost_hides_all_but_one_chunk():
    from repro.plan import DEFAULT_OVERLAP_CHUNKS, executor_mode_cost

    # every mode keeps local extent >= DEFAULT_OVERLAP_CHUNKS (local (4,16,4))
    problem = Problem(
        shape=(8, 16, 16), rank=5,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    for n in range(3):
        sh = executor_mode_cost(problem, n, "1step", "sharded")
        ov = executor_mode_cost(problem, n, "1step", "overlapping")
        # same physical terms, only the schedule differs
        assert ov.flops == sh.flops and ov.bytes == sh.bytes
        assert ov.collective_bytes == sh.collective_bytes
        assert ov.serial_fraction == pytest.approx(1.0 / DEFAULT_OVERLAP_CHUNKS)
        assert ov.predicted_s < sh.predicted_s  # every mode psums here
        assert ov.predicted_overlap_efficiency == pytest.approx(
            1.0 - 1.0 / DEFAULT_OVERLAP_CHUNKS
        )
    # chunk count is capped by the local row count of the mode
    tiny = Problem(
        shape=(4, 2, 4), rank=5,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    ov = executor_mode_cost(tiny, 1, "1step", "overlapping", n_chunks=8)
    assert ov.serial_fraction == pytest.approx(1.0 / 2)


def test_compressed_cost_wire_ratio():
    """int8 all-gather wire bytes: (p-1) * B/4 vs the ring's 2B(p-1)/p --
    a 4x win at p=2 that vanishes at p=8."""
    from repro.plan import compressed_allgather_bytes, ring_allreduce_bytes

    B = 1e6
    assert compressed_allgather_bytes(B, 1) == 0.0
    assert compressed_allgather_bytes(B, 2) == pytest.approx(
        ring_allreduce_bytes(B, 2) / 4, rel=1e-4
    )
    assert compressed_allgather_bytes(B, 8) == pytest.approx(
        ring_allreduce_bytes(B, 8), rel=1e-4
    )
    # int8 payload is per-*element*: bf16 blocks compress 2x, not 4x
    assert compressed_allgather_bytes(B, 2, itemsize=2.0) == pytest.approx(
        B / 2, rel=1e-4
    )
    # and the executor cost threads the problem dtype through
    from repro.plan import executor_mode_cost

    for dtype, ratio in (("float32", 4.0), (jnp.bfloat16, 2.0), ("float64", 8.0)):
        p = Problem(
            shape=(2, 64, 2), rank=32, dtype=dtype,
            mode_axes={0: "data"}, axis_sizes={"data": 2},
        )
        sh = executor_mode_cost(p, 1, "1step", "sharded")
        co = executor_mode_cost(p, 1, "1step", "compressed")
        # ring moves 2B(p-1)/p = B at p=2; the gather moves ~B/ratio
        assert co.collective_bytes == pytest.approx(
            sh.collective_bytes / ratio, rel=1e-2
        )


def test_select_executor_cost_argmin():
    from repro.plan import EXECUTORS, select_executor

    assert EXECUTORS == ("local", "sharded", "overlapping", "compressed")
    # unsharded -> local
    assert select_executor(Problem(shape=(8, 8, 8), rank=4)) == "local"
    # sharded with real collectives -> overlapping (hiding is free in the model)
    sharded = Problem(
        shape=(8, 6, 4, 4), rank=3,
        mode_axes={0: "data", 2: "model"}, axis_sizes={"data": 2, "model": 4},
    )
    assert select_executor(sharded) == "overlapping"
    # few participants + collective-bound -> compressed beats exact by >10%
    bound = Problem(
        shape=(2, 64, 2), rank=4096, mode_axes={0: "data"}, axis_sizes={"data": 2}
    )
    assert select_executor(bound) == "compressed"
    # dimtree plans compete on the same footing: their per-node psums can be
    # overlapped or compressed too, so selection is a cost argmin, not a
    # forced "sharded" (this tiny collective-bound tree clears the >10%
    # compression margin; the exact executors remain selectable by force)
    assert select_executor(sharded, "dimtree") in ("overlapping", "compressed")
    assert plan_sweep(sharded, strategy="dimtree", executor="overlapping").executor == "overlapping"
    # plan_sweep agrees and stamps the choice on the plan
    for problem in (sharded, bound):
        plan = plan_sweep(problem)
        assert plan.executor == select_executor(problem)
        assert json.loads(json.dumps(plan.describe()))["executor"] == plan.executor


def test_plan_executor_validation():
    from repro.plan import make_executor

    sharded = Problem(
        shape=(4, 4), rank=2, mode_axes={0: "data"}, axis_sizes={"data": 2}
    )
    with pytest.raises(ValueError, match="cannot run this problem"):
        plan_sweep(sharded, executor="local")  # local cannot run sharded problems
    with pytest.raises(ValueError, match="cannot run this problem"):
        plan_sweep(Problem(shape=(4, 4), rank=2), executor="overlapping")
    # any (schedule, executor) pair is costed or rejected by the one shared
    # predicate: dimtree + compressed/overlapping is now a valid pairing...
    sharded3 = Problem(
        shape=(4, 4, 4), rank=2, mode_axes={0: "data"}, axis_sizes={"data": 2}
    )
    plan = plan_sweep(sharded3, strategy="dimtree", executor="compressed")
    assert plan.executor == "compressed" and plan.kind == "dimtree"
    # ...and compressed on an unsharded problem is rejected with the same
    # message the flat schedule gets
    with pytest.raises(ValueError, match="cannot run this problem"):
        plan_sweep(
            Problem(shape=(4, 4, 4), rank=2), strategy="dimtree", executor="compressed"
        )
    with pytest.raises(ValueError):
        plan_sweep(Problem(shape=(4, 4), rank=2), executor="nope")
    with pytest.raises(ValueError):  # sharded kinds need the concrete mesh
        make_executor("overlapping")
    with pytest.raises(ValueError):
        make_executor("nope")
    # a sharded plan refuses to run on the default LocalExecutor
    plan = plan_sweep(sharded)
    with pytest.raises(ValueError, match="make_executor"):
        cp_als(jnp.zeros((4, 4)), plan)


def test_make_executor_builds_matching_kinds():
    from repro.launch import mesh as meshlib
    from repro.plan import (
        CompressedShardedExecutor,
        LocalExecutor,
        OverlappingExecutor,
        ShardedExecutor,
        make_executor,
    )

    mesh = meshlib.make_host_mesh(1, 1)
    mode_axes = {0: "data"}
    assert isinstance(make_executor("local"), LocalExecutor)
    sh = make_executor("sharded", mesh, mode_axes)
    assert isinstance(sh, ShardedExecutor) and not isinstance(sh, OverlappingExecutor)
    ov = make_executor("overlapping", mesh, mode_axes, n_chunks=7)
    assert isinstance(ov, OverlappingExecutor) and ov.n_chunks == 7
    assert isinstance(make_executor("compressed", mesh, mode_axes), CompressedShardedExecutor)


# ------------------------------------------------------------- dispatch cache
class _CountingCache(dict):
    """dict that counts lookups so tests can distinguish hit from rebuild."""

    def __init__(self):
        super().__init__()
        self.hits = 0

    def __getitem__(self, key):
        self.hits += 1
        return super().__getitem__(key)


def test_dispatch_cache_reuses_compiled_chunk():
    """A second ``cp_als`` call with the same key reuses the cached dispatch
    (no new entry, one hit) and reproduces the first run bit for bit."""
    shape, rank = (6, 5, 4), 3
    x = random_tensor(jax.random.PRNGKey(50), shape)
    init = random_factors(jax.random.PRNGKey(51), shape, rank)
    problem = Problem(shape=shape, rank=rank)
    plan = plan_sweep(problem)
    cache = _CountingCache()
    key = problem.signature()

    a = cp_als(x, plan, n_iters=4, tol=0.0, init_factors=list(init),
               dispatch_cache=cache, dispatch_key=key)
    assert len(cache) == 1 and cache.hits == 0  # cold: built, not looked up
    b = cp_als(x, plan, n_iters=4, tol=0.0, init_factors=list(init),
               dispatch_cache=cache, dispatch_key=key)
    assert len(cache) == 1 and cache.hits == 1  # warm: reused, nothing built
    for fa, fb in zip(a.factors, b.factors):
        assert np.array_equal(np.asarray(fa), np.asarray(fb))
    assert np.array_equal(np.asarray(a.weights), np.asarray(b.weights))


def test_dispatch_cache_isolates_signatures():
    """Different problems -- including the same shape with PP enabled -- get
    their own cache entries when keyed by ``Problem.signature()``; the PP
    signature is distinct by construction (``|pp`` suffix)."""
    shape, rank = (6, 5, 4), 3
    x = random_tensor(jax.random.PRNGKey(52), shape)
    cache = _CountingCache()

    exact = Problem(shape=shape, rank=rank)
    cp_als(x, plan_sweep(exact), n_iters=3, tol=0.0,
           dispatch_cache=cache, dispatch_key=exact.signature())
    assert len(cache) == 1

    other = Problem(shape=(5, 5, 5), rank=rank)
    cp_als(random_tensor(jax.random.PRNGKey(53), (5, 5, 5)), plan_sweep(other),
           n_iters=3, tol=0.0, dispatch_cache=cache,
           dispatch_key=other.signature())
    assert len(cache) == 2

    pp = Problem(shape=shape, rank=rank, pp_tol=0.1)
    assert pp.signature() != exact.signature() and "|pp" in pp.signature()
    cp_als(x, plan_sweep(pp, strategy="pp"), n_iters=3, tol=0.0,
           dispatch_cache=cache, dispatch_key=pp.signature())
    assert len(cache) == 3 and cache.hits == 0  # three builds, zero collisions


# --------------------------------------------- hypothesis planner invariants
from conftest import given, settings, st  # noqa: E402  (shared optional-dep shim)


@settings(max_examples=30, deadline=None)
@given(
    shape=st.lists(st.integers(2, 30), min_size=3, max_size=6),
    rank=st.integers(1, 32),
)
def test_auto_plan_invariants(shape, rank):
    # the per-mode invariants live on the flat schedule; tree-schedule
    # invariants are property-tested in test_schedule.py
    plan = plan_sweep(Problem(shape=tuple(shape), rank=rank), schedule="flat")
    assert [m.mode for m in plan.modes] == list(range(len(shape)))
    # external modes are always 1-step (2-step degenerates there)
    assert plan.modes[0].algorithm == "1step"
    assert plan.modes[-1].algorithm == "1step"
    for m in plan.modes:
        assert m.algorithm in ("1step", "2step-left", "2step-right")
        assert m.cost.predicted_s > 0.0
        assert m.cost.collective_bytes == 0.0


@settings(max_examples=15, deadline=None)
@given(
    shape=st.lists(st.integers(2, 12), min_size=3, max_size=5),
    strategy=st.sampled_from(["1step", "einsum", "baseline", "fused"]),
)
def test_forced_strategy_is_verbatim(shape, strategy):
    plan = plan_sweep(Problem(shape=tuple(shape), rank=4), strategy=strategy)
    assert all(m.algorithm == strategy for m in plan.modes)
