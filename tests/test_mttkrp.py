"""Unit + property tests for MTTKRP algorithms (Algs. 2-4) vs einsum oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (
    matricize,
    matricize_multi,
    mttkrp,
    mttkrp_1step,
    mttkrp_2step,
    mttkrp_baseline,
    mttkrp_einsum,
    multi_ttv,
    random_factors,
    random_tensor,
    ttm,
    ttv,
)

METHODS = ["1step", "2step", "2step-left", "2step-right", "baseline", "auto"]
SHAPES = [(6, 7), (4, 5, 6), (3, 4, 5, 2), (2, 3, 2, 3, 2)]


def _problem(shape, c=5, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, kf = jax.random.split(key)
    x = random_tensor(kx, shape)
    factors = random_factors(kf, shape, c)
    return x, factors


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("method", METHODS)
def test_mttkrp_all_modes_match_oracle(shape, method):
    x, factors = _problem(shape)
    for n in range(len(shape)):
        ref = np.asarray(mttkrp_einsum(x, factors, n))
        out = np.asarray(mttkrp(x, factors, n, method=method))
        assert out.shape == (shape[n], 5)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)


def test_mttkrp_1step_blocked_matches():
    x, factors = _problem((4, 5, 6, 3))
    for n in range(4):
        ref = np.asarray(mttkrp_einsum(x, factors, n))
        out = np.asarray(mttkrp_1step(x, factors, n, blocked=True))
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-4)


def test_2step_left_right_orders_agree():
    x, factors = _problem((3, 4, 5, 2), c=4, seed=3)
    for n in (1, 2):
        left = np.asarray(mttkrp_2step(x, factors, n, order="left"))
        right = np.asarray(mttkrp_2step(x, factors, n, order="right"))
        np.testing.assert_allclose(left, right, rtol=2e-4, atol=1e-4)


def test_matricize_definition():
    """X_(n)[i, j] must equal x[..., i, ...] with j the row-major remainder."""
    x = jnp.arange(2 * 3 * 4, dtype=jnp.float32).reshape(2, 3, 4)
    xn = np.asarray(matricize(x, 1))
    xnp = np.asarray(x)
    for i in range(3):
        col = 0
        for a in range(2):
            for b in range(4):
                assert xn[i, col] == xnp[a, i, b]
                col += 1


def test_matricize_multi_is_reshape():
    x = jnp.arange(2 * 3 * 4 * 5, dtype=jnp.float32).reshape(2, 3, 4, 5)
    m = matricize_multi(x, 1)
    assert m.shape == (6, 20)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(x).reshape(6, 20))


def test_ttv_ttm_definitions():
    x, _ = _problem((3, 4, 5))
    v = jnp.arange(4, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ttv(x, v, 1)),
        np.einsum("ijk,j->ik", np.asarray(x), np.asarray(v)),
        rtol=1e-5,
    )
    m = jnp.ones((5, 2), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ttm(x, m, 2)),
        np.einsum("ijk,kl->ijl", np.asarray(x), np.asarray(m)),
        rtol=1e-5,
    )


def test_multi_ttv_matches_percolumn_loop():
    """Alg. 4's 2nd step: batched contraction == per-column TTV loop."""
    key = jax.random.PRNGKey(7)
    t = jax.random.normal(key, (3, 4, 5, 6))  # (I_a, I_b, I_keep, C)
    fa = jax.random.normal(key, (3, 6))
    fb = jax.random.normal(key, (4, 6))
    out = np.asarray(multi_ttv(t, [fa, fb]))
    for c in range(6):
        ref_c = np.einsum("abz,a,b->z", np.asarray(t[..., c]), np.asarray(fa[:, c]), np.asarray(fb[:, c]))
        np.testing.assert_allclose(out[:, c], ref_c, rtol=1e-4, atol=1e-4)


def test_mttkrp_grad_flows():
    """MTTKRP is part of the CP gradient; all paths must be differentiable."""
    x, factors = _problem((3, 4, 5), c=3)

    def loss(fs, method):
        return jnp.sum(mttkrp(x, fs, 1, method=method) ** 2)

    g_ref = jax.grad(lambda fs: loss(fs, "einsum"))(factors)
    for method in ("1step", "2step"):
        g = jax.grad(lambda fs: loss(fs, method))(factors)
        for a, b in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    shape=st.lists(st.integers(2, 5), min_size=3, max_size=5),
    c=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_mttkrp_property_methods_agree(shape, c, seed, data):
    shape = tuple(shape)
    n = data.draw(st.integers(0, len(shape) - 1))
    method = data.draw(st.sampled_from(["1step", "2step", "baseline"]))
    x, factors = _problem(shape, c=c, seed=seed)
    ref = np.asarray(mttkrp_einsum(x, factors, n))
    out = np.asarray(mttkrp(x, factors, n, method=method))
    scale = max(1.0, float(np.abs(ref).max()))
    np.testing.assert_allclose(out / scale, ref / scale, rtol=5e-4, atol=5e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mttkrp_property_linearity_in_tensor(seed):
    """MTTKRP is linear in X: M(aX + bY) = a M(X) + b M(Y)."""
    shape = (3, 4, 2)
    x, factors = _problem(shape, c=3, seed=seed)
    y, _ = _problem(shape, c=3, seed=seed + 1)
    a, b = 0.7, -1.3
    lhs = np.asarray(mttkrp(a * x + b * y, factors, 1))
    rhs = a * np.asarray(mttkrp(x, factors, 1)) + b * np.asarray(mttkrp(y, factors, 1))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)
