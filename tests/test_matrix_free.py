"""Matrix-free Pallas MTTKRP vs the jnp oracle, every mode of orders 3-6.

The kernel streams natural-layout tensor blocks (no matricization, no KRP)
and folds factors in VMEM; these tests pin it to ``fused_mttkrp_ref`` at
HIGHEST precision across the full (order, mode, batch) grid the planner
offers it for, in interpret mode on CPU.  Block sizes are chosen small so
multi-block grids, revisited output blocks, and padding all execute.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401  (parametrize marks below)

from conftest import given, settings, st  # shared optional-dep shim

from repro.core import random_factors, random_tensor
from repro.kernels import ops, ref

# one representative shape per order, odd dims so padding paths run
SHAPES = {
    3: (10, 8, 12),
    4: (6, 5, 8, 4),
    5: (4, 6, 5, 3, 4),
    6: (3, 4, 3, 3, 4, 3),
}


def _problem(shape, c, seed=0):
    kx, kf = jax.random.split(jax.random.PRNGKey(seed))
    return random_tensor(kx, shape), random_factors(kf, shape, c)


def _batched_problem(shape, c, s, seed=0):
    kx, kf = jax.random.split(jax.random.PRNGKey(seed))
    x = random_tensor(kx, (s,) + shape)
    keys = jax.random.split(kf, s)
    fs = [
        jnp.stack([random_factors(keys[b], shape, c)[k] for b in range(s)])
        for k in range(len(shape))
    ]
    return x, fs


@pytest.mark.parametrize("order", sorted(SHAPES))
def test_matrix_free_all_modes(order):
    shape = SHAPES[order]
    x, factors = _problem(shape, 7, seed=order)
    for n in range(order):
        out = np.asarray(ops.matrix_free_mttkrp(x, factors, n, block_i=4, block_r=2))
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4, err_msg=f"mode {n}")


@pytest.mark.parametrize("order", sorted(SHAPES))
@pytest.mark.parametrize("s", [1, 4])
def test_matrix_free_batched_all_modes(order, s):
    shape = SHAPES[order]
    x, factors = _batched_problem(shape, 5, s, seed=10 + order)
    for n in range(order):
        out = np.asarray(
            ops.matrix_free_mttkrp_batched(
                x, factors, n, block_i=4, block_r=2, block_batch=2
            )
        )
        want = np.asarray(
            jax.vmap(lambda xb, *fb, n=n: ref.fused_mttkrp_ref(xb, fb, n))(x, *factors)
        )
        np.testing.assert_allclose(
            out, want, rtol=1e-4, atol=1e-4, err_msg=f"mode {n} batch {s}"
        )


def test_matrix_free_rank_one_and_default_blocks():
    # rank 1 (degenerate KRP) and the default tile stamps both hold
    x, factors = _problem((9, 7, 5, 6), 1, seed=3)
    for n in range(4):
        out = np.asarray(ops.matrix_free_mttkrp(x, factors, n))
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4, err_msg=f"mode {n}")


def test_matrix_free_rejects_unsupported_orders():
    x, factors = _problem((8, 6), 3, seed=5)
    with pytest.raises(ValueError):
        ops.matrix_free_mttkrp(x, factors, 0)


def test_matrix_free_via_core_dispatch():
    # method="matrix_free" through core.mttkrp threads tiles into the kernel
    from repro.core import mttkrp

    x, factors = _problem((8, 9, 6, 5), 4, seed=6)
    for n in range(4):
        out = np.asarray(
            mttkrp(x, factors, n, method="matrix_free", tiles={"block_i": 4, "block_r": 2})
        )
        want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4, err_msg=f"mode {n}")


@settings(max_examples=10, deadline=None)
@given(
    order=st.integers(3, 6),
    c=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_matrix_free_property(order, c, seed, data):
    shape = tuple(
        data.draw(st.integers(2, 9 if order <= 4 else 5)) for _ in range(order)
    )
    n = data.draw(st.integers(0, order - 1))
    x, factors = _problem(shape, c, seed=seed)
    out = np.asarray(ops.matrix_free_mttkrp(x, factors, n, block_i=4, block_r=2))
    want = np.asarray(ref.fused_mttkrp_ref(x, factors, n))
    scale = max(1.0, float(np.abs(want).max()))
    np.testing.assert_allclose(out / scale, want / scale, rtol=1e-4, atol=1e-5)
