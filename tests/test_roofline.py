"""Roofline analysis + dry-run spec machinery tests (no 512-device compile)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.flops import active_param_count, model_flops, param_count
from repro.analysis.roofline import (
    RooflineTerms,
    extrapolate,
    parse_collectives,
    terms_from_record,
)
from repro.configs import get_config, get_shape

SCHEDULED_HLO = """
HloModule jit_step, is_scheduled=true, num_partitions=256

%fused (p: f32[4,8]) -> f32[4,8] {
  ROOT %r = f32[4,8]{1,0} parameter(0)
}

ENTRY %main {
  %convert_fusion.1 = f32[512,2048]{1,0} fusion(%x), kind=kLoop
  %all-gather.85 = f32[512,2048]{0,1} all-gather(%convert_fusion.1), channel_id=8, replica_groups=[16,16]<=[16,16]T(1,0), dimensions={1}
  %small = bf16[16,64]{1,0} fusion(%y), kind=kLoop
  %all-reduce.3 = bf16[16,64]{1,0} all-reduce(%small), channel_id=9
  %rs = f32[8,8]{1,0} reduce-scatter(%convert_fusion.1), channel_id=10
}
"""


def test_parse_collectives_symbol_table():
    out = parse_collectives(SCHEDULED_HLO)
    # all-gather operand: f32[512,2048] = 4 MiB
    assert out["bytes_by_kind"]["all-gather"] == 512 * 2048 * 4
    assert out["bytes_by_kind"]["all-reduce"] == 16 * 64 * 2
    assert out["bytes_by_kind"]["reduce-scatter"] == 512 * 2048 * 4
    assert out["total_count"] == 3


def test_parse_collectives_inline_shapes():
    txt = "  %ar = f32[4,4]{1,0} all-reduce(f32[4,4]{1,0} %x), channel_id=1"
    out = parse_collectives(txt)
    assert out["bytes_by_kind"]["all-reduce"] == 64


def test_extrapolate_linear():
    assert extrapolate(10.0, 13.0, 5) == 10.0 + 4 * 3.0


def test_terms_and_bottleneck():
    t = RooflineTerms(
        flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
        model_flops_total=197e12 * 256 * 0.5, chips=256,
    )
    assert abs(t.compute_s - 1.0) < 1e-9
    assert abs(t.memory_s - 2.0) < 1e-9
    assert abs(t.collective_s - 0.5) < 1e-9
    assert t.bottleneck == "memory"
    assert abs(t.step_bound_s - 2.0) < 1e-9
    assert 0 < t.mfu_bound < 1


def test_terms_from_record_probe_path():
    rec = {
        "chips": 256, "n_layers": 10, "accum_steps": 2,
        "model_flops": 1e15,
        "probe1": {"flops": 5.0, "bytes": 50.0, "coll_bytes": 500.0},
        "probe2": {"flops": 8.0, "bytes": 70.0, "coll_bytes": 600.0},
    }
    t = terms_from_record(rec)
    assert t.flops == (5.0 + 9 * 3.0) * 2
    assert t.hbm_bytes == (50.0 + 9 * 20.0) * 2
    assert t.coll_bytes == (500.0 + 9 * 100.0) * 2


def test_model_flops_kinds():
    cfg = get_config("olmo-1b")
    n = active_param_count(cfg)
    assert model_flops(cfg, get_shape("train_4k")) == 6.0 * n * 256 * 4096
    assert model_flops(cfg, get_shape("decode_32k")) == 2.0 * n * 128


def test_moe_active_params_smaller():
    cfg = get_config("dbrx-132b")
    assert active_param_count(cfg) < 0.45 * param_count(cfg)


def test_cache_structs_shapes_and_specs():
    """Cache spec builder: shapes/specs line up for each family."""
    from repro.launch import mesh as meshlib
    from repro.launch.specs import cache_structs
    from repro.models import build_model

    mesh = meshlib.make_host_mesh(1, 1)
    shape = get_shape("decode_32k")
    for arch in ["olmo-1b", "falcon-mamba-7b", "recurrentgemma-2b", "whisper-base"]:
        cfg = get_config(arch)
        model = build_model(cfg)
        structs = cache_structs(model, shape, mesh)
        leaves = jax.tree.leaves(structs)
        assert leaves, arch
        for leaf in leaves:
            assert isinstance(leaf, jax.ShapeDtypeStruct)
            assert leaf.sharding is not None


def test_cell_applicability_rules():
    from repro.configs import cell_is_applicable

    ok, _ = cell_is_applicable(get_config("falcon-mamba-7b"), get_shape("long_500k"))
    assert ok
    ok, why = cell_is_applicable(get_config("qwen3-8b"), get_shape("long_500k"))
    assert not ok and "full-attention" in why
    ok, _ = cell_is_applicable(get_config("h2o-danube-3-4b"), get_shape("long_500k"))
    assert ok  # SWA bounds the state
