"""CP-ALS behaviour tests: recovery of planted low-rank tensors, fit monotonicity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CPConfig, cp_als, cp_full, random_factors


def planted_tensor(shape, rank, seed=0, noise=0.0):
    key = jax.random.PRNGKey(seed)
    kf, kn = jax.random.split(key)
    factors = random_factors(kf, shape, rank)
    x = cp_full(None, factors)
    if noise:
        x = x + noise * jax.random.normal(kn, x.shape)
    return x


@pytest.mark.parametrize("method", ["auto", "1step", "2step", "einsum"])
def test_cpals_recovers_planted_rank(method):
    x = planted_tensor((12, 9, 10), rank=3, seed=1)
    state = cp_als(x, CPConfig(rank=3, n_iters=150, tol=1e-8, method=method, seed=4))
    assert float(state.fit) > 0.99, f"fit={float(state.fit)} for {method}"


def test_cpals_fit_monotone_nondecreasing():
    x = planted_tensor((10, 8, 6, 4), rank=2, seed=2, noise=0.05)
    fits = []
    cp_als(
        x,
        CPConfig(rank=4, n_iters=25, tol=0.0),
        callback=lambda it, fit, dt: fits.append(fit),
    )
    fits = np.asarray(fits)
    # ALS monotonically decreases the residual (tiny numerical slack).
    assert np.all(np.diff(fits) > -1e-4), fits


def test_cpals_4way_matches_across_methods():
    x = planted_tensor((6, 5, 4, 3), rank=2, seed=3)
    f1 = cp_als(x, CPConfig(rank=2, n_iters=60, method="1step", seed=9)).fit
    f2 = cp_als(x, CPConfig(rank=2, n_iters=60, method="2step", seed=9)).fit
    np.testing.assert_allclose(float(f1), float(f2), atol=1e-3)


def test_cpals_reconstruction_error_matches_fit():
    x = planted_tensor((8, 7, 6), rank=2, seed=5)
    st = cp_als(x, CPConfig(rank=2, n_iters=100, tol=1e-9, seed=11))
    recon = cp_full(st.weights, st.factors)
    true_fit = 1.0 - float(jnp.linalg.norm((x - recon).ravel()) / jnp.linalg.norm(x.ravel()))
    # The factored fit formula (normX^2 - 2<X,Y> + normY^2) loses ~sqrt(eps)
    # precision near zero residual in fp32 -- allow that slack.
    np.testing.assert_allclose(float(st.fit), true_fit, atol=2e-3)


def test_cpals_weights_positive_and_sorted_magnitudes():
    x = planted_tensor((9, 9, 9), rank=3, seed=6)
    st = cp_als(x, CPConfig(rank=3, n_iters=80, seed=2))
    assert np.all(np.asarray(st.weights) > 0)
