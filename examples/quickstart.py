"""Quickstart: CP decomposition of a dense tensor with the paper's MTTKRP.

Builds a rank-4 planted tensor + noise, plans the sweep through the
``Problem -> SweepPlan -> Executor`` front door (the planner argmins over
contraction schedules -- on an order-4 tensor it picks a dimension tree,
reading X twice per sweep instead of four times; full MTTKRPs inside any
schedule follow the paper's Sec. 5.3.3 method mix), runs CP-ALS, prints
fit trajectory and per-iteration timing, and cross-checks the fused Pallas
kernel against the einsum oracle on one MTTKRP.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CPConfig,
    cp_als,
    cp_full,
    mttkrp_einsum,
    random_factors,
)
from repro.kernels import ops
from repro.plan import Problem, plan_sweep


def main():
    key = jax.random.PRNGKey(0)
    shape, rank = (60, 48, 36, 24), 4
    planted = random_factors(key, shape, rank)
    x = cp_full(None, planted)
    x = x + 0.05 * jnp.std(x) * jax.random.normal(jax.random.PRNGKey(1), x.shape)
    print(f"tensor {shape}, planted rank {rank}, noise 5% of signal std")

    # the front door: plan the sweep, see what the cost model picked per mode
    plan = plan_sweep(Problem.from_tensor(x, rank))
    for mp in plan.modes:
        print(f"  mode {mp.mode}: {mp.algorithm:12s} "
              f"predicted {mp.cost.predicted_s*1e6:8.1f} us "
              f"({mp.cost.flops:.2e} flops, {mp.cost.bytes:.2e} B)")

    history = []
    state = cp_als(
        x,
        CPConfig(rank=rank, n_iters=40, tol=1e-7, method="auto"),
        callback=lambda it, fit, dt: history.append((it, fit, dt)),
    )
    for it, fit, dt in history[:3] + history[-2:]:
        print(f"  iter {it:2d}  fit={fit:.6f}  {dt*1e3:7.1f} ms")
    print(f"final fit {float(state.fit):.6f} after {state.it} sweeps")
    assert float(state.fit) > 0.95

    # fused Pallas kernel (interpret mode on CPU) vs oracle
    m_kernel = ops.fused_mttkrp(x, state.factors, 1)
    m_ref = mttkrp_einsum(x, state.factors, 1)
    err = float(jnp.max(jnp.abs(m_kernel - m_ref)))
    print(f"fused-kernel MTTKRP max|err| vs einsum oracle: {err:.2e}")
    assert err < 1e-2
    print("OK")


if __name__ == "__main__":
    main()
