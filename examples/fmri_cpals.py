"""The paper's neuroimaging application (Sec. 3 / Sec. 5.3.3), synthetic data.

Builds a time x subject x region x region functional-connectivity tensor with
planted network components (rank-1 outer products of temporal envelopes,
subject loadings, and symmetric network maps), then:
  1. runs CP-ALS on the 4-way tensor,
  2. linearizes the symmetric region-region modes (upper triangle, as the
     paper does -- halves the entries) and runs CP-ALS on the 3-way tensor,
  3. reports per-iteration times for the paper's method mix vs the
     reorder-baseline, and the recovered component count.

    PYTHONPATH=src python examples/fmri_cpals.py [--regions 60] [--rank 5]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CPConfig, cp_als


def synth_fmri(t=120, subjects=30, regions=60, rank=5, seed=0):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    # temporal envelopes: smooth positive signals
    tt = jnp.linspace(0, 8 * jnp.pi, t)[:, None]
    phases = jax.random.uniform(ks[0], (1, rank)) * 2 * jnp.pi
    temporal = 1.0 + jnp.sin(tt / (1 + jnp.arange(rank)) + phases)
    subj = jax.nn.softplus(jax.random.normal(ks[1], (subjects, rank)))
    seeds = jax.random.normal(ks[2], (regions, rank))
    networks = jnp.einsum("ir,jr->rij", seeds, seeds)  # symmetric maps
    x = jnp.einsum("tr,sr,rij->tsij", temporal, subj, networks)
    x = x / jnp.max(jnp.abs(x))
    noise = 0.05 * jax.random.normal(ks[3], x.shape)
    return x + noise


def run(x, rank, label, method="auto", iters=15):
    times = []
    st = cp_als(
        x,
        CPConfig(rank=rank, n_iters=iters, tol=1e-6, method=method),
        callback=lambda it, fit, dt: times.append(dt),
    )
    per_iter = float(np.min(times[1:])) if len(times) > 1 else times[0]
    print(
        f"  {label:28s} fit={float(st.fit):.4f}  per-iter={per_iter*1e3:8.1f} ms"
        f"  ({st.it} sweeps)"
    )
    return st, per_iter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--regions", type=int, default=60)
    ap.add_argument("--subjects", type=int, default=30)
    ap.add_argument("--time", type=int, default=120)
    ap.add_argument("--rank", type=int, default=5)
    args = ap.parse_args()

    x4 = synth_fmri(args.time, args.subjects, args.regions, args.rank)
    print(f"4-way tensor {tuple(x4.shape)} ({x4.size:,} entries)")
    _, t_auto = run(x4, args.rank, "4D paper methods (auto)")
    _, t_base = run(x4, args.rank, "4D reorder-baseline", method="baseline")
    print(f"  4D speedup over baseline: {t_base / t_auto:.2f}x")

    # linearize symmetric region modes (paper: halves entries, 3-way tensor)
    r = args.regions
    iu = jnp.triu_indices(r)
    x3 = x4[:, :, iu[0], iu[1]]
    print(f"3-way linearized tensor {tuple(x3.shape)}")
    st3, t3_auto = run(x3, args.rank, "3D paper methods (auto)")
    _, t3_base = run(x3, args.rank, "3D reorder-baseline", method="baseline")
    print(f"  3D speedup over baseline: {t3_base / t3_auto:.2f}x")

    # component summary: temporal factor column norms = component energies
    w = np.asarray(st3.weights)
    print(f"recovered component weights: {np.sort(w)[::-1][:args.rank].round(3)}")


if __name__ == "__main__":
    main()
