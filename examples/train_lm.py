"""End-to-end training driver: train a small LM for a few hundred steps.

Any of the 10 assigned architectures is selectable with ``--arch`` (reduced
to a CPU-trainable width by default; ``--width/--layers/--vocab`` override).
Exercises the full substrate: data pipeline -> train loop with fault-tolerant
checkpointing -> metrics.  Default (~40 steps, ~13M params) finishes in a few
minutes on one CPU core; ``--steps 300 --width 512`` approximates the
"~100M model for a few hundred steps" driver on real hardware.

    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --steps 40
"""

import argparse
import dataclasses
import logging

import jax

from repro.configs import get_config, list_archs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch import mesh as meshlib
from repro.models import build_model
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--accum", type=int, default=1)
    args = ap.parse_args()

    base = get_config(args.arch).reduced()
    cfg = dataclasses.replace(
        base,
        d_model=args.width,
        n_layers=max(args.layers, len(base.block_pattern) or 1),
        n_heads=max(4, args.width // 64),
        n_kv_heads=max(2, args.width // 128),
        head_dim=64,
        d_ff=args.width * 4,
        d_ff_expert=args.width * 2 if base.n_experts else 0,
        d_ff_shared=args.width * 2 if base.d_ff_shared else 0,
        lru_width=args.width if base.lru_width else 0,
        dt_rank=max(8, args.width // 16),
        vocab=args.vocab,
    )
    model = build_model(cfg)
    from repro.analysis.flops import _defs_count

    n_params = _defs_count(model.param_defs)
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    data = SyntheticLM(
        DataConfig(vocab=args.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)):
        result = train_loop(
            model,
            data,
            OptConfig(lr=args.lr, warmup_steps=20, total_steps=max(args.steps, 100)),
            LoopConfig(
                total_steps=args.steps,
                ckpt_every=max(args.steps // 4, 10),
                ckpt_dir=args.ckpt_dir,
                accum_steps=args.accum,
                log_every=10,
            ),
        )
    first = result.metrics_history[0]["loss"]
    last = result.metrics_history[-1]["loss"]
    print(f"loss {first:.4f} -> {last:.4f} over {result.step} steps "
          f"({result.failures} recovered failures)")


if __name__ == "__main__":
    main()
