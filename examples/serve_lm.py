"""Batched serving example: prefill + decode with the request engine.

    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.launch import mesh as meshlib
from repro.models import build_model
from repro.serve.engine import GenerationConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    with meshlib.use_mesh(meshlib.make_host_mesh(1, 1)):
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(
            model,
            params,
            GenerationConfig(
                max_new_tokens=args.new_tokens, temperature=args.temperature
            ),
            batch_size=4,
        )
        rng = np.random.default_rng(0)
        rids = [
            eng.submit(rng.integers(0, cfg.vocab, size=rng.integers(4, 12)))
            for _ in range(args.requests)
        ]
        t0 = time.perf_counter()
        results = eng.flush()
        dt = time.perf_counter() - t0
    tokens_out = sum(len(v) for v in results.values())
    print(f"arch={cfg.name}: served {len(results)} requests, "
          f"{tokens_out} tokens in {dt:.2f}s")
    for rid in rids[:3]:
        print(f"  req {rid}: {results[rid].tolist()}")


if __name__ == "__main__":
    main()
