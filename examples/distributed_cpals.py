"""Distributed CP-ALS demo: paper Alg. 3 across a device mesh.

Runs on 8 simulated host devices (mesh 2x4), tensor block-distributed over
two modes, full ALS inside one shard_map (local MTTKRP + psum reductions --
the device-for-thread port of the paper's parallelization).

    PYTHONPATH=src python examples/distributed_cpals.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402

from repro.core import CPConfig, cp_als, cp_full, random_factors  # noqa: E402
from repro.dist.dist_mttkrp import dist_cp_als  # noqa: E402
from repro.plan import Problem, plan_sweep  # noqa: E402


def main():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    shape, rank = (64, 48, 40), 6
    x = cp_full(None, random_factors(key, shape, rank))

    # plan the sharded sweep: per-mode algorithm + predicted psum volume,
    # plus the cost-argmin executor pick (overlapping hides the psums behind
    # the chunked local GEMMs; see docs/distributed.md)
    plan = plan_sweep(Problem.from_tensor(x, rank, mode_axes={0: "data", 1: "model"},
                                          mesh=mesh))
    print(f"  planner picked executor: {plan.executor}")
    for mp in plan.modes:
        print(f"  mode {mp.mode}: {mp.algorithm:12s} "
              f"psum {mp.cost.collective_bytes/1e3:8.1f} kB/device "
              f"overlap_eff {mp.cost.predicted_overlap_efficiency:.2f}")

    t0 = time.perf_counter()
    factors, weights, fit = dist_cp_als(
        x, rank=rank, mode_axes={0: "data", 1: "model"}, mesh=mesh, n_iters=60
    )
    t_dist = time.perf_counter() - t0
    print(f"distributed CP-ALS: fit={float(fit):.5f} in {t_dist:.2f}s "
          f"(tensor sharded {mesh.shape} over modes 0,1)")

    t0 = time.perf_counter()
    st = cp_als(x, CPConfig(rank=rank, n_iters=60))
    t_local = time.perf_counter() - t0
    print(f"single-device reference: fit={float(st.fit):.5f} in {t_local:.2f}s")
    assert abs(float(fit) - float(st.fit)) < 1e-2
    print("OK: distributed result matches")


if __name__ == "__main__":
    main()
