"""Docs checker: markdown link check + executable fenced snippets.

Two passes, no dependencies beyond the repo's own runtime:

1. **Links** -- every ``[text](target)`` in ``README.md`` and ``docs/*.md``
   must resolve: relative paths must exist on disk, ``#anchors`` must match
   a heading slug (GitHub slugification) in the target file.  External
   ``http(s)://`` / ``mailto:`` links are skipped (no network in CI).
2. **Snippets** -- every fenced block whose info string is exactly
   ``python`` in ``docs/*.md`` is executed, top to bottom, in one shared
   namespace per file (so later snippets can build on earlier ones).  A
   raised exception fails the run with the file and snippet line.  README
   fences are link-checked but not executed (they elide setup by design).

Run from the repo root:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python tools/check_docs.py

The multi-device flag is defaulted below (before jax's first import) so a
bare invocation works too.
"""

from __future__ import annotations

import os
import re
import sys
import traceback
from pathlib import Path

# must be set before any snippet triggers jax's backend init
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

ROOT = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```(\w*)[^\n]*\n(.*?)^```\s*$", re.MULTILINE | re.DOTALL)
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, spaces to dashes, drop punctuation."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def anchors_of(md_path: Path) -> set[str]:
    return {github_slug(h) for h in HEADING_RE.findall(md_path.read_text())}


def check_links(md_path: Path) -> list[str]:
    """Return human-readable problems for every unresolvable link."""
    problems = []
    text = md_path.read_text()
    # fenced code often contains pseudo-links (dict literals etc.); drop it
    prose = FENCE_RE.sub("", text)
    for target in LINK_RE.findall(prose):
        if target.startswith(EXTERNAL):
            continue
        path_part, _, anchor = target.partition("#")
        dest = md_path if not path_part else (md_path.parent / path_part).resolve()
        if not dest.exists():
            problems.append(f"{md_path}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md" and github_slug(anchor) not in anchors_of(dest):
            problems.append(f"{md_path}: missing anchor -> {target}")
    return problems


def run_snippets(md_path: Path) -> list[str]:
    """Execute the file's ``python`` fences in one shared namespace."""
    problems = []
    text = md_path.read_text()
    ns: dict = {"__name__": f"docs_snippet[{md_path.name}]"}
    for m in FENCE_RE.finditer(text):
        lang, code = m.group(1), m.group(2)
        if lang != "python":
            continue
        line = text[: m.start()].count("\n") + 2  # first line inside the fence
        try:
            exec(compile(code, f"{md_path}:{line}", "exec"), ns)  # noqa: S102
        except Exception:
            problems.append(
                f"{md_path}:{line}: snippet raised\n{traceback.format_exc()}"
            )
    return problems


def main() -> int:
    md_files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    problems: list[str] = []
    for f in md_files:
        problems.extend(check_links(f))
    for f in md_files:
        if f.parent.name == "docs":
            print(f"executing snippets: {f.relative_to(ROOT)}")
            problems.extend(run_snippets(f))
    if problems:
        print("\n--- docs check FAILED ---")
        for p in problems:
            print(p)
        return 1
    print(f"docs check OK ({len(md_files)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
