"""Subpackage."""
