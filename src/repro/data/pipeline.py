"""Deterministic, resumable data pipeline: synthetic LM stream + memmap corpus.

Determinism contract (fault tolerance depends on it): batch ``i`` of a source
is a pure function of ``(seed, i)`` -- after a crash+restore at step ``s`` the
loop asks for batch ``s`` and gets exactly what it would have seen.  Host
sharding slices each global batch by ``(host_id, host_count)`` so every host
feeds its addressable devices only.  A background prefetch thread keeps
``depth`` batches in flight (overlaps host data work with device steps).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    host_count: int = 1


class SyntheticLM:
    """Markov-ish synthetic token stream (structure so loss can decrease)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        base = np.random.default_rng(cfg.seed)
        # fixed bigram transition table: each token has 8 likely successors
        self._succ = base.integers(0, cfg.vocab, size=(cfg.vocab, 8), dtype=np.int64)

    def batch(self, index: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        local = cfg.global_batch // cfg.host_count
        lo = cfg.host_id * local
        tokens = np.empty((local, cfg.seq_len + 1), np.int32)
        start = rng.integers(0, cfg.vocab, size=(cfg.global_batch,))
        choices = rng.integers(0, 8, size=(cfg.global_batch, cfg.seq_len))
        noise = rng.random((cfg.global_batch, cfg.seq_len)) < 0.1
        rand_tok = rng.integers(0, cfg.vocab, size=(cfg.global_batch, cfg.seq_len))
        for b in range(local):
            g = lo + b
            t = start[g]
            tokens[b, 0] = t
            for s in range(cfg.seq_len):
                t = rand_tok[g, s] if noise[g, s] else self._succ[t, choices[g, s]]
                tokens[b, s + 1] = t
        return {"tokens": tokens}


class MemmapCorpus:
    """Pre-tokenized flat corpus (uint16/uint32 .bin); random crops by index."""

    def __init__(self, path: str, cfg: DataConfig, dtype=np.uint16):
        self.cfg = cfg
        self.data = np.memmap(path, dtype=dtype, mode="r")
        if len(self.data) < cfg.seq_len + 2:
            raise ValueError("corpus shorter than one sequence")

    def batch(self, index: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        local = cfg.global_batch // cfg.host_count
        lo = cfg.host_id * local
        starts = rng.integers(0, len(self.data) - cfg.seq_len - 1, size=cfg.global_batch)
        out = np.stack(
            [
                np.asarray(self.data[s : s + cfg.seq_len + 1], np.int32)
                for s in starts[lo : lo + local]
            ]
        )
        return {"tokens": np.minimum(out, cfg.vocab - 1)}


class Prefetcher:
    """Background-thread prefetch of source.batch(i) for i = start, start+1, ..."""

    def __init__(self, source, start: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._next = start
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        i = self._next
        while not self._stop.is_set():
            try:
                self._q.put((i, self.source.batch(i)), timeout=0.2)
                i += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
