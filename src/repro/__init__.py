"""repro: MTTKRP/CP-ALS framework + LM substrate on JAX."""
