"""repro: MTTKRP/CP-ALS framework + LM substrate on JAX."""

from . import compat  # noqa: F401  -- installs the jax >= 0.6 aliases on old jax
