"""MTTKRP algorithms: 1-step (Algs. 2-3), 2-step (Alg. 4), baseline, fused.

All functions compute, for mode ``n`` of an N-way tensor ``x`` with CP factors
``factors = [U_0, ..., U_{N-1}]`` (``U_k`` of shape ``(I_k, C)``):

    M = X_(n) . (U_{N-1} (x) ... (x) U_{n+1} (x) U_{n-1} (x) ... (x) U_0)

i.e.  ``M[i, c] = sum_{l, r} X3[l, i, r] * K_L[l, c] * K_R[r, c]``  with
``X3 = x.reshape(L, I_n, R)`` (free view),  ``K_L = U_0 (.) ... (.) U_{n-1}``,
``K_R = U_{n+1} (.) ... (.) U_{N-1}``  (see krp.py for the row convention).

None of the algorithms reorders tensor entries -- the defining constraint of
the paper.  Only :func:`mttkrp_baseline` does (by design: it is the paper's
"reorder + one GEMM" comparator, a *lower bound* for the straightforward
approach since we still exclude KRP-formation time there).
"""

from __future__ import annotations

import math
from typing import Literal, Mapping, Sequence

import jax
import jax.numpy as jnp

from .krp import krp, krp_or_ones
from .tensor_ops import as_lir, dims_split, matricize, mode_letters, multi_ttv

Array = jax.Array
Method = Literal["auto", "1step", "2step", "2step-left", "2step-right", "einsum", "baseline", "fused", "matrix_free"]


def _split_factors(factors: Sequence[Array], n: int):
    return list(factors[:n]), list(factors[n + 1 :])


def mttkrp_einsum(x: Array, factors: Sequence[Array], n: int) -> Array:
    """Direct einsum oracle (no algorithmic structure; for tests/autodiff ref)."""
    letters = mode_letters(x.ndim)
    terms = [letters]
    args: list[Array] = [x]
    for k, u in enumerate(factors):
        if k == n:
            continue
        terms.append(letters[k] + "c")
        args.append(u)
    return jnp.einsum(",".join(terms) + f"->{letters[n]}c", *args)


def mttkrp_1step(
    x: Array, factors: Sequence[Array], n: int, *, blocked: bool = False
) -> Array:
    """1-step MTTKRP (paper Algs. 2-3): explicit KRP, layout-respecting GEMMs.

    Forms the full KRP ``K = K_L (.) K_R`` with the reuse algorithm, then
    multiplies against the *unreordered* tensor.  ``blocked=False`` expresses
    the block inner product of Alg. 2 line 9 as a single ``dot_general``
    contracting ``(l, r)`` (XLA fuses the block loop -- the TPU analogue of
    the per-block BLAS calls).  ``blocked=True`` keeps the paper's explicit
    loop over blocks (one GEMM per ``l``) via ``lax.scan`` accumulation --
    the faithful Alg. 2 structure, useful for benchmarking loop overhead.
    """
    left, right = _split_factors(factors, n)
    c = factors[0].shape[1]
    L, In, R = dims_split(x.shape, n)
    k = krp_or_ones(left + right, c, x.dtype)  # (L*R, C), reuse Alg. 1
    x3 = as_lir(x, n)
    if not blocked or L == 1:
        if L == 1:
            return x3[0] @ k  # external mode n=0: single GEMM (Alg. 2 line 4)
        return jnp.einsum("lir,lrc->ic", x3, k.reshape(L, R, c))
    k3 = k.reshape(L, R, c)

    def body(acc, lr):
        xl, kl = lr
        return acc + xl @ kl, None  # Alg. 2 line 9: one row-major GEMM per block

    acc0 = jnp.zeros((In, c), x.dtype)
    out, _ = jax.lax.scan(body, acc0, (x3, k3))
    return out


def mttkrp_2step(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    order: Literal["auto", "left", "right"] = "auto",
) -> Array:
    """2-step MTTKRP (paper Alg. 4 / Phan et al.): partial MTTKRP + multi-TTV.

    right-first:  R_t = reshape(X, (L*I_n, R)) @ K_R      (one GEMM, free view)
                  M[i,c] = sum_l R_t[l,i,c] * K_L[l,c]    (multi-TTV)
    left-first:   L_t = K_L^T @ reshape(X, (L, I_n*R))    (one GEMM, free view)
                  M[i,c] = sum_r L_t[c,i,r] * K_R[r,c]    (multi-TTV)

    ``order='auto'`` follows Alg. 4 line 4: left-first iff ``L > R`` (the
    2nd-step flops are ``I_n*C*min(L,R)`` that way).  External modes
    degenerate to the 1-step single GEMM.
    """
    left, right = _split_factors(factors, n)
    c = factors[0].shape[1]
    L, In, R = dims_split(x.shape, n)
    if L == 1 or R == 1:  # external modes: 2-step degenerates to 1-step
        return mttkrp_1step(x, factors, n)
    if order == "auto":
        order = "left" if L > R else "right"
    if order == "right":
        k_r = krp(right)  # (R, C)
        r_t = (x.reshape(L * In, R) @ k_r).reshape(L, In, c)
        k_l = krp(left)  # (L, C)
        return jnp.einsum("lic,lc->ic", r_t, k_l)  # multi-TTV (Alg. 4 l.13-15)
    k_l = krp(left)
    l_t = (k_l.T @ x.reshape(L, In * R)).reshape(c, In, R)
    k_r = krp(right)
    return jnp.einsum("cir,rc->ic", l_t, k_r)  # multi-TTV (Alg. 4 l.7-9)


def mttkrp_baseline(x: Array, factors: Sequence[Array], n: int) -> Array:
    """Paper's baseline: explicitly reorder to ``X_(n)`` then one big GEMM.

    The transpose-copy in :func:`matricize` is the cost the paper's algorithms
    exist to avoid.  (The paper's reported baseline *excludes* both the copy
    and KRP formation; benchmarks report the pieces separately.)
    """
    left, right = _split_factors(factors, n)
    c = factors[0].shape[1]
    xn = matricize(x, n)  # data movement happens here
    k = krp_or_ones(left + right, c, x.dtype)
    return xn @ k


def mttkrp(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    method: Method = "auto",
    tiles: Mapping[str, int] | None = None,
) -> Array:
    """Dispatching MTTKRP.

    ``method='auto'`` reproduces the paper's recommended configuration
    (Sec. 5.3.3): 1-step for external modes (where 2-step degenerates anyway)
    and 2-step for internal modes.  ``'fused'`` routes to the Pallas kernel
    (beyond-paper: KRP never materialized in HBM) via repro.kernels.ops;
    ``'matrix_free'`` routes to the fully streaming kernel (no matricization
    and no KRP of any size -- raw factors go straight into VMEM).  ``tiles``
    (``{"block_i": ..., "block_b": ...}`` for fused, ``{"block_i": ...,
    "block_r": ...}`` for matrix-free, from the autotuner's
    ``NodePlan.tiles``) overrides the kernel's tile sizes and is ignored by
    the non-kernel methods (their blocking is XLA's concern).
    """
    if method == "auto":
        method = "1step" if n in (0, len(factors) - 1) else "2step"
    if method == "1step":
        return mttkrp_1step(x, factors, n)
    if method == "2step":
        return mttkrp_2step(x, factors, n, order="auto")
    if method == "2step-left":
        return mttkrp_2step(x, factors, n, order="left")
    if method == "2step-right":
        return mttkrp_2step(x, factors, n, order="right")
    if method == "einsum":
        return mttkrp_einsum(x, factors, n)
    if method == "baseline":
        return mttkrp_baseline(x, factors, n)
    if method == "fused":
        from repro.kernels import ops as kops  # lazy: kernels import pallas

        kw = {
            k: int(v)
            for k, v in (tiles or {}).items()
            if k in ("block_i", "block_b")
        }
        return kops.fused_mttkrp(x, list(factors), n, **kw)
    if method == "matrix_free":
        from repro.kernels import ops as kops  # lazy: kernels import pallas

        kw = {
            k: int(v)
            for k, v in (tiles or {}).items()
            if k in ("block_i", "block_r")
        }
        return kops.matrix_free_mttkrp(x, list(factors), n, **kw)
    raise ValueError(f"unknown method {method!r}")


def mttkrp_batched(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    method: Method = "auto",
    tiles: Mapping[str, int] | None = None,
) -> Array:
    """MTTKRP over a leading batch axis: one dispatch for B stacked problems.

    ``x`` is ``(B, *shape)`` and each factor is ``(B, I_k, C)``; the result is
    ``(B, I_n, C)``.  The non-kernel methods are ``vmap`` of the unbatched
    algorithms (einsum/reshape/dot all batch cleanly under vmap); ``'fused'``
    routes to the Pallas kernel's native batch grid axis, which keeps the KRP
    in registers per batch slab instead of materializing B of them.  ``tiles``
    may carry ``block_batch`` in addition to the unbatched tile names.
    """
    if method == "auto":
        method = "1step" if n in (0, len(factors) - 1) else "2step"
    if method == "fused":
        from repro.kernels import ops as kops  # lazy: kernels import pallas

        kw = {
            k: int(v)
            for k, v in (tiles or {}).items()
            if k in ("block_i", "block_b", "block_batch")
        }
        return kops.fused_mttkrp_batched(x, list(factors), n, **kw)
    if method == "matrix_free":
        from repro.kernels import ops as kops  # lazy: kernels import pallas

        kw = {
            k: int(v)
            for k, v in (tiles or {}).items()
            if k in ("block_i", "block_r", "block_batch")
        }
        return kops.matrix_free_mttkrp_batched(x, list(factors), n, **kw)

    def one(xb, *fb):
        return mttkrp(xb, list(fb), n, method=method, tiles=tiles)

    return jax.vmap(one)(x, *factors)


def mttkrp_flops(
    shape: Sequence[int],
    rank: int,
    n: int,
    *,
    dtype=None,
    itemsize: float | None = None,
    batch: int = 1,
) -> dict[str, float]:
    """Analytic flop/byte model per algorithm (used by benchmarks/roofline
    and the ``repro.plan`` cost model).

    Returns flops for the GEMM part, the KRP part, and bytes touched for the
    tensor read -- mirrors the paper's O(IC) GEMM / O(I_{neq n} C) KRP split.
    Byte terms scale with the element size: pass ``dtype`` (anything
    ``jnp.dtype`` accepts) or ``itemsize`` directly so bf16/f64 rooflines are
    correct; the default remains 4-byte (f32) elements.  ``batch`` scales
    every flop/byte term: a batched problem has its own tensor, factors, and
    KRP per batch entry (nothing is shared across the batch).
    """
    if itemsize is None:
        import numpy as np  # jax dtypes (incl. bfloat16 via ml_dtypes) resolve here

        itemsize = float(np.dtype(dtype).itemsize) if dtype is not None else 4.0
    b = float(batch)
    L, In, R = dims_split(shape, n)
    total = math.prod(shape)
    gemm = 2.0 * total * rank * b
    krp_full = float((L * R) * rank) * b  # reuse: ~1 hadamard mult per row
    krp_naive = float((L * R) * rank * max(1, len(shape) - 2)) * b
    second_step = (
        2.0 * In * rank * min(L, R) * b if 0 < n < len(shape) - 1 else 0.0
    )
    return {
        "gemm_flops": gemm,
        "krp_flops": krp_full,
        "krp_naive_flops": krp_naive,
        "second_step_flops": second_step,
        "tensor_bytes": itemsize * total * b,
        "krp_bytes": itemsize * L * R * rank * b,
        "itemsize": float(itemsize),
    }
