"""Core library: the paper's contribution (KRP, MTTKRP, CP-ALS) in JAX."""

from .cpals import CPConfig, CPState, cp_als, normalize_columns
from .krp import krp, krp_naive, krp_or_ones, krp_row_block, krp_rowwise_scan
from .mttkrp import (
    mttkrp,
    mttkrp_1step,
    mttkrp_2step,
    mttkrp_baseline,
    mttkrp_einsum,
    mttkrp_flops,
)
from .tensor_ops import (
    EINSUM_LETTERS,
    as_lir,
    cp_full,
    dims_split,
    matricize,
    matricize_multi,
    mode_letters,
    multi_ttv,
    random_factors,
    random_tensor,
    tensor_norm,
    ttm,
    ttv,
)

__all__ = [
    "CPConfig",
    "CPState",
    "EINSUM_LETTERS",
    "cp_als",
    "mode_letters",
    "normalize_columns",
    "krp",
    "krp_naive",
    "krp_or_ones",
    "krp_row_block",
    "krp_rowwise_scan",
    "mttkrp",
    "mttkrp_1step",
    "mttkrp_2step",
    "mttkrp_baseline",
    "mttkrp_einsum",
    "mttkrp_flops",
    "as_lir",
    "cp_full",
    "dims_split",
    "matricize",
    "matricize_multi",
    "multi_ttv",
    "random_factors",
    "random_tensor",
    "tensor_norm",
    "ttm",
    "ttv",
]
