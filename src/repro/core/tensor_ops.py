"""Dense-tensor layout primitives underlying the MTTKRP algorithms.

Layout convention
-----------------
The paper (Hayashi et al., 2017) linearizes tensor entries colexicographically
(first index fastest; a "generalized column-major" order).  JAX/numpy arrays
are row-major (last index fastest).  We therefore mirror the paper's algebra:
for mode ``n`` of an ``N``-way tensor with dims ``I_0 x ... x I_{N-1}`` define

    L = prod(I_k for k < n)        # paper's I_n^L  (but on the *slow* side here)
    R = prod(I_k for k > n)        # paper's I_n^R  (fast side)

and view the natural buffer as ``X3 = X.reshape(L, I_n, R)`` -- a free reshape,
no data movement.  Every statement in the paper about "contiguous row-major
I_n x I_n^L blocks" of the mode-n matricization holds here for the ``(I_n, R)``
slices ``X3[l]``; the roles of left/right swap symmetrically and we keep the
paper's left/right naming relative to *mode order*, not memory order.

``matricize`` below produces the *explicit* (copied) mode-n matricization used
only by the reorder-based baseline that the paper's algorithms beat.
"""

from __future__ import annotations

import math
from functools import reduce
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# Mode-index einsum letters shared by every contraction in the package.
# 'c' is reserved for the CP rank axis, 'z' for a kept mode in multi_ttv,
# hence both are absent from the pool.
EINSUM_LETTERS = "abdefghijklm"


def mode_letters(order: int) -> str:
    """Einsum letters for the modes of an order-``order`` tensor.

    One shared pool (rather than per-module copies) so the supported-order
    limit is enforced in one place instead of silently truncating.
    """
    if not 0 < order <= len(EINSUM_LETTERS):
        raise ValueError(
            f"tensor order {order} outside supported range 1..{len(EINSUM_LETTERS)} "
            "('c' is reserved for the CP rank axis, 'z' for the kept mode)"
        )
    return EINSUM_LETTERS[:order]


def dims_split(shape: Sequence[int], n: int) -> tuple[int, int, int]:
    """Return ``(L, I_n, R)`` for mode ``n`` of ``shape`` (see module docstring)."""
    if not 0 <= n < len(shape):
        raise ValueError(f"mode {n} out of range for order-{len(shape)} tensor")
    L = math.prod(shape[:n]) if n > 0 else 1
    R = math.prod(shape[n + 1 :]) if n < len(shape) - 1 else 1
    return L, int(shape[n]), R


def as_lir(x: Array, n: int) -> Array:
    """Free (copy-less) view of ``x`` as ``(L, I_n, R)`` for mode ``n``."""
    L, In, R = dims_split(x.shape, n)
    return x.reshape(L, In, R)


def matricize(x: Array, n: int) -> Array:
    """Explicit mode-n matricization ``X_(n)`` of shape ``(I_n, I_neq_n)``.

    Column order is the row-major linearization of the remaining modes in
    their original order -- matching the KRP ordering of :mod:`repro.core.krp`.
    This *copies* (a transpose); it exists to implement the paper's baseline
    ("reorder then one GEMM"), which Algs. 2-4 are designed to avoid.
    """
    L, In, R = dims_split(x.shape, n)
    return jnp.moveaxis(x.reshape(L, In, R), 1, 0).reshape(In, L * R)


def matricize_multi(x: Array, n: int) -> Array:
    """Generalized matricization ``X_(0:n)`` of shape ``(I_0*...*I_n, rest)``.

    In our row-major mirror this is a free reshape (the paper's statement
    "X_(0:n) is column-major in memory" maps to "the row block is the slow
    axis"), which is what makes the 2-step partial MTTKRP a single GEMM.
    """
    shape = x.shape
    rows = math.prod(shape[: n + 1])
    return x.reshape(rows, -1)


def ttv(x: Array, v: Array, n: int) -> Array:
    """Tensor-times-vector along mode ``n``: contracts ``I_n`` away."""
    L, In, R = dims_split(x.shape, n)
    if v.shape != (In,):
        raise ValueError(f"vector shape {v.shape} != ({In},)")
    out = jnp.einsum("lir,i->lr", x.reshape(L, In, R), v)
    new_shape = x.shape[:n] + x.shape[n + 1 :]
    return out.reshape(new_shape)


def ttm(x: Array, m: Array, n: int) -> Array:
    """Tensor-times-matrix along mode ``n``:  Y_(n) = M^T X_(n).

    ``m`` has shape ``(I_n, J)``; the result has mode-n dimension ``J``.
    """
    L, In, R = dims_split(x.shape, n)
    if m.shape[0] != In:
        raise ValueError(f"matrix rows {m.shape[0]} != mode dim {In}")
    out = jnp.einsum("lir,ij->ljr", x.reshape(L, In, R), m)
    new_shape = x.shape[:n] + (m.shape[1],) + x.shape[n + 1 :]
    return out.reshape(new_shape)


def multi_ttv(t: Array, factors: Sequence[Array], cols_last: bool = True) -> Array:
    """The paper's *multi-TTV* (2nd step of Alg. 4).

    ``t`` is an ``(M+1)``-way tensor whose last axis is the CP-rank axis ``C``
    (the output of a partial MTTKRP, reshaped).  For each column ``c``, the
    subtensor ``t[..., c]`` is contracted with column ``c`` of every factor in
    ``factors`` (each ``(I_k, C)``), leaving exactly one uncontracted mode.
    Returns the ``(I_keep, C)`` MTTKRP result.
    """
    order = t.ndim - 1
    if len(factors) != order - 1:
        raise ValueError("need order-1 factor matrices (one mode stays)")
    # Contract the leading len(factors) modes; the kept mode is the last
    # non-rank axis.  einsum with a shared 'c' index implements the per-column
    # TTVs of Alg. 4 lines 7-9 / 13-15 as one batched contraction.
    # order-1 letters for the contracted modes ('z' names the kept mode)
    letters = mode_letters(order - 1) if order > 1 else ""
    spec_t = letters + "z" + "c"
    spec_fs = [let + "c" for let in letters]
    return jnp.einsum(",".join([spec_t] + spec_fs) + "->zc", t, *factors)


def tensor_norm(x: Array, *, batched: bool = False) -> Array:
    """Frobenius norm of a dense tensor.

    With ``batched=True`` the leading axis is a batch of tensors and the
    result is the per-tensor norm vector of shape ``(B,)``.
    """
    sq = jnp.square(x.astype(jnp.float32))
    if batched:
        return jnp.sqrt(jnp.sum(sq, axis=tuple(range(1, x.ndim))))
    return jnp.sqrt(jnp.sum(sq))


def random_tensor(key: jax.Array, shape: Sequence[int], dtype=jnp.float32) -> Array:
    return jax.random.normal(key, tuple(shape), dtype=dtype)


def random_factors(
    key: jax.Array, shape: Sequence[int], rank: int, dtype=jnp.float32, *, batch: int = 1
) -> list[Array]:
    """Per-mode Gaussian factors ``(I_k, C)`` -- or ``(batch, I_k, C)`` when
    ``batch > 1`` (each batch entry gets independent randomness)."""
    keys = jax.random.split(key, len(shape))
    lead = (int(batch),) if batch > 1 else ()
    return [
        jax.random.normal(k, lead + (int(dim), rank), dtype=dtype)
        for k, dim in zip(keys, shape)
    ]


def cp_full(weights: Array | None, factors: Sequence[Array]) -> Array:
    """Densify a CP model  [[lambda; U_0, ..., U_{N-1}]]  (for tests/fit checks)."""
    rank = factors[0].shape[1]
    if weights is None:
        weights = jnp.ones((rank,), factors[0].dtype)
    letters = mode_letters(len(factors))
    spec = ",".join(["c"] + [let + "c" for let in letters]) + "->" + letters
    return jnp.einsum(spec, weights, *factors)


def linear_index(multi_index: Sequence[int], shape: Sequence[int]) -> int:
    """Row-major linearization (last index fastest) -- mirrors paper's eq. for l."""
    return int(np.ravel_multi_index(tuple(multi_index), tuple(shape)))
