"""CP-factorized layers: the paper's technique as an LM compression hook.

A dense weight W (d_in x d_out) is a 2-way tensor; its rank-r CP model is
W ~= A @ B (A: d_in x r, B: r x d_out) with the rank-1 terms as columns --
fit here with the same CP-ALS machinery (for matrices, ALS converges to the
truncated-SVD subspace).  3-way weights (MoE expert stacks (E, d, f)) use the
full 3-way CP decomposition, whose factor updates are exactly our MTTKRP.

``cfg.cp_rank > 0`` switches models/ffn.py to the factorized parameterization
(trainable end to end); :func:`factorize_linear` / :func:`compress_ffn`
convert a trained dense checkpoint into that parameterization.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cpals import CPConfig, cp_als

Array = jax.Array


def factorize_linear(w: Array, rank: int, *, n_iters: int = 60) -> tuple[Array, Array]:
    """Rank-r CP (== low-rank) factorization of a matrix:  W ~= A @ B."""
    st = cp_als(w, CPConfig(rank=rank, n_iters=n_iters, tol=1e-7, method="auto"))
    a, b = st.factors
    return a * st.weights[None, :], b.T  # fold lambda into A


def factorize_expert_stack(w: Array, rank: int, *, n_iters: int = 60):
    """3-way CP of an (E, d_in, d_out) expert stack -> (E-, in-, out-) factors."""
    st = cp_als(w, CPConfig(rank=rank, n_iters=n_iters, tol=1e-7, method="auto"))
    e, a, b = st.factors
    return e * st.weights[None, :], a, b


def reconstruction_error(w: Array, a: Array, b: Array) -> float:
    approx = a @ b
    return float(jnp.linalg.norm(w - approx) / jnp.linalg.norm(w))


def compress_ffn(ffn_params: dict, rank: int) -> dict:
    """Dense FFN params {gate, up, down} -> CP-factorized {._a, ._b} tree
    matching models/ffn.py's cp_rank parameterization."""
    out = {}
    for name in ("gate", "up", "down"):
        if name not in ffn_params:
            continue
        a, b = factorize_linear(ffn_params[name], rank)
        out[f"{name}_a"] = a
        out[f"{name}_b"] = b
    return out
