"""Dimension-tree contraction primitives -- the paper's Sec. 6 "next step".

Phan et al. [19, Sec. III.C] avoid recomputing partial MTTKRPs across modes:
split the modes into halves L = {0..m-1}, R = {m..N-1} and compute two
X-sized partial contractions per sweep instead of N:

    T_L[i_0..i_{m-1}, c] = sum_R X * K_R[r, c]      (one GEMM, free reshape)
    T_R[i_m..i_{N-1}, c] = sum_L X * K_L[l, c]      (one GEMM, free reshape)

Every mode-n MTTKRP then reads only the small T tensor of its half (a
multi-TTV over the sibling modes).  Updating the left modes first (from T_L,
which depends only on the *right* factors) and then recomputing T_R from the
fresh left factors reproduces the EXACT standard-ALS iterates -- verified in
tests against cpals.als_sweep -- while reading X twice per sweep instead of
N times.  The paper predicts ~2x per-iteration gain for 4-way tensors.

This module holds the *numeric primitives* of that idea, generalized so the
binary two-partial split is just one point in a family: any tree over
contiguous mode ranges (Ma & Solomonik's multi-level dimension trees) is
expressible with two operations --

* :func:`partial_mttkrp_range` -- contract every mode outside ``[lo, hi)``
  of the raw tensor away (the root-level GEMM of a tree node);
* :func:`contract_from_partial` -- contract a subset of a partial tensor's
  surviving modes with their factors (an inner tree edge, or a leaf's
  multi-TTV when a single mode survives).

The tree *shapes* themselves live in :mod:`repro.plan.schedule` (the
contraction-schedule IR); :func:`dimtree_sweep` stays as the frozen
back-compat wrapper for the original binary-split sweep.
"""

from __future__ import annotations

import math
from typing import Mapping, Sequence

import jax
import jax.numpy as jnp

from .krp import krp_or_ones
from .tensor_ops import mode_letters

Array = jax.Array


def partial_mttkrp_right(x: Array, right_factors: Sequence[Array]) -> Array:
    """T_L = X contracted with the KRP of the trailing ``len(right)`` modes.

    Returns a tensor of shape  x.shape[:m] + (C,).
    """
    n_right = len(right_factors)
    c = right_factors[0].shape[1]
    m = x.ndim - n_right
    left_size = math.prod(x.shape[:m])
    k_r = krp_or_ones(list(right_factors), c, x.dtype)  # (R, C)
    t = x.reshape(left_size, -1) @ k_r
    return t.reshape(x.shape[:m] + (c,))


def partial_mttkrp_left(x: Array, left_factors: Sequence[Array]) -> Array:
    """T_R = X contracted with the KRP of the leading ``len(left)`` modes.

    Returns a tensor of shape  x.shape[m:] + (C,).
    """
    m = len(left_factors)
    c = left_factors[0].shape[1]
    right_size = math.prod(x.shape[m:])
    k_l = krp_or_ones(list(left_factors), c, x.dtype)  # (L, C)
    t = k_l.T @ x.reshape(-1, right_size)  # (C, R)
    return jnp.moveaxis(t.reshape((c,) + x.shape[m:]), 0, -1)


def partial_mttkrp_range(x: Array, factors: Sequence[Array], lo: int, hi: int) -> Array:
    """Contract every mode of ``x`` outside ``[lo, hi)`` with its factor.

    Returns the partial tensor of shape ``x.shape[lo:hi] + (C,)`` -- the
    root-level contraction of a general dimension-tree node.  The trailing
    modes ``[hi, N)`` go first through the same GEMM as
    :func:`partial_mttkrp_right` (so ``lo == 0`` reproduces it exactly, and
    ``hi == N`` reproduces :func:`partial_mttkrp_left`); a leading range is
    then contracted against its KRP along the shared rank axis.  ``factors``
    is the full mode-ordered list; entries inside ``[lo, hi)`` are ignored.
    """
    n = x.ndim
    if not 0 <= lo < hi <= n:
        raise ValueError(f"range [{lo}, {hi}) invalid for order-{n} tensor")
    if lo == 0 and hi == n:
        raise ValueError("range [0, N) contracts nothing")
    if lo == 0:
        return partial_mttkrp_right(x, list(factors[hi:]))
    if hi == n:
        return partial_mttkrp_left(x, list(factors[:lo]))
    t = partial_mttkrp_right(x, list(factors[hi:]))  # x.shape[:hi] + (C,)
    c = factors[0].shape[1]
    left_size = math.prod(x.shape[:lo])
    k_l = krp_or_ones(list(factors[:lo]), c, x.dtype)  # (L, C)
    t3 = t.reshape(left_size, -1, c)
    out = jnp.einsum("lmc,lc->mc", t3, k_l)
    return out.reshape(x.shape[lo:hi] + (c,))


def contract_from_partial(
    t: Array, factors: Mapping[int, Array], lo: int, hi: int, parent_lo: int
) -> Array:
    """Contract modes of a partial tensor ``t`` down to the range ``[lo, hi)``.

    ``t`` carries the parent node's surviving modes (starting at tensor mode
    ``parent_lo``) plus the trailing rank axis; ``factors`` maps each
    *tensor* mode being contracted here to its ``(I_m, C)`` factor.  The
    rank axis is shared by every term (Hadamard semantics, exactly as in the
    binary tree's multi-TTV).  With a single surviving mode this is the
    leaf-level MTTKRP of :func:`mttkrp_from_partial`.
    """
    order = t.ndim - 1
    letters = mode_letters(order)
    terms = [letters + "c"]
    args: list[Array] = [t]
    for m in sorted(factors):
        terms.append(letters[m - parent_lo] + "c")
        args.append(factors[m])
    out = "".join(letters[k - parent_lo] for k in range(lo, hi)) + "c"
    return jnp.einsum(",".join(terms) + f"->{out}", *args)


def mttkrp_from_partial(t: Array, siblings: Sequence[Array], pos: int) -> Array:
    """MTTKRP for one mode of a half from its partial tensor ``t``.

    ``t``: (I_s0, ..., I_sk, C) -- the half's modes plus the rank axis;
    ``siblings``: factors of the half's other modes (in order, skipping pos).
    """
    order = t.ndim - 1
    letters = mode_letters(order)
    terms = [letters + "c"]
    args: list[Array] = [t]
    si = 0
    for k in range(order):
        if k == pos:
            continue
        terms.append(letters[k] + "c")
        args.append(siblings[si])
        si += 1
    return jnp.einsum(",".join(terms) + f"->{letters[pos]}c", *args)


def dimtree_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: Array,
    *,
    normalize: bool = True,
    split: int | None = None,
):
    """One full ALS sweep via the dimension tree; same signature contract as
    cpals.als_sweep (returns (factors, weights, fit)) and identical iterates.

    Back-compat wrapper: builds the ``strategy='dimtree'`` plan and runs the
    single shared sweep engine on a LocalExecutor.
    """
    from repro import plan as planlib

    return planlib.legacy_sweep(
        x, factors, weights, norm_x, it,
        strategy="dimtree", normalize=normalize, split=split,
    )
