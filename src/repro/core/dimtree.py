"""Dimension-tree CP-ALS sweep -- the paper's Sec. 6 "natural next step".

Phan et al. [19, Sec. III.C] avoid recomputing partial MTTKRPs across modes:
split the modes into halves L = {0..m-1}, R = {m..N-1} and compute two
X-sized partial contractions per sweep instead of N:

    T_L[i_0..i_{m-1}, c] = sum_R X * K_R[r, c]      (one GEMM, free reshape)
    T_R[i_m..i_{N-1}, c] = sum_L X * K_L[l, c]      (one GEMM, free reshape)

Every mode-n MTTKRP then reads only the small T tensor of its half (a
multi-TTV over the sibling modes).  Updating the left modes first (from T_L,
which depends only on the *right* factors) and then recomputing T_R from the
fresh left factors reproduces the EXACT standard-ALS iterates -- verified in
tests against cpals.als_sweep -- while reading X twice per sweep instead of
N times.  The paper predicts ~2x per-iteration gain for 4-way tensors; the
dry-run byte counts in EXPERIMENTS.md SPerf confirm it at pod scale.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .krp import krp_or_ones
from .tensor_ops import mode_letters

Array = jax.Array


def partial_mttkrp_right(x: Array, right_factors: Sequence[Array]) -> Array:
    """T_L = X contracted with the KRP of the trailing ``len(right)`` modes.

    Returns a tensor of shape  x.shape[:m] + (C,).
    """
    n_right = len(right_factors)
    c = right_factors[0].shape[1]
    m = x.ndim - n_right
    left_size = math.prod(x.shape[:m])
    k_r = krp_or_ones(list(right_factors), c, x.dtype)  # (R, C)
    t = x.reshape(left_size, -1) @ k_r
    return t.reshape(x.shape[:m] + (c,))


def partial_mttkrp_left(x: Array, left_factors: Sequence[Array]) -> Array:
    """T_R = X contracted with the KRP of the leading ``len(left)`` modes.

    Returns a tensor of shape  x.shape[m:] + (C,).
    """
    m = len(left_factors)
    c = left_factors[0].shape[1]
    right_size = math.prod(x.shape[m:])
    k_l = krp_or_ones(list(left_factors), c, x.dtype)  # (L, C)
    t = k_l.T @ x.reshape(-1, right_size)  # (C, R)
    return jnp.moveaxis(t.reshape((c,) + x.shape[m:]), 0, -1)


def mttkrp_from_partial(t: Array, siblings: Sequence[Array], pos: int) -> Array:
    """MTTKRP for one mode of a half from its partial tensor ``t``.

    ``t``: (I_s0, ..., I_sk, C) -- the half's modes plus the rank axis;
    ``siblings``: factors of the half's other modes (in order, skipping pos).
    """
    order = t.ndim - 1
    letters = mode_letters(order)
    terms = [letters + "c"]
    args: list[Array] = [t]
    si = 0
    for k in range(order):
        if k == pos:
            continue
        terms.append(letters[k] + "c")
        args.append(siblings[si])
        si += 1
    return jnp.einsum(",".join(terms) + f"->{letters[pos]}c", *args)


def dimtree_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: Array,
    *,
    normalize: bool = True,
    split: int | None = None,
):
    """One full ALS sweep via the dimension tree; same signature contract as
    cpals.als_sweep (returns (factors, weights, fit)) and identical iterates.

    Back-compat wrapper: builds the ``strategy='dimtree'`` plan and runs the
    single shared sweep engine on a LocalExecutor.
    """
    from repro import plan as planlib

    return planlib.legacy_sweep(
        x, factors, weights, norm_x, it,
        strategy="dimtree", normalize=normalize, split=split,
    )
