"""CP-ALS driver built on the MTTKRP kernels (paper Sec. 2.2 / Sec. 5.3.3).

Per mode-n update (alternating least squares):
    M   = MTTKRP(X, {U_k}, n)                      (the bottleneck; Algs. 2-4)
    H   = *_{k != n} (U_k^T U_k)                   (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda

Fit is tracked with the standard factored identity (no residual tensor):
    ||X - Y||^2 = ||X||^2 - 2 <X, Y> + ||Y||^2
    <X, Y>      = sum(M_last * (U_last * lambda))   (reuses the last MTTKRP)
    ||Y||^2     = lambda^T ( *_k U_k^T U_k ) lambda

The whole sweep (all N modes) is one jitted function; the mode loop is a
static Python unroll (each mode has a different shape).  The MTTKRP method is
selectable per the paper's recommendation (1-step external / 2-step internal)
via ``method='auto'``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .mttkrp import Method, mttkrp
from .tensor_ops import random_factors, tensor_norm

Array = jax.Array


@dataclass
class CPState:
    factors: list[Array]
    weights: Array  # lambda, shape (C,)
    fit: Array  # scalar in [.., 1]
    it: int = 0


@dataclass
class CPConfig:
    rank: int
    n_iters: int = 50
    tol: float = 1.0e-5
    method: Method = "auto"
    seed: int = 0
    normalize: bool = True
    track_fit: bool = True


def grams(factors: Sequence[Array]) -> list[Array]:
    return [u.T @ u for u in factors]


def hadamard_except(gs: Sequence[Array], n: int) -> Array:
    out = None
    for k, g in enumerate(gs):
        if k == n:
            continue
        out = g if out is None else out * g
    assert out is not None
    return out


def fit_from_last_mttkrp(
    gs: Sequence[Array],
    weights: Array,
    m_last: Array,
    last_factor: Array,
    norm_x: Array,
) -> Array:
    """Fit via the factored identity, reusing the final mode's MTTKRP:
    ||X - Y||^2 = ||X||^2 - 2 <X, Y> + ||Y||^2  with
    <X, Y> = sum(M_last * (U_last * lambda)) and
    ||Y||^2 = lambda^T ( *_k U_k^T U_k ) lambda."""
    n_modes = len(gs)
    full_h = gs[-1] * hadamard_except(gs, n_modes - 1)
    norm_y_sq = jnp.einsum("c,cd,d->", weights, full_h, weights)
    inner = jnp.sum(m_last * (last_factor * weights[None, :]))
    resid_sq = jnp.maximum(norm_x**2 - 2.0 * inner + norm_y_sq, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / norm_x


def _normalize_columns(u: Array, it: int) -> tuple[Array, Array]:
    """Column norms -> lambda.  First sweep uses 2-norm, later sweeps use
    max(1, norm) (the Tensor Toolbox convention that keeps lambdas stable)."""
    norms = jnp.linalg.norm(u, axis=0)
    norms = jnp.where(it == 0, norms, jnp.maximum(norms, 1.0))
    return u / norms[None, :], norms


def als_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: int,
    method: Method,
    normalize: bool,
) -> tuple[list[Array], Array, Array]:
    """One full ALS sweep over all modes; returns (factors, weights, fit)."""
    n_modes = len(factors)
    gs = grams(factors)
    m_last = None
    for n in range(n_modes):
        m = mttkrp(x, factors, n, method=method)
        h = hadamard_except(gs, n)
        # Solve U H = M  via pinv on the C x C Gram-Hadamard (paper Sec. 2.2).
        u = m @ jnp.linalg.pinv(h)
        if normalize:
            u, norms = _normalize_columns(u, it)
            weights = norms
        factors = list(factors)
        factors[n] = u
        gs[n] = u.T @ u
        m_last = m
    # Fit from the last MTTKRP (standard trick; avoids forming the model).
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], norm_x)
    return factors, weights, fit


def cp_als(
    x: Array,
    config: CPConfig,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
) -> CPState:
    """Run CP-ALS.  Returns the final CPState; per-iteration times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them."""
    key = jax.random.PRNGKey(config.seed)
    factors = init_factors or random_factors(key, x.shape, config.rank, x.dtype)
    weights = jnp.ones((config.rank,), x.dtype)
    norm_x = tensor_norm(x).astype(x.dtype)

    sweep = jax.jit(
        partial(als_sweep, method=config.method, normalize=config.normalize),
        static_argnames=(),
    )

    fit_prev = -jnp.inf
    fit = jnp.asarray(0.0, x.dtype)
    it = 0
    for it in range(config.n_iters):
        t0 = time.perf_counter()
        factors, weights, fit = sweep(x, factors, weights, norm_x, it)
        fit = jax.block_until_ready(fit)
        dt = time.perf_counter() - t0
        if callback is not None:
            callback(it, float(fit), dt)
        if config.track_fit and abs(float(fit) - float(fit_prev)) < config.tol:
            break
        fit_prev = fit
    return CPState(factors=factors, weights=weights, fit=fit, it=it + 1)
