"""CP-ALS entry points + the shared per-update algebra (paper Sec. 2.2).

Per mode-n update (alternating least squares):
    M   = MTTKRP(X, {U_k}, n)                      (the bottleneck; Algs. 2-4)
    H   = *_{k != n} (U_k^T U_k)                   (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda

Fit is tracked with the standard factored identity (no residual tensor):
    ||X - Y||^2 = ||X||^2 - 2 <X, Y> + ||Y||^2
    <X, Y>      = sum(M_last * (U_last * lambda))   (reuses the last MTTKRP)
    ||Y||^2     = lambda^T ( *_k U_k^T U_k ) lambda

The sweep itself lives in ONE place -- :func:`repro.plan.sweep.als_sweep` --
driven by a ``SweepPlan`` (per-mode algorithm choice from the analytic cost
model) and an ``Executor`` (local or sharded).  ``als_sweep`` / ``cp_als``
below are thin back-compat wrappers that build the plan for the old
``method=`` argument; this module keeps the small algebra helpers
(:func:`grams`, :func:`hadamard_except`, :func:`fit_from_last_mttkrp`,
:func:`normalize_columns`) the engine imports.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .mttkrp import Method

Array = jax.Array


@dataclass
class CPState:
    factors: list[Array]
    weights: Array  # lambda, shape (C,) -- or (B, C) for batched problems
    fit: Array  # scalar in [.., 1] -- or shape (B,) for batched problems
    it: int = 0
    # Exact (re-materializing) sweeps executed when the run used pairwise
    # perturbation (Problem.pp_tol > 0); None for classic exact-only runs.
    pp_exact_sweeps: int | None = None


@dataclass
class CPConfig:
    rank: int
    n_iters: int = 50
    tol: float = 1.0e-5
    method: Method = "auto"
    seed: int = 0
    normalize: bool = True
    track_fit: bool = True


def grams(factors: Sequence[Array]) -> list[Array]:
    # rank-polymorphic: (I, C) -> (C, C), and (B, I, C) -> (B, C, C); for the
    # unbatched 2-D case swapaxes @ is exactly u.T @ u
    return [jnp.swapaxes(u, -1, -2) @ u for u in factors]


def hadamard_except(gs: Sequence[Array], n: int) -> Array:
    out = None
    for k, g in enumerate(gs):
        if k == n:
            continue
        out = g if out is None else out * g
    assert out is not None
    return out


def fit_from_last_mttkrp(
    gs: Sequence[Array],
    weights: Array,
    m_last: Array,
    last_factor: Array,
    norm_x: Array,
) -> Array:
    """Fit via the factored identity, reusing the final mode's MTTKRP:
    ||X - Y||^2 = ||X||^2 - 2 <X, Y> + ||Y||^2  with
    <X, Y> = sum(M_last * (U_last * lambda)) and
    ||Y|| ^2 = lambda^T ( *_k U_k^T U_k ) lambda.

    Rank-polymorphic: with batched arguments (leading ``B`` axis on every
    operand, ``norm_x`` of shape ``(B,)``) the return is the per-problem fit
    vector ``(B,)``; unbatched it stays the classic scalar."""
    n_modes = len(gs)
    full_h = gs[-1] * hadamard_except(gs, n_modes - 1)
    norm_y_sq = jnp.einsum("...c,...cd,...d->...", weights, full_h, weights)
    inner = jnp.sum(
        m_last * (last_factor * weights[..., None, :]), axis=(-2, -1)
    )
    resid_sq = jnp.maximum(norm_x**2 - 2.0 * inner + norm_y_sq, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / norm_x


def normalize_columns(u: Array, it: int) -> tuple[Array, Array]:
    """Column norms -> lambda.  First sweep uses 2-norm, later sweeps use
    max(1, norm) (the Tensor Toolbox convention that keeps lambdas stable).
    Rank-polymorphic: norms are taken over the row axis (``-2``), so a
    batched ``(B, I, C)`` factor yields ``(B, C)`` lambdas."""
    norms = jnp.linalg.norm(u, axis=-2)
    norms = jnp.where(it == 0, norms, jnp.maximum(norms, 1.0))
    return u / norms[..., None, :], norms


# Historical private name; dimtree.py and dist_mttkrp.py used to import it.
_normalize_columns = normalize_columns


def als_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: int,
    method: Method,
    normalize: bool,
) -> tuple[list[Array], Array, Array]:
    """One full ALS sweep over all modes; returns (factors, weights, fit).

    Back-compat wrapper: builds the :class:`repro.plan.SweepPlan` for
    ``method`` and runs the single shared sweep engine on a LocalExecutor.
    """
    from repro import plan as planlib

    return planlib.legacy_sweep(
        x, factors, weights, norm_x, it, strategy=method, normalize=normalize
    )


def cp_als(
    x: Array,
    config: CPConfig,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
) -> CPState:
    """Run CP-ALS.  Returns the final CPState; per-iteration times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them.

    Back-compat wrapper over the single :func:`repro.plan.cp_als` driver.
    """
    from repro import plan as planlib

    problem = planlib.Problem.from_tensor(x, config.rank)
    sweep_plan = planlib.plan_sweep(
        problem, strategy=config.method, normalize=config.normalize
    )
    return planlib.cp_als(
        x,
        sweep_plan,
        n_iters=config.n_iters,
        tol=config.tol,
        seed=config.seed,
        track_fit=config.track_fit,
        init_factors=init_factors,
        callback=callback,
    )
