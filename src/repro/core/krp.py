"""Khatri-Rao product (KRP) algorithms -- paper Algorithm 1 and variants.

Row convention (matches the paper's row-wise definition): for
``K = krp([U_0, ..., U_{Z-1}])`` with ``U_z`` of shape ``(J_z, C)``,

    K[j, :] = U_0[j_0, :] * U_1[j_1, :] * ... * U_{Z-1}[j_{Z-1}, :]

where ``j`` is the row-major linearization of ``(j_0, ..., j_{Z-1})`` (first
factor slowest) -- exactly the paper's ``K(j,:) = A(a,:)*B(b,:)*C(c,:)`` with
``j = a*I_B*I_C + b*I_C + c``.

Three implementations:

* :func:`krp` -- the *reuse* algorithm (Alg. 1).  The sequential algorithm
  caches ``Z-2`` partial Hadamard products so each output row costs ~one
  Hadamard product.  The TPU-native vectorization of that idea is a left fold:
  every intermediate ``K_partial = U_0 (.) ... (.) U_z`` is computed exactly
  once and reused for all ``prod(J_{z+1}..)`` extensions -- the fold level
  *is* Alg. 1's ``P`` matrix, materialized batched instead of row-by-row.
  Total work ~= one Hadamard product per output row (geometric sum), the same
  flop count as Alg. 1.

* :func:`krp_naive` -- the paper's "Naive" comparator: every output row pays
  ``Z-1`` Hadamard products (vectorized as ``Z`` full-size gathers + ``Z-1``
  full-size multiplies), no reuse.

* :func:`krp_rowwise_scan` -- a literal port of Alg. 1's loop (multi-index
  increment + partial-product update via masked recompute), kept for fidelity
  tests and as the reference for the row-block-parallel decomposition: a
  thread/device starting at row ``s`` re-initializes ``(ell, P)`` from ``s``
  (Sec. 4.1.2) -- see :func:`krp_row_block`, which computes an arbitrary
  contiguous row block independently and is the building block both of the
  paper's parallel KRP and of our Pallas fused-MTTKRP tiles.
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def _check(mats: Sequence[Array]) -> int:
    if len(mats) == 0:
        raise ValueError("KRP of zero matrices is undefined here; see krp_or_ones")
    cols = {int(m.shape[1]) for m in mats}
    if len(cols) != 1:
        raise ValueError(f"all factors must share the column count, got {cols}")
    return cols.pop()


def krp(mats: Sequence[Array]) -> Array:
    """Reuse-based KRP (vectorized Algorithm 1).  Shape ``(prod J_z, C)``."""
    _check(mats)
    out = mats[0]
    for u in mats[1:]:
        # (J_partial, 1, C) * (1, J_z, C) -> flatten: each partial row is the
        # cached Hadamard prefix, reused J_z times (Alg. 1's P-matrix reuse).
        out = (out[:, None, :] * u[None, :, :]).reshape(-1, u.shape[1])
    return out


def krp_naive(mats: Sequence[Array]) -> Array:
    """No-reuse KRP: Z full-size row gathers + Z-1 full-size Hadamards."""
    c = _check(mats)
    dims = [int(m.shape[0]) for m in mats]
    rows = math.prod(dims)
    grids = jnp.meshgrid(*[jnp.arange(d) for d in dims], indexing="ij")
    out = jnp.ones((rows, c), mats[0].dtype)
    for u, g in zip(mats, grids):
        out = out * u[g.reshape(-1)]
    return out


def krp_or_ones(mats: Sequence[Array], cols: int, dtype=jnp.float32) -> Array:
    """KRP that degenerates to a ``(1, C)`` row of ones for an empty factor set.

    This is the convention that makes mode-0 / mode-(N-1) MTTKRP (the paper's
    "external modes", where one of K_L / K_R is empty) fall out of the same
    code path.
    """
    if len(mats) == 0:
        return jnp.ones((1, cols), dtype)
    return krp(mats)


def krp_batched(mats: Sequence[Array]) -> Array:
    """Reuse-based KRP over a leading batch axis.

    Each ``mats[z]`` is ``(S, J_z, C)``; the result is ``(S, prod J_z, C)``
    with the same row-major linearization as :func:`krp`, per batch entry
    (each entry has its own factors, so nothing is shared across the batch).
    """
    if len(mats) == 0:
        raise ValueError("KRP of zero matrices is undefined here; see krp_or_ones_batched")
    out = mats[0]
    for u in mats[1:]:
        # (S, J_partial, 1, C) * (S, 1, J_z, C) -> flatten per batch entry
        out = (out[:, :, None, :] * u[:, None, :, :]).reshape(
            out.shape[0], -1, u.shape[2]
        )
    return out


def krp_or_ones_batched(
    mats: Sequence[Array], batch: int, cols: int, dtype=jnp.float32
) -> Array:
    """Batched :func:`krp_or_ones`: ``(S, 1, C)`` ones for an empty set."""
    if len(mats) == 0:
        return jnp.ones((batch, 1, cols), dtype)
    return krp_batched(mats)


def krp_row_block(mats: Sequence[Array], start: int, length: int) -> Array:
    """Rows ``[start, start+length)`` of the KRP, computed independently.

    This is the parallel decomposition of Sec. 4.1.2: a worker re-derives the
    multi-index for its start row and produces its contiguous block without
    touching other rows.  Vectorized: unravel the row range into per-factor
    index vectors, gather, and Hadamard-reduce.  ``start``/``length`` must be
    static (Python ints) -- appropriate for per-device/per-tile blocks.
    """
    _check(mats)
    dims = tuple(int(m.shape[0]) for m in mats)
    rows = np.arange(start, start + length)
    multi = np.unravel_index(rows, dims)  # row-major: first factor slowest
    out = mats[0][jnp.asarray(multi[0])]
    for u, idx in zip(mats[1:], multi[1:]):
        out = out * u[jnp.asarray(idx)]
    return out


def krp_rowwise_scan(mats: Sequence[Array]) -> Array:
    """Literal Algorithm 1: one row per step, multi-index + reused partials.

    Kept as a fidelity reference (the vectorized :func:`krp` is numerically
    identical).  State carried through ``lax.scan``:
      * ``ell``  -- the multi-index (length Z, int32),
      * ``p``    -- the partial-product stack; ``p[z]`` = Hadamard product of
                    ``U_0[ell_0] .. U_{z+1}[ell_{z+1}]`` (Alg. 1's P has Z-2
                    rows; we store Z-1 prefixes for uniform indexing).
    Each step emits ``p[Z-2] * U_{Z-1}[ell_{Z-1}]`` (line 5), increments the
    multi-index (line 6), and recomputes only the prefixes whose index changed
    (line 7) -- expressed as a masked fori over z for JAX-compatibility.
    """
    c = _check(mats)
    z = len(mats)
    if z < 2:
        return mats[0]
    dims = jnp.asarray([m.shape[0] for m in mats], jnp.int32)
    rows = int(np.prod([m.shape[0] for m in mats]))

    def prefixes(ell):
        p = [mats[0][ell[0]]]
        for k in range(1, z):
            p.append(p[-1] * mats[k][ell[k]])
        return jnp.stack(p)  # (Z, C); p[k] = prefix through factor k

    def increment(ell):
        # Row-major odometer: bump last index, carry leftwards.
        def body(k, state):
            ell, carry = state
            kk = z - 1 - k
            nxt = ell[kk] + carry
            wrap = nxt >= dims[kk]
            ell = ell.at[kk].set(jnp.where(wrap, 0, nxt))
            return ell, jnp.where(wrap, 1, 0).astype(jnp.int32)

        ell, _ = jax.lax.fori_loop(0, z, body, (ell, jnp.int32(1)))
        return ell

    def step(state, _):
        ell, p = state
        row = p[z - 1]  # == p[z-2-th partial] * U_{Z-1}[ell_{Z-1}]
        new_ell = increment(ell)
        changed = new_ell != ell
        # update(P): recompute prefixes from the leftmost changed position on.
        # (Cheap amortized: index k changes once per prod(J_{k+1}..) rows.)
        new_p = prefixes(new_ell)
        keep = jnp.cumprod(jnp.where(changed, 0, 1))[:, None]  # 1 until first change
        p = jnp.where(keep.astype(bool), p, new_p)
        return (new_ell, p), row

    ell0 = jnp.zeros((z,), jnp.int32)
    (_, _), out = jax.lax.scan(step, (ell0, prefixes(ell0)), None, length=rows)
    return out.astype(mats[0].dtype).reshape(rows, c)
