"""Subpackage."""
