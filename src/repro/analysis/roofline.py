"""Roofline analysis from compiled dry-run artifacts (brief: ROOFLINE ANALYSIS).

Three terms per (arch x shape x mesh), all in seconds, from the SPMD-partitioned
per-device module:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = collective_operand_bytes_per_chip / ICI_BW

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
from parsing ``compiled.as_text()`` (operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, per the brief).

While-loop correction: ``lax.scan`` bodies (layer stack, grad accumulation,
chunked attention) appear ONCE in the HLO although they execute `trip` times;
cost_analysis and static parsing undercount them.  The dry-run therefore also
compiles L=1 and L=2 *unrolled* probe variants and we extrapolate linearly:
``v(L) = v(1) + (L-1) * (v(2) - v(1))`` -- exact for quantities linear in
depth (flops, bytes, collectives all are).  Loop-built models (whisper,
recurrentgemma) are already unrolled and need no correction.

Hardware constants (TPU v5e target, per brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI (treated as the per-chip collective drain rate; the
parsed bytes are per-chip since the partitioned module is per-device).
"""

from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
# Nominal inter-node (DCN / host network) bandwidth per device, for two-level
# meshes: 4x slower than the intra-node ICI links, which is what makes the
# hierarchical psum (reduce-scatter on ICI, cross-node exchange on the 1/k
# shard, all-gather back) worth its extra intra-node hops.
DCN_BW = 12.5e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# "%name = type[...]... kind(" or "kind-start(" -- scheduled HLO form
_COLL_RE = re.compile(
    r"(%\S+)\s+=\s+(\S+)\s+(" + "|".join(_COLL_KINDS) + r")(?:-start)?\("
)
_DEF_RE = re.compile(r"^\s+(%[\w.\-]+)\s+=\s+([a-z0-9]+)\[([0-9,]*)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


# numpy-spelled dtypes that np.dtype() cannot resolve without ml_dtypes
_DTYPE_NAME_BYTES = {"bfloat16": 2, "float8_e4m3fn": 1, "float8_e5m2": 1}


def dtype_itemsize(dtype) -> int:
    """Bytes per element from an HLO dtype name ('bf16'), a numpy-style name
    ('bfloat16'), or anything ``np.dtype`` accepts (numpy/jax dtypes)."""
    if isinstance(dtype, str):
        if dtype in _DTYPE_BYTES:
            return _DTYPE_BYTES[dtype]
        if dtype in _DTYPE_NAME_BYTES:
            return _DTYPE_NAME_BYTES[dtype]
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError as e:
        raise ValueError(f"cannot resolve itemsize for dtype {dtype!r}") from e


def mttkrp_roofline(
    shape,
    rank: int,
    n: int,
    *,
    dtype="f32",
    peak_flops: float = PEAK_FLOPS,
    hbm_bw: float = HBM_BW,
) -> dict:
    """Analytic single-device roofline *bound* for one mode-``n`` MTTKRP.

    Converts the flop/byte terms of :func:`repro.core.mttkrp.mttkrp_flops`
    (dtype-aware, so bf16/f64 rooflines differ) into seconds against the
    hardware constants above, assuming perfect compute/memory overlap
    (``max`` of the two terms) and no algorithm-specific intermediates.
    Used by ``benchmarks/roofline_report`` as the optimistic bound next to
    measurements.  Note this is a different quantity from
    ``repro.plan.cost.ModeCost.predicted_s``, which is an *additive*
    no-overlap cost including per-algorithm intermediate and collective
    traffic -- built for comparing algorithms, not bounding one.
    """
    from repro.core.mttkrp import mttkrp_flops  # local: keep this module jax-light

    itemsize = dtype_itemsize(dtype)
    f = mttkrp_flops(shape, rank, n, itemsize=itemsize)
    # charge the cheapest real algorithm's extra terms, not both: external
    # modes must form the full KRP (1-step), internal modes take the 2-step
    # path (second-step multi-TTV + its intermediate instead of the KRP)
    internal = f["second_step_flops"] > 0
    flops = f["gemm_flops"] + (f["second_step_flops"] if internal else f["krp_flops"])
    intermediate = f["second_step_flops"] / 2.0 * itemsize  # In*min(L,R)*C elems
    bytes_ = f["tensor_bytes"] + (intermediate if internal else f["krp_bytes"])
    compute_s, memory_s = flops / peak_flops, bytes_ / hbm_bw
    return {
        "flops": flops,
        "bytes": bytes_,
        "itemsize": f["itemsize"],
        "compute_s": compute_s,
        "memory_s": memory_s,
        "intensity_flops_per_byte": flops / bytes_ if bytes_ else 0.0,
        "bound": "compute" if compute_s >= memory_s else "memory",
        "bound_s": max(compute_s, memory_s),
    }


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind operand-byte totals from a partitioned HLO module's text.

    Scheduled HLO prints operands by name only, so we first build a symbol
    table of instruction result shapes and resolve each collective's operand
    bytes through it (falling back to the collective's own result shape,
    which equals the operand for all-reduce).
    """
    # symbol table: instruction name -> bytes of its result
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        d = _DEF_RE.match(line)
        if d:
            sizes[d.group(1)] = _shape_bytes(d.group(2), d.group(3))

    totals = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        # operand list: from the op's '(' to the first '),' boundary
        args_part = line[m.end():].split(")", 1)[0]
        inline = _SHAPE_RE.findall(args_part)
        if inline:  # unscheduled form: shapes inline
            op_bytes = sum(_shape_bytes(dt, dims) for dt, dims in inline)
        else:
            names = _OPERAND_RE.findall(args_part)
            op_bytes = sum(sizes.get(n, 0) for n in names)
            if op_bytes == 0:  # fallback: result shape (== operand for AR)
                res = _SHAPE_RE.findall(m.group(2))
                op_bytes = sum(_shape_bytes(dt, dims) for dt, dims in res)
        totals[kind] += op_bytes
        counts[kind] += 1
    return {
        "bytes_by_kind": totals,
        "count_by_kind": counts,
        "total_bytes": sum(totals.values()),
        "total_count": sum(counts.values()),
    }


def extrapolate(v1: float, v2: float, layers: int) -> float:
    """Linear-in-depth correction from L=1 / L=2 probes."""
    return v1 + (layers - 1) * (v2 - v1)


@dataclass
class RooflineTerms:
    flops: float  # per chip
    hbm_bytes: float  # per chip
    coll_bytes: float  # per chip
    model_flops_total: float  # analytic 6ND (whole step, all chips)
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_bound_s(self) -> float:
        """Roofline-optimal step time assuming perfect overlap."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS -- how much compiled compute is useful."""
        total_hlo = self.flops * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-flops utilization at the roofline bound."""
        t = self.step_bound_s
        if not t:
            return 0.0
        return self.model_flops_total / (self.chips * PEAK_FLOPS * t)

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "coll_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_bound_s": self.step_bound_s,
            "model_flops_total": self.model_flops_total,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def terms_from_record(record: dict) -> RooflineTerms | None:
    """Build roofline terms from a dry-run JSON record (see launch/dryrun.py).

    Uses probe extrapolation when probes are present, else the full compile's
    own numbers (loop-built models).
    """
    chips = record["chips"]
    layers = record["n_layers"]
    # Grad-accumulation while body is also counted once by cost_analysis:
    # multiply by the known accum factor (slightly overcounts the once-per-
    # step optimizer/psum tail; noted in EXPERIMENTS.md).
    accum = record.get("accum_steps", 1)
    if record.get("probe1") and record.get("probe2"):
        p1, p2 = record["probe1"], record["probe2"]
        flops = extrapolate(p1["flops"], p2["flops"], layers) * accum
        hbm = extrapolate(p1["bytes"], p2["bytes"], layers) * accum
        coll = extrapolate(p1["coll_bytes"], p2["coll_bytes"], layers) * accum
    else:
        full = record["full"]
        flops = full["flops"] * accum
        hbm = full["bytes"] * accum
        coll = full["coll_bytes"] * accum
    return RooflineTerms(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll,
        model_flops_total=record["model_flops"],
        chips=chips,
    )
