"""Analytic parameter & MODEL_FLOPS counters (roofline's "useful flops" term).

param_count derives from the ParamDef tree (single source of truth with the
actual init), so MoE expert padding etc. is counted exactly as allocated.

MODEL_FLOPS follows the brief: 6*N*D for dense training, 6*N_active*D for MoE
(N_active = non-expert params + top-k routed experts + shared experts); the
attention O(S^2) term is excluded by that convention (noted in EXPERIMENTS.md
where it matters -- prefill_32k makes it visible in the HLO/MODEL ratio).
"""

from __future__ import annotations

import math
from typing import Any

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _defs_count(defs: Any) -> int:
    import jax

    from repro.models.common import ParamDef, is_def

    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


def param_count(cfg: ModelConfig) -> int:
    from repro.models import build_model

    return _defs_count(build_model(cfg).param_defs)


def _per_expert_params(cfg: ModelConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff_expert  # gate/up/down


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token: excludes non-selected and padded experts."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    from repro.models.moe import padded_experts

    e_pad = padded_experts(cfg.n_experts)
    inactive = (e_pad - cfg.n_experts_per_tok) * _per_expert_params(cfg) * cfg.n_layers
    return total - inactive


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for one step of the given shape (whole batch)."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


def bytes_per_param(cfg: ModelConfig, training: bool) -> int:
    """fp32 master + Adam m/v when training; bf16 weights when serving."""
    return 12 if training else 2


def hbm_estimate(cfg: ModelConfig, shape: ShapeConfig, n_chips: int) -> float:
    """Rough per-chip HBM for params(+opt states), used as a sanity bound."""
    n = param_count(cfg)
    return n * bytes_per_param(cfg, shape.kind == "train") / n_chips
