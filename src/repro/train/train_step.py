"""train_step builder: mixed precision, grad accumulation, sharded lowering.

The returned step is a pure function suitable both for jit execution and for
AOT ``.lower().compile()`` in the dry-run.  Gradient accumulation runs a
``lax.scan`` over microbatches (batch must divide); gradients are averaged in
fp32.  With params FSDP+TP sharded, XLA emits all-gather-on-use for the
forward/backward and reduce-scatter for the gradients (ZeRO-3 exchange), plus
the data-parallel mean -- this is the overlap-friendly exchange pattern the
latency-hiding scheduler pipelines on real hardware.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import Model

from .optimizer import OptConfig, OptState, adamw_update

Array = jax.Array


def make_train_step(
    model: Model, opt_cfg: OptConfig, *, accum_steps: int = 1
) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params: Any, batch: dict) -> tuple[Array, dict, Any]:
        (loss, metrics), grads = jax.value_and_grad(model.loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def step(params: Any, opt_state: OptState, batch: dict):
        if accum_steps == 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            def micro(b, i):
                return jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum_steps), x.shape[0] // accum_steps, 0
                    ),
                    b,
                )

            def body(carry, i):
                acc_g, acc_l = carry
                loss_i, _, g_i = grads_of(params, micro(batch, i))
                acc_g = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / accum_steps, acc_g, g_i
                )
                return (acc_g, acc_l + loss_i / accum_steps), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), jnp.arange(accum_steps)
            )
            metrics = {"ce": loss}
        params, opt_state, opt_stats = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_stats)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step
