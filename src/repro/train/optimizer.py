"""AdamW with warmup+cosine schedule, global-norm clipping, sharded states.

Hand-rolled (no optax in the environment).  Optimizer state is a pytree of
fp32 (m, v) mirroring the params; under jit the states inherit the params'
shardings (ZeRO-style: FSDP-sharded params give FSDP-sharded Adam moments).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: Array
    m: Any
    v: Any


def init_opt_state(params: Any) -> OptState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())


def schedule(cfg: OptConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: OptConfig
) -> tuple[Any, OptState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
