"""Fault-tolerant training loop: checkpoint/restart, failure injection,
straggler watchdog, deterministic resume.

Recovery model (maps to a real fleet):
  * every ``ckpt_every`` steps the full (params, opt_state, step) is saved
    asynchronously (atomic rename; keep-k);
  * any exception inside a step (device loss, preemption, injected fault)
    rolls back to the latest complete checkpoint and replays from there --
    the data pipeline is index-deterministic so replayed batches are
    identical; ``max_failures`` bounds the retry budget;
  * a wall-time watchdog flags steps slower than ``straggler_factor`` x the
    running median -- on a real pod this feeds the coordinator's slow-host
    eviction; here it is recorded in the metrics log (and tested by
    injecting a slow step).
Elastic restarts (different mesh after failure) go through
CheckpointManager.restore(mesh=..., specs=...) -- exercised in tests.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.models import Model

from .optimizer import OptConfig, init_opt_state
from .train_step import make_train_step

log = logging.getLogger("repro.train")


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 2
    max_failures: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    accum_steps: int = 1


@dataclass
class LoopResult:
    step: int
    metrics_history: list[dict] = field(default_factory=list)
    failures: int = 0
    straggler_steps: list[int] = field(default_factory=list)


def train_loop(
    model: Model,
    data_source: Any,
    opt_cfg: OptConfig,
    loop_cfg: LoopConfig,
    *,
    params: Any = None,
    fault_hook: Callable[[int], None] | None = None,
    jit_kwargs: dict | None = None,
) -> LoopResult:
    """Run training with checkpoint/restart semantics.

    ``fault_hook(step)`` (tests) may raise to simulate a failure or sleep to
    simulate a straggler; it runs inside the protected region.
    """
    mgr = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep)
    step_fn = make_train_step(model, opt_cfg, accum_steps=loop_cfg.accum_steps)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1), **(jit_kwargs or {}))

    def fresh_state():
        p = params if params is not None else model.init(jax.random.PRNGKey(0))
        return p, init_opt_state(p)

    result = LoopResult(step=0)
    latest = mgr.latest_step()
    if latest is not None:
        template = jax.tree.map(lambda x: x, _state_template(model, params))
        (p, opt_state), _ = mgr.restore(template, latest)
        step = latest
        log.info("restored checkpoint at step %d", step)
    else:
        p, opt_state = fresh_state()
        step = 0
        # Step-0 checkpoint: guarantees a restore point exists even if the
        # first failure precedes the first periodic save (and keeps the
        # donated-buffer invariant: we never reuse a donated initial tree).
        mgr.save(0, (p, opt_state))

    durations: list[float] = []
    while step < loop_cfg.total_steps:
        try:
            t0 = time.perf_counter()
            if fault_hook is not None:
                fault_hook(step)
            batch = data_source.batch(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            p, opt_state, metrics = step_fn(p, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}: {loss}")
            # straggler watchdog
            if len(durations) >= 5:
                med = float(np.median(durations[-20:]))
                if dt > loop_cfg.straggler_factor * med:
                    result.straggler_steps.append(step)
                    log.warning("straggler step %d: %.3fs (median %.3fs)", step, dt, med)
            durations.append(dt)
            step += 1
            result.metrics_history.append(
                {"step": step, "loss": loss, "seconds": dt}
            )
            if step % loop_cfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step, loss, dt)
            if step % loop_cfg.ckpt_every == 0 or step == loop_cfg.total_steps:
                mgr.wait()
                mgr.save_async(step, (p, opt_state))
        except Exception as e:  # noqa: BLE001 -- recovery boundary
            result.failures += 1
            log.warning("step %d failed (%s); failures=%d", step, e, result.failures)
            if result.failures > loop_cfg.max_failures:
                raise
            mgr.wait()
            latest = mgr.latest_step()
            if latest is None:
                p, opt_state = fresh_state()
                step = 0
            else:
                template = _state_template(model, params)
                (p, opt_state), _ = mgr.restore(template, latest)
                step = latest
            log.info("recovered to step %d", step)

    mgr.wait()
    result.step = step
    return result


def _state_template(model: Model, params: Any):
    p = params if params is not None else model.init(jax.random.PRNGKey(0))
    return (p, init_opt_state(p))
