"""Subpackage."""
