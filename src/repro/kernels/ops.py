"""jit'd wrappers around the Pallas kernels: padding, tiling, mode dispatch.

Public entry points:

* :func:`fused_mttkrp`   -- MTTKRP for any mode without materializing the full
                            KRP in HBM (beyond-paper; see fused_mttkrp.py).
* :func:`fused_mttkrp_batched` -- same, with a leading batch axis mapped to
                            the kernel's batch grid dimension (one launch
                            for S stacked problems).
* :func:`matrix_free_mttkrp` -- streaming matrix-free MTTKRP (no KRP at
                            all, not even partial; see matrix_free.py).
* :func:`matrix_free_mttkrp_batched` -- same, leading batch axis.
* :func:`krp_materialize`-- explicit KRP via the tiled kernel (Alg. 1).
* :func:`multi_ttv`      -- kernelized 2nd step of the 2-step algorithm.
* :func:`multi_ttv_batched` -- batched variant over a leading batch axis.
* :func:`mttkrp_2step_kernel` -- Alg. 4 with the multi-TTV step kernelized.

``multi_ttv`` / ``multi_ttv_batched`` and the matrix-free pair are frozen
aliases of the single implementations in ``multi_ttv.py`` / ``matrix_free.py``
(re-exported here so callers keep one import surface).

On non-TPU backends the kernels run in ``interpret=True`` mode (the kernel
body executes in Python on CPU) -- this container's validation path.  Real-TPU
runs additionally pad the rank axis to the 128-lane boundary.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.krp import krp_or_ones, krp_or_ones_batched
from repro.core.tensor_ops import dims_split

from ._tiling import block as _block
from ._tiling import interpret_default as _interpret
from ._tiling import on_tpu as _on_tpu
from ._tiling import pad_axis as _pad_axis
from .fused_mttkrp import fused_mttkrp_bilinear, fused_mttkrp_bilinear_batched
from .krp_kernel import krp_pair
from .matrix_free import matrix_free_mttkrp, matrix_free_mttkrp_batched
from .multi_ttv import multi_ttv, multi_ttv_batched

Array = jax.Array


def balanced_split(dims: Sequence[int]) -> int:
    """Split index minimizing |log prod(left) - log prod(right)| (>=1 each side).

    Public because the ``repro.plan`` cost model mirrors the fused kernel's
    partial-KRP split when predicting its HBM traffic.

    ``dims`` must be *mode* extents only -- never a raw ``x.shape`` that
    still carries a leading batch axis, which would skew the split (and
    hence the tile sizes) toward the batch extent.  The batched wrappers
    split on ``x.shape[1:]`` / per-factor row counts for exactly this
    reason.
    """
    best, best_val = 1, float("inf")
    total = math.prod(dims)
    acc = 1
    for i in range(1, len(dims)):
        acc *= dims[i - 1]
        val = abs(math.log(acc) - math.log(total / acc))
        if val < best_val:
            best, best_val = i, val
    return best


_balanced_split = balanced_split


@partial(jax.jit, static_argnames=("n", "block_i", "block_b", "interpret", "pad_rank_to"))
def fused_mttkrp(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    block_i: int = 128,
    block_b: int = 256,
    interpret: bool | None = None,
    pad_rank_to: int | None = None,
) -> Array:
    """MTTKRP via the fused kernel.  ``M = X_(n) . KRP(factors != n)``.

    The two partial KRPs fed to the kernel are built with the reuse fold
    (Alg. 1); the full ``L*R x C`` KRP never exists.  External modes split
    their single factor list at the log-balanced point so both kernel inputs
    stay near ``sqrt`` of the full KRP size.
    """
    factors = list(factors)
    big_n = len(factors)
    if x.ndim != big_n:
        # a batched tensor here would silently treat the batch axis as mode 0
        # and derive tiles from it; route batched inputs explicitly instead
        raise ValueError(
            f"x.ndim {x.ndim} != {big_n} factors -- for a leading batch axis "
            "use fused_mttkrp_batched"
        )
    c = factors[0].shape[1]
    interp = _interpret(interpret)
    if pad_rank_to is None and _on_tpu():
        pad_rank_to = 128

    left = factors[:n]
    right = factors[n + 1 :]
    in_dim = x.shape[n]

    if 0 < n < big_n - 1:
        pos = 1
        a_mats, b_mats = left, right
        big_l, _, big_r = dims_split(x.shape, n)
        t = x.reshape(big_l, in_dim, big_r)
    elif n == 0:
        pos = 0
        split = _balanced_split([f.shape[0] for f in right]) if len(right) > 1 else 0
        a_mats, b_mats = right[:split], right[split:]
        da = math.prod(f.shape[0] for f in a_mats) if a_mats else 1
        db = math.prod(f.shape[0] for f in b_mats)
        t = x.reshape(in_dim, da, db)
    else:  # n == N-1
        pos = 2
        split = _balanced_split([f.shape[0] for f in left]) if len(left) > 1 else 1
        a_mats, b_mats = left[:split], left[split:]
        da = math.prod(f.shape[0] for f in a_mats)
        db = math.prod(f.shape[0] for f in b_mats) if b_mats else 1
        t = x.reshape(da, db, in_dim)

    a = krp_or_ones(a_mats, c, x.dtype)
    b = krp_or_ones(b_mats, c, x.dtype)
    if pad_rank_to:
        a = _pad_axis(a, 1, pad_rank_to)
        b = _pad_axis(b, 1, pad_rank_to)

    bi = _block(in_dim, block_i)
    bb = _block(b.shape[0], block_b)
    b_axis = 1 if pos == 2 else 2  # t layout: pos0 (i,a,b), pos1 (a,i,b), pos2 (a,b,i)
    t = _pad_axis(_pad_axis(t, pos, bi), b_axis, bb)
    b = _pad_axis(b, 0, bb)
    out = fused_mttkrp_bilinear(
        t, a, b, pos=pos, block_i=bi, block_b=bb, interpret=interp
    )
    return out[:in_dim, :c].astype(x.dtype)


@partial(
    jax.jit,
    static_argnames=(
        "n", "block_i", "block_b", "block_batch", "interpret", "pad_rank_to"
    ),
)
def fused_mttkrp_batched(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    block_i: int = 128,
    block_b: int = 256,
    block_batch: int = 8,
    interpret: bool | None = None,
    pad_rank_to: int | None = None,
) -> Array:
    """Batched fused MTTKRP: ``x`` is ``(S, *shape)``, factors ``(S, I_k, C)``.

    One kernel launch covers all S stacked problems via the kernel's leading
    batch grid axis (``block_batch`` slabs); each slab forms its own KRP
    tiles in VMEM, so the per-problem KRP still never exists in HBM.  All
    reshape/split/tile arithmetic runs on the *mode* dims ``x.shape[1:]`` --
    the batch axis never participates in tile selection -- and every pad
    axis is shifted by one for the leading batch axis.
    """
    factors = list(factors)
    big_n = len(factors)
    if x.ndim != big_n + 1:
        raise ValueError(
            f"x.ndim {x.ndim} != {big_n} factors + batch axis -- for an "
            "unbatched tensor use fused_mttkrp"
        )
    s_batch = x.shape[0]
    mode_shape = x.shape[1:]  # tile choice keys on mode dims, never the batch
    c = factors[0].shape[2]
    interp = _interpret(interpret)
    if pad_rank_to is None and _on_tpu():
        pad_rank_to = 128

    left = factors[:n]
    right = factors[n + 1 :]
    in_dim = mode_shape[n]

    if 0 < n < big_n - 1:
        pos = 1
        a_mats, b_mats = left, right
        big_l, _, big_r = dims_split(mode_shape, n)
        t = x.reshape(s_batch, big_l, in_dim, big_r)
    elif n == 0:
        pos = 0
        split = balanced_split([f.shape[1] for f in right]) if len(right) > 1 else 0
        a_mats, b_mats = right[:split], right[split:]
        da = math.prod(f.shape[1] for f in a_mats) if a_mats else 1
        db = math.prod(f.shape[1] for f in b_mats)
        t = x.reshape(s_batch, in_dim, da, db)
    else:  # n == N-1
        pos = 2
        split = balanced_split([f.shape[1] for f in left]) if len(left) > 1 else 1
        a_mats, b_mats = left[:split], left[split:]
        da = math.prod(f.shape[1] for f in a_mats)
        db = math.prod(f.shape[1] for f in b_mats) if b_mats else 1
        t = x.reshape(s_batch, da, db, in_dim)

    a = krp_or_ones_batched(a_mats, s_batch, c, x.dtype)
    b = krp_or_ones_batched(b_mats, s_batch, c, x.dtype)
    if pad_rank_to:
        a = _pad_axis(a, 2, pad_rank_to)
        b = _pad_axis(b, 2, pad_rank_to)

    bi = _block(in_dim, block_i)
    bb = _block(b.shape[1], block_b)
    bs = _block(s_batch, block_batch)
    b_axis = 2 if pos == 2 else 3  # unbatched layout axes, shifted by one
    t = _pad_axis(_pad_axis(_pad_axis(t, pos + 1, bi), b_axis, bb), 0, bs)
    a = _pad_axis(a, 0, bs)
    b = _pad_axis(_pad_axis(b, 1, bb), 0, bs)
    out = fused_mttkrp_bilinear_batched(
        t, a, b, pos=pos, block_i=bi, block_b=bb, block_batch=bs,
        interpret=interp,
    )
    return out[:s_batch, :in_dim, :c].astype(x.dtype)


@partial(jax.jit, static_argnames=("block_b", "interpret"))
def krp_materialize(
    mats: Sequence[Array], *, block_b: int = 512, interpret: bool | None = None
) -> Array:
    """Explicit KRP via the tiled kernel, left-folded for Z > 2 (Alg. 1 reuse:
    each fold intermediate is a cached partial Hadamard product)."""
    mats = list(mats)
    interp = _interpret(interpret)
    out = mats[0]
    for u in mats[1:]:
        jb = u.shape[0]
        bb = _block(jb, block_b)
        u_pad = _pad_axis(u, 0, bb)
        ja = out.shape[0]
        prod = krp_pair(out, u_pad, block_b=bb, interpret=interp)
        prod = prod.reshape(ja, u_pad.shape[0], -1)[:, :jb, :]
        out = prod.reshape(ja * jb, -1)
    return out


@partial(jax.jit, static_argnames=("n", "block_i", "interpret"))
def mttkrp_2step_kernel(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    block_i: int = 256,
    interpret: bool | None = None,
) -> Array:
    """Alg. 4 with the partial MTTKRP on the MXU (plain dot) and the 2nd-step
    multi-TTV in the Pallas kernel.  Right-first ordering shown; the left
    variant transposes into the same kernel form.  jit'd with the mode /
    tile / interpret flags static so repeated calls on the same shapes reuse
    the trace instead of re-running the reshape + padding logic; ``block_i``
    is the multi-TTV kernel's (autotunable) row tile."""
    factors = list(factors)
    c = factors[0].shape[1]
    big_l, in_dim, big_r = dims_split(x.shape, n)
    left, right = factors[:n], factors[n + 1 :]
    if big_l == 1 or big_r == 1:
        return fused_mttkrp(x, factors, n, interpret=interpret)
    if big_l <= big_r:  # right-first: 2nd step contracts the smaller L
        k_r = krp_or_ones(right, c, x.dtype)
        r_t = (x.reshape(big_l * in_dim, big_r) @ k_r).reshape(big_l, in_dim, c)
        k_l = krp_or_ones(left, c, x.dtype)
        return multi_ttv(r_t, k_l, block_i=block_i, interpret=interpret)
    k_l = krp_or_ones(left, c, x.dtype)
    l_t = (k_l.T @ x.reshape(big_l, in_dim * big_r)).reshape(c, in_dim, big_r)
    k_r = krp_or_ones(right, c, x.dtype)
    # transpose (C, I, R) -> (R, I, C): same multi-TTV form over r.
    return multi_ttv(jnp.transpose(l_t, (2, 1, 0)), k_r, block_i=block_i, interpret=interpret)
