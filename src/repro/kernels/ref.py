"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.krp import krp as _krp_reuse
from repro.core.mttkrp import mttkrp_einsum

Array = jax.Array


def fused_mttkrp_ref(x: Array, factors: Sequence[Array], n: int) -> Array:
    """Oracle for kernels.ops.fused_mttkrp: the direct einsum MTTKRP."""
    return mttkrp_einsum(x, factors, n)


def bilinear_ref(t: Array, a: Array, b: Array, pos: int) -> Array:
    """Oracle for the unified bilinear form of fused_mttkrp_bilinear."""
    spec = {0: "iab,ac,bc->ic", 1: "aib,ac,bc->ic", 2: "abi,ac,bc->ic"}[pos]
    return jnp.einsum(spec, t, a, b)


def krp_ref(mats: Sequence[Array]) -> Array:
    """Oracle for kernels.ops.krp_materialize: the reuse-fold KRP."""
    return _krp_reuse(mats)


def multi_ttv_ref(t: Array, w: Array) -> Array:
    """Oracle for kernels.ops.multi_ttv:  M[i,c] = sum_l t[l,i,c] w[l,c]."""
    return jnp.einsum("lic,lc->ic", t, w)
