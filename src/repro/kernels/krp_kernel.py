"""Tiled Khatri-Rao product Pallas kernel (paper Alg. 1, parallel variant).

Materializes ``K = A (.) B`` (``(J_A*J_B, C)``) tile by tile.  The grid maps
directly onto the paper's parallel decomposition (Sec. 4.1.2): each grid step
owns a contiguous row block of the output; the "re-initialize the multi-index
from the start row" step becomes the BlockSpec index map ``(a, b)``, and the
cached partial Hadamard product is the ``(1, C)`` A-row held in VMEM while the
fast index sweeps a ``(block_b, C)`` tile -- one VPU broadcast-multiply per
output tile, i.e. ~one Hadamard multiply per output row, the same flop count
as Alg. 1's reuse scheme.

Z > 2 factors are handled in ops.py by left-folding (the fold intermediates
are exactly Alg. 1's reused partials).  Used by the 1-step MTTKRP path when an
explicit KRP is requested; the fused kernel (fused_mttkrp.py) skips the HBM
round-trip entirely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(a_ref, b_ref, o_ref):
    # (1, C) * (bb, C) -> (bb, C): the row-wise KRP definition on the VPU.
    o_ref[0, :, :] = (a_ref[0, :] * b_ref[...]).astype(o_ref.dtype)


def krp_pair(
    a: Array, b: Array, *, block_b: int, interpret: bool = False
) -> Array:
    """KRP of two matrices: out[(ja, jb), c] = a[ja, c] * b[jb, c]."""
    ja, c = a.shape
    jb, cb = b.shape
    if c != cb:
        raise ValueError("factor column counts differ")
    if jb % block_b:
        raise ValueError("J_B must be padded to the block size")
    grid = (ja, jb // block_b)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c), lambda al, bl: (al, 0)),
            pl.BlockSpec((block_b, c), lambda al, bl: (bl, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_b, c), lambda al, bl: (al, bl, 0)),
        out_shape=jax.ShapeDtypeStruct((ja, jb, c), a.dtype),
        interpret=interpret,
    )(a, b)
    return out.reshape(ja * jb, c)
