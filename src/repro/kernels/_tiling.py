"""Shared tiling/padding helpers for the Pallas kernel wrappers.

Every jit'd wrapper (ops.py, multi_ttv.py, matrix_free.py) uses the same
three decisions: interpret off-TPU, zero-pad each tiled axis to its block
multiple, and clamp requested blocks to the actual extent.  Keeping them in
one module means the kernels never import each other's wrapper modules
(no ops <-> multi_ttv <-> matrix_free cycles).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default(flag: bool | None) -> bool:
    """Interpret mode resolves to "not on TPU" unless explicitly forced."""
    return (not on_tpu()) if flag is None else flag


def pad_axis(x: Array, axis: int, mult: int) -> Array:
    """Zero-pad ``axis`` up to a multiple of ``mult``.

    ``axis`` is a raw array axis, NOT a tensor mode: batched wrappers must
    shift mode positions by one for the leading batch axis (the unbatched
    wrappers pass modes through unchanged).
    """
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def block(dim: int, target: int) -> int:
    """Largest block <= target; dims smaller than target use the dim itself."""
    return min(dim, target)
