"""Fused MTTKRP Pallas TPU kernel -- the beyond-paper contribution.

The paper's closing lesson (Sec. 6): *"Just as tensor reordering should be
avoided, future optimization of MTTKRP should avoid computing large KRPs."*
On TPU we can do exactly that: the full KRP ``K = K_L (.) K_R`` (size
``L*R x C``, the dominant memory-bound object of the 1-step algorithm) is
never written to HBM.  Instead each grid step forms a *KRP tile* in VMEM from
one row of the small partial ``A`` and a block of rows of the small partial
``B`` (one broadcast VPU multiply -- the row-wise Hadamard definition of the
KRP) and immediately feeds it to the MXU.

Computation (unified bilinear form; `pos` places the uncontracted mode):

    pos=1 (internal modes):  M[i,c] = sum_{a,b} T[a,i,b] * A[a,c] * B[b,c]
    pos=0 (mode 0):          M[i,c] = sum_{a,b} T[i,a,b] * A[a,c] * B[b,c]
    pos=2 (mode N-1):        M[i,c] = sum_{a,b} T[a,b,i] * A[a,c] * B[b,c]

where for an internal mode ``n``: ``T = x.view(L, I_n, R)``, ``A = K_L``,
``B = K_R`` (both geometrically smaller than ``K``); for external modes the
right (resp. left) factor list is split in two and ``T`` is the corresponding
free 3-D view -- so even external modes avoid the full-KRP write that the
paper's Alg. 3 pays for (their Fig. 6 shows KRP costing up to half the time).

Grid layout: ``(I_blocks, A_dim, B_blocks)`` with the two reduction dims
innermost, so each output block stays resident in VMEM across its whole
reduction (revisited-output accumulation pattern).  The output is zeroed at
the first reduction step via ``pl.when``.

TPU tiling notes (the BlockSpec shapes define the VMEM working set):
  * block_i x block_b is the MXU matmul tile -> multiples of 128 when the
    dims allow (hardware-aligned); C (CP rank, typically 10-50) is padded to
    the 128-lane boundary by the wrapper.
  * VMEM footprint per step = T-tile (bi*bb) + A-row (C) + B-tile (bb*C)
    + out (bi*C) floats -- e.g. bi=bb=256, C=128: ~0.5 MB, far under ~16 MB,
    leaving headroom for double buffering of the streamed T tiles.
  * ``a`` advances fastest among reduction steps with block size 1: the A row
    is a (1, C) VMEM vector; K-tiles are (bb, C) -- formed and consumed, never
    stored.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(t_ref, a_ref, b_ref, o_ref, *, pos: int):
    """One grid step: o += T_tile @ (A_row * B_tile)."""
    a_idx = pl.program_id(1)
    b_idx = pl.program_id(2)

    @pl.when(jnp.logical_and(a_idx == 0, b_idx == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # KRP tile, formed on the fly in VMEM (row-wise Hadamard definition):
    # (1, C) * (bb, C) -> (bb, C).  This is the object the paper materializes
    # in HBM (Alg. 2 line 2 / Alg. 3 line 15); here it lives only in VMEM.
    k_tile = a_ref[0, :] * b_ref[...]  # (bb, C)

    t = t_ref[...]
    if pos == 0:  # T block (bi, 1, bb)
        x_tile = t[:, 0, :]
    elif pos == 1:  # T block (1, bi, bb)
        x_tile = t[0, :, :]
    else:  # pos == 2: T block (1, bb, bi) -> contract over bb
        x_tile = t[0].T
    # MXU contraction of the streamed tensor tile with the in-VMEM KRP tile.
    o_ref[...] += jax.lax.dot(
        x_tile.astype(k_tile.dtype), k_tile, precision=jax.lax.Precision.HIGHEST
    ).astype(o_ref.dtype)


def _kernel_batched(t_ref, a_ref, b_ref, o_ref, *, pos: int):
    """One grid step of the batched kernel: per batch slab, o += T @ (A*B).

    Identical algebra to :func:`_kernel` with a leading batch axis on every
    ref; the MXU contraction becomes a batched ``dot_general`` (batch dim 0,
    contracting the KRP-tile rows)."""
    a_idx = pl.program_id(2)
    b_idx = pl.program_id(3)

    @pl.when(jnp.logical_and(a_idx == 0, b_idx == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-batch KRP tiles: (bt, 1, C) * (bt, bb, C) -> (bt, bb, C) -- each
    # batch entry has its own factors, so the Hadamard is per-slab
    k_tile = a_ref[:, 0, :][:, None, :] * b_ref[...]

    t = t_ref[...]
    if pos == 0:  # T block (bt, bi, 1, bb)
        x_tile = t[:, :, 0, :]
    elif pos == 1:  # T block (bt, 1, bi, bb)
        x_tile = t[:, 0, :, :]
    else:  # pos == 2: T block (bt, 1, bb, bi) -> contract over bb
        x_tile = jnp.swapaxes(t[:, 0, :, :], 1, 2)
    o_ref[...] += jax.lax.dot_general(
        x_tile.astype(k_tile.dtype),
        k_tile,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        precision=jax.lax.Precision.HIGHEST,
    ).astype(o_ref.dtype)


def fused_mttkrp_bilinear_batched(
    t: Array,
    a: Array,
    b: Array,
    *,
    pos: int,
    block_i: int,
    block_b: int,
    block_batch: int,
    interpret: bool = False,
) -> Array:
    """Batched bilinear MTTKRP: ``M[s,i,c] = sum_{a,b} T[s,...] A[s,a,c] B[s,b,c]``.

    ``t`` is ``(S, *3-D view)`` with the i-axis of the per-slab view at
    ``pos``; ``a``/``b`` are per-batch partial KRPs ``(S, dim, C)``.  The
    grid gains a leading batch axis ``S // block_batch`` (outermost, so each
    output block still stays VMEM-resident across its whole reduction).
    Dims (including S) must be padded to block multiples by the wrapper.
    """
    if t.ndim != 4:
        raise ValueError("t must be a batched (4-D) view")
    n_batch = t.shape[0]
    if a.shape[0] != n_batch or b.shape[0] != n_batch:
        raise ValueError(
            f"batch mismatch: t {t.shape}, a {a.shape}, b {b.shape}"
        )
    dim_a, dim_b = a.shape[1], b.shape[1]
    c = a.shape[2]
    shape = list(t.shape[1:])
    dim_i = shape.pop(pos)
    if shape != [dim_a, dim_b]:
        raise ValueError(f"t shape {t.shape} inconsistent with A/B {a.shape}/{b.shape}")
    if dim_i % block_i or dim_b % block_b or n_batch % block_batch:
        raise ValueError("dims must be padded to block multiples")

    grid = (n_batch // block_batch, dim_i // block_i, dim_a, dim_b // block_b)

    if pos == 0:
        t_spec = pl.BlockSpec(
            (block_batch, block_i, 1, block_b),
            lambda s, i, al, bl: (s, i, al, bl),
        )
    elif pos == 1:
        t_spec = pl.BlockSpec(
            (block_batch, 1, block_i, block_b),
            lambda s, i, al, bl: (s, al, i, bl),
        )
    else:
        t_spec = pl.BlockSpec(
            (block_batch, 1, block_b, block_i),
            lambda s, i, al, bl: (s, al, bl, i),
        )

    return pl.pallas_call(
        functools.partial(_kernel_batched, pos=pos),
        grid=grid,
        in_specs=[
            t_spec,
            pl.BlockSpec((block_batch, 1, c), lambda s, i, al, bl: (s, al, 0)),
            pl.BlockSpec(
                (block_batch, block_b, c), lambda s, i, al, bl: (s, bl, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_batch, block_i, c), lambda s, i, al, bl: (s, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_batch, dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, a, b)


def fused_mttkrp_bilinear(
    t: Array,
    a: Array,
    b: Array,
    *,
    pos: int,
    block_i: int,
    block_b: int,
    interpret: bool = False,
) -> Array:
    """``M[i,c] = sum_{a,b} T * A[a,c] * B[b,c]`` with T's i-axis at ``pos``.

    Dims must already be padded to multiples of the block sizes (the ops.py
    wrapper does this); C should be lane-aligned (128) for real TPUs.
    """
    if t.ndim != 3:
        raise ValueError("t must be a 3-D view")
    dim_a, dim_b = a.shape[0], b.shape[0]
    c = a.shape[1]
    shape = list(t.shape)
    dim_i = shape.pop(pos)
    if shape != [dim_a, dim_b]:
        raise ValueError(f"t shape {t.shape} inconsistent with A/B {a.shape}/{b.shape}")
    if dim_i % block_i or dim_b % block_b:
        raise ValueError("dims must be padded to block multiples")

    grid = (dim_i // block_i, dim_a, dim_b // block_b)

    if pos == 0:
        t_spec = pl.BlockSpec((block_i, 1, block_b), lambda i, al, bl: (i, al, bl))
    elif pos == 1:
        t_spec = pl.BlockSpec((1, block_i, block_b), lambda i, al, bl: (al, i, bl))
    else:
        t_spec = pl.BlockSpec((1, block_b, block_i), lambda i, al, bl: (al, bl, i))

    return pl.pallas_call(
        functools.partial(_kernel, pos=pos),
        grid=grid,
        in_specs=[
            t_spec,
            pl.BlockSpec((1, c), lambda i, al, bl: (al, 0)),
            pl.BlockSpec((block_b, c), lambda i, al, bl: (bl, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, c), lambda i, al, bl: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, a, b)
