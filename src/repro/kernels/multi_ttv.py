"""Multi-TTV Pallas kernel -- the 2nd step of the 2-step MTTKRP (Alg. 4).

Computes  ``M[i, c] = sum_l T[l, i, c] * W[l, c]``  where ``T`` is the partial
MTTKRP output (``(L, I_n, C)``, the paper's R-tensor reshaped) and ``W`` is the
complementary partial KRP (``(L, C)``).  The paper implements this as ``C``
independent DGEMV calls (Alg. 4 lines 7-9 / 13-15); on TPU a batched GEMV is
lane-hostile, so the idiomatic form is a broadcast multiply-accumulate on the
VPU with the rank axis on lanes: each grid step does
``o[i-block, :] += T[l, i-block, :] * W[l, :]``.

Grid ``(I_blocks, L)`` with the reduction dim innermost (revisited-output
accumulation, zero-initialized at l == 0).  VMEM per step: T-tile (bi*C) +
W row (C) + out (bi*C) -> a few hundred KB at bi=512, C=128.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(t_ref, w_ref, o_ref):
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (t_ref[0, :, :] * w_ref[0, :]).astype(o_ref.dtype)


def multi_ttv(
    t: Array, w: Array, *, block_i: int, interpret: bool = False
) -> Array:
    """``M[i,c] = sum_l t[l,i,c] * w[l,c]`` (t: (L, I, C), w: (L, C))."""
    big_l, dim_i, c = t.shape
    if w.shape != (big_l, c):
        raise ValueError(f"w shape {w.shape} != ({big_l}, {c})")
    if dim_i % block_i:
        raise ValueError("I must be padded to the block size")
    grid = (dim_i // block_i, big_l)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i, c), lambda i, l: (l, i, 0)),
            pl.BlockSpec((1, c), lambda i, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, c), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, w)


def _kernel_batched(t_ref, w_ref, o_ref):
    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-batch broadcast MAC: (bt, bi, C) * (bt, 1, C)
    o_ref[...] += (t_ref[:, 0, :, :] * w_ref[:, 0, :][:, None, :]).astype(
        o_ref.dtype
    )


def multi_ttv_batched(
    t: Array,
    w: Array,
    *,
    block_i: int,
    block_batch: int,
    interpret: bool = False,
) -> Array:
    """Batched multi-TTV: ``M[s,i,c] = sum_l t[s,l,i,c] * w[s,l,c]``.

    Same VPU accumulation as :func:`multi_ttv` with a leading batch grid
    axis (outermost; the L reduction stays innermost so each output block
    is revisited in place).  S and I must be padded to block multiples.
    """
    n_batch, big_l, dim_i, c = t.shape
    if w.shape != (n_batch, big_l, c):
        raise ValueError(f"w shape {w.shape} != ({n_batch}, {big_l}, {c})")
    if dim_i % block_i or n_batch % block_batch:
        raise ValueError("S and I must be padded to the block sizes")
    grid = (n_batch // block_batch, dim_i // block_i, big_l)
    return pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_batch, 1, block_i, c), lambda s, i, l: (s, l, i, 0)
            ),
            pl.BlockSpec((block_batch, 1, c), lambda s, i, l: (s, l, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_batch, block_i, c), lambda s, i, l: (s, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_batch, dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, w)
