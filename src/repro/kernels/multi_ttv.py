"""Multi-TTV Pallas kernel -- the 2nd step of the 2-step MTTKRP (Alg. 4).

Computes  ``M[i, c] = sum_l T[l, i, c] * W[l, c]``  where ``T`` is the partial
MTTKRP output (``(L, I_n, C)``, the paper's R-tensor reshaped) and ``W`` is the
complementary partial KRP (``(L, C)``).  The paper implements this as ``C``
independent DGEMV calls (Alg. 4 lines 7-9 / 13-15); on TPU a batched GEMV is
lane-hostile, so the idiomatic form is a broadcast multiply-accumulate on the
VPU with the rank axis on lanes: each grid step does
``o[i-block, :] += T[l, i-block, :] * W[l, :]``.

Grid ``(I_blocks, L)`` with the reduction dim innermost (revisited-output
accumulation, zero-initialized at l == 0).  VMEM per step: T-tile (bi*C) +
W row (C) + out (bi*C) -> a few hundred KB at bi=512, C=128.

This module is the single multi-TTV implementation: the raw grid kernels
(``multi_ttv_kernel`` / ``multi_ttv_batched_kernel``) plus the jit'd
padding wrappers (``multi_ttv`` / ``multi_ttv_batched``).  ``ops.multi_ttv``
is a frozen alias of the wrapper here, so tile threading has one seam.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import block as _block
from ._tiling import interpret_default as _interpret
from ._tiling import pad_axis as _pad_axis

Array = jax.Array


def _kernel(t_ref, w_ref, o_ref):
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += (t_ref[0, :, :] * w_ref[0, :]).astype(o_ref.dtype)


def multi_ttv_kernel(
    t: Array, w: Array, *, block_i: int, interpret: bool = False
) -> Array:
    """``M[i,c] = sum_l t[l,i,c] * w[l,c]`` (t: (L, I, C), w: (L, C))."""
    big_l, dim_i, c = t.shape
    if w.shape != (big_l, c):
        raise ValueError(f"w shape {w.shape} != ({big_l}, {c})")
    if dim_i % block_i:
        raise ValueError("I must be padded to the block size")
    grid = (dim_i // block_i, big_l)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_i, c), lambda i, l: (l, i, 0)),
            pl.BlockSpec((1, c), lambda i, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((block_i, c), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, w)


def _kernel_batched(t_ref, w_ref, o_ref):
    l_idx = pl.program_id(2)

    @pl.when(l_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # per-batch broadcast MAC: (bt, bi, C) * (bt, 1, C)
    o_ref[...] += (t_ref[:, 0, :, :] * w_ref[:, 0, :][:, None, :]).astype(
        o_ref.dtype
    )


def multi_ttv_batched_kernel(
    t: Array,
    w: Array,
    *,
    block_i: int,
    block_batch: int,
    interpret: bool = False,
) -> Array:
    """Batched multi-TTV: ``M[s,i,c] = sum_l t[s,l,i,c] * w[s,l,c]``.

    Same VPU accumulation as :func:`multi_ttv_kernel` with a leading batch
    grid axis (outermost; the L reduction stays innermost so each output
    block is revisited in place).  S and I must be padded to block multiples.
    """
    n_batch, big_l, dim_i, c = t.shape
    if w.shape != (n_batch, big_l, c):
        raise ValueError(f"w shape {w.shape} != ({n_batch}, {big_l}, {c})")
    if dim_i % block_i or n_batch % block_batch:
        raise ValueError("S and I must be padded to the block sizes")
    grid = (n_batch // block_batch, dim_i // block_i, big_l)
    return pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (block_batch, 1, block_i, c), lambda s, i, l: (s, l, i, 0)
            ),
            pl.BlockSpec((block_batch, 1, c), lambda s, i, l: (s, l, 0)),
        ],
        out_specs=pl.BlockSpec(
            (block_batch, block_i, c), lambda s, i, l: (s, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_batch, dim_i, c), jnp.float32),
        interpret=interpret,
    )(t, w)


@partial(jax.jit, static_argnames=("block_i", "interpret"))
def multi_ttv(
    t: Array, w: Array, *, block_i: int = 256, interpret: bool | None = None
) -> Array:
    """Kernelized multi-TTV:  M[i,c] = sum_l t[l,i,c] * w[l,c]."""
    interp = _interpret(interpret)
    dim_i = t.shape[1]
    bi = _block(dim_i, block_i)
    t_pad = _pad_axis(t, 1, bi)
    out = multi_ttv_kernel(t_pad, w, block_i=bi, interpret=interp)
    return out[:dim_i].astype(t.dtype)


@partial(jax.jit, static_argnames=("block_i", "block_batch", "interpret"))
def multi_ttv_batched(
    t: Array,
    w: Array,
    *,
    block_i: int = 256,
    block_batch: int = 8,
    interpret: bool | None = None,
) -> Array:
    """Batched multi-TTV: ``M[s,i,c] = sum_l t[s,l,i,c] * w[s,l,c]``.

    One launch over the kernel's batch grid axis; the I tile is chosen from
    the mode extent ``t.shape[2]`` (pad axes shifted for the batch axis).
    """
    interp = _interpret(interpret)
    s_batch, dim_i = t.shape[0], t.shape[2]
    bi = _block(dim_i, block_i)
    bs = _block(s_batch, block_batch)
    t_pad = _pad_axis(_pad_axis(t, 2, bi), 0, bs)
    w_pad = _pad_axis(w, 0, bs)
    out = multi_ttv_batched_kernel(
        t_pad, w_pad, block_i=bi, block_batch=bs, interpret=interp
    )
    return out[:s_batch, :dim_i].astype(t.dtype)
