"""Matrix-free Pallas MTTKRP: stream tensor blocks once, no KRP anywhere.

The fused kernel (fused_mttkrp.py) already avoids the *full* KRP, but it
still materializes two partial KRPs in HBM and reads the tensor through a
matricized 3-D view.  This kernel goes the rest of the way, following the
source paper's closing lesson (avoid tensor reordering AND large KRP
intermediates) and GenTen's performance-portable dense formulation
(Kosmacher-Phipps-Rajamanickam, arXiv:2510.14891): the tensor is passed to
the kernel in its natural N-D layout (no matricization, no reshape), the
raw factor matrices ride along untouched, and each grid step folds one
tensor block against the non-target factor rows entirely in VMEM:

* one MXU contraction over the innermost non-target mode produces a
  trailing rank axis (``dot_general`` at HIGHEST precision), then
* one VPU broadcast-multiply-reduce per remaining non-target mode peels
  the block down to an ``(I-block, C)`` contribution.

The output factor block stays resident in VMEM across all reduction grid
steps (revisited-output accumulation, zero-initialized on the first visit),
so each tensor element is read exactly once from HBM and nothing of KRP
shape -- full or partial -- is ever written.

Supported: every mode of order-3..6 tensors, plus a leading batch axis
(``matrix_free_mttkrp_batched``).  Tile knobs: ``block_i`` (target-mode
rows kept in VMEM), ``block_r`` (cap on each reduction-mode block; the
wrapper shrinks caps further if the tensor tile would blow the VMEM
budget), ``block_batch`` (batch slab).
"""

from __future__ import annotations

import math
from functools import partial, reduce
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ._tiling import block as _block
from ._tiling import interpret_default as _interpret
from ._tiling import on_tpu as _on_tpu
from ._tiling import pad_axis as _pad_axis

Array = jax.Array

# Cap on tensor-tile elements held in VMEM per grid step (2 MB at f32).
_TILE_ELEM_BUDGET = 512 * 1024


def _fold_tile(t, us_by_mode, live, n, batched):
    """Contract every non-target mode out of one VMEM tile.

    ``live`` is the list of original mode ids for ``t``'s spatial axes (in
    order); ``n`` is the target mode.  Descending-order processing keeps
    axis bookkeeping local: removing an axis only shifts larger ids, which
    are already gone.
    """
    off = 1 if batched else 0
    hi = jax.lax.Precision.HIGHEST
    live = list(live)
    desc = sorted((k for k in live if k != n), reverse=True)
    first = desc[0]
    u = us_by_mode[first][...].astype(jnp.float32)
    pos = live.index(first) + off
    if batched:
        t = jax.lax.dot_general(t, u, (((pos,), (1,)), ((0,), (0,))), precision=hi)
    else:
        t = jax.lax.dot_general(t, u, (((pos,), (0,)), ((), ())), precision=hi)
    live.remove(first)
    for a in desc[1:]:
        u = us_by_mode[a][...].astype(jnp.float32)
        pos = live.index(a) + off
        shape = [1] * t.ndim
        if batched:
            shape[0] = u.shape[0]
        shape[pos] = u.shape[-2]
        shape[-1] = u.shape[-1]
        t = (t * u.reshape(shape)).sum(axis=pos)
        live.remove(a)
    return t


def matrix_free_kernel(
    x: Array,
    us: Sequence[Array],
    n: int,
    *,
    block_i: int,
    blocks: Sequence[int],
    interpret: bool = False,
) -> Array:
    """Raw matrix-free MTTKRP grid: ``M = X_(n) . KRP(us)`` with no KRP.

    ``x`` is the natural N-D tensor, every axis pre-padded to its block
    multiple; ``us`` the non-target factors in ascending mode order (rows
    padded likewise); ``blocks`` the per-non-target-mode block sizes in the
    same order.  Grid: target-mode blocks outermost, one reduction axis per
    non-target mode inner, so the ``(block_i, C)`` output block is revisited
    in place across every reduction step.
    """
    big_n = x.ndim
    others = [k for k in range(big_n) if k != n]
    c = us[0].shape[1]
    if len(us) != len(others) or len(blocks) != len(others):
        raise ValueError("need one factor and one block per non-target mode")
    if x.shape[n] % block_i:
        raise ValueError("target mode must be padded to block_i")
    for k, u, b in zip(others, us, blocks):
        if x.shape[k] % b or u.shape[0] != x.shape[k] or u.shape[1] != c:
            raise ValueError(f"mode {k}: factor/block mismatch")

    grid = (x.shape[n] // block_i,) + tuple(
        x.shape[k] // b for k, b in zip(others, blocks)
    )
    x_block = [0] * big_n
    x_block[n] = block_i
    for k, b in zip(others, blocks):
        x_block[k] = b

    def x_index(i, *rs):
        out = [0] * big_n
        out[n] = i
        for j, k in enumerate(others):
            out[k] = rs[j]
        return tuple(out)

    in_specs = [pl.BlockSpec(tuple(x_block), x_index)]
    for j, (k, b) in enumerate(zip(others, blocks)):
        in_specs.append(
            pl.BlockSpec((b, c), lambda i, *rs, j=j: (rs[j], 0))
        )

    def kernel(x_ref, *refs):
        o_ref = refs[-1]
        u_refs = refs[:-1]
        red = [pl.program_id(j + 1) for j in range(len(others))]

        @pl.when(reduce(jnp.logical_and, [r == 0 for r in red]))
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        t = x_ref[...].astype(jnp.float32)
        us_by_mode = dict(zip(others, u_refs))
        o_ref[...] += _fold_tile(t, us_by_mode, list(range(big_n)), n, False)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_i, c), lambda i, *rs: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[n], c), jnp.float32),
        interpret=interpret,
    )(x, *us)


def matrix_free_batched_kernel(
    x: Array,
    us: Sequence[Array],
    n: int,
    *,
    block_i: int,
    blocks: Sequence[int],
    block_batch: int,
    interpret: bool = False,
) -> Array:
    """Batched raw grid: ``x`` is ``(S, *shape)``, ``us`` are ``(S, I_k, C)``.

    Leading batch grid axis outermost; each batch slab folds its own factor
    rows, so per-problem intermediates still never leave VMEM.
    """
    big_n = x.ndim - 1
    s_batch = x.shape[0]
    others = [k for k in range(big_n) if k != n]
    c = us[0].shape[2]
    if s_batch % block_batch or x.shape[1 + n] % block_i:
        raise ValueError("batch and target mode must be padded to their blocks")
    for k, u, b in zip(others, us, blocks):
        if x.shape[1 + k] % b or u.shape[:2] != (s_batch, x.shape[1 + k]):
            raise ValueError(f"mode {k}: factor/block mismatch")

    grid = (
        s_batch // block_batch,
        x.shape[1 + n] // block_i,
    ) + tuple(x.shape[1 + k] // b for k, b in zip(others, blocks))
    x_block = [0] * (big_n + 1)
    x_block[0] = block_batch
    x_block[1 + n] = block_i
    for k, b in zip(others, blocks):
        x_block[1 + k] = b

    def x_index(s, i, *rs):
        out = [0] * (big_n + 1)
        out[0] = s
        out[1 + n] = i
        for j, k in enumerate(others):
            out[1 + k] = rs[j]
        return tuple(out)

    in_specs = [pl.BlockSpec(tuple(x_block), x_index)]
    for j, (k, b) in enumerate(zip(others, blocks)):
        in_specs.append(
            pl.BlockSpec((block_batch, b, c), lambda s, i, *rs, j=j: (s, rs[j], 0))
        )

    def kernel(x_ref, *refs):
        o_ref = refs[-1]
        u_refs = refs[:-1]
        red = [pl.program_id(j + 2) for j in range(len(others))]

        @pl.when(reduce(jnp.logical_and, [r == 0 for r in red]))
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        t = x_ref[...].astype(jnp.float32)
        us_by_mode = dict(zip(others, u_refs))
        o_ref[...] += _fold_tile(t, us_by_mode, list(range(big_n)), n, True)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (block_batch, block_i, c), lambda s, i, *rs: (s, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((s_batch, x.shape[1 + n], c), jnp.float32),
        interpret=interpret,
    )(x, *us)


def _reduction_blocks(
    mode_shape: Sequence[int], n: int, lead_elems: int, block_r: int
) -> dict[int, int]:
    """Per-non-target-mode block sizes, shrunk to fit the VMEM tile budget.

    ``lead_elems`` is the number of tile elements already committed to the
    non-reduction axes (``block_i``, times ``block_batch`` when batched).
    """
    rb = {k: _block(d, block_r) for k, d in enumerate(mode_shape) if k != n}
    while lead_elems * math.prod(rb.values()) > _TILE_ELEM_BUDGET:
        k = max(rb, key=lambda kk: rb[kk])
        if rb[k] == 1:
            break
        rb[k] = rb[k] // 2
    return rb


@partial(
    jax.jit,
    static_argnames=("n", "block_i", "block_r", "interpret", "pad_rank_to"),
)
def matrix_free_mttkrp(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    block_i: int = 128,
    block_r: int = 8,
    interpret: bool | None = None,
    pad_rank_to: int | None = None,
) -> Array:
    """Matrix-free MTTKRP for any mode of an order-3..6 tensor.

    The tensor is zero-padded to block multiples (zero entries nullify any
    padded factor rows) and handed to the kernel in its natural layout --
    no reshape, no matricization, no KRP of any size.
    """
    factors = list(factors)
    big_n = len(factors)
    if x.ndim != big_n:
        raise ValueError(
            f"x.ndim {x.ndim} != {big_n} factors -- for a leading batch axis "
            "use matrix_free_mttkrp_batched"
        )
    if not 3 <= big_n <= 6:
        raise ValueError(f"matrix-free kernel covers order-3..6, got {big_n}")
    c = factors[0].shape[1]
    interp = _interpret(interpret)
    if pad_rank_to is None and _on_tpu():
        pad_rank_to = 128

    in_dim = x.shape[n]
    others = [k for k in range(big_n) if k != n]
    bi = _block(in_dim, block_i)
    rb = _reduction_blocks(x.shape, n, bi, block_r)

    x_pad = _pad_axis(x, n, bi)
    us = []
    for k in others:
        x_pad = _pad_axis(x_pad, k, rb[k])
        u = _pad_axis(factors[k], 0, x_pad.shape[k])
        if pad_rank_to:
            u = _pad_axis(u, 1, pad_rank_to)
        us.append(u)
    out = matrix_free_kernel(
        x_pad, us, n,
        block_i=bi, blocks=[rb[k] for k in others], interpret=interp,
    )
    return out[:in_dim, :c].astype(x.dtype)


@partial(
    jax.jit,
    static_argnames=(
        "n", "block_i", "block_r", "block_batch", "interpret", "pad_rank_to"
    ),
)
def matrix_free_mttkrp_batched(
    x: Array,
    factors: Sequence[Array],
    n: int,
    *,
    block_i: int = 128,
    block_r: int = 8,
    block_batch: int = 8,
    interpret: bool | None = None,
    pad_rank_to: int | None = None,
) -> Array:
    """Batched matrix-free MTTKRP: ``x`` is ``(S, *shape)``, factors
    ``(S, I_k, C)``.  Tile choice keys on the mode dims only; every pad
    axis is shifted by one for the leading batch axis."""
    factors = list(factors)
    big_n = len(factors)
    if x.ndim != big_n + 1:
        raise ValueError(
            f"x.ndim {x.ndim} != {big_n} factors + batch axis -- for an "
            "unbatched tensor use matrix_free_mttkrp"
        )
    if not 3 <= big_n <= 6:
        raise ValueError(f"matrix-free kernel covers order-3..6, got {big_n}")
    s_batch = x.shape[0]
    mode_shape = x.shape[1:]
    c = factors[0].shape[2]
    interp = _interpret(interpret)
    if pad_rank_to is None and _on_tpu():
        pad_rank_to = 128

    in_dim = mode_shape[n]
    others = [k for k in range(big_n) if k != n]
    bi = _block(in_dim, block_i)
    bs = _block(s_batch, block_batch)
    rb = _reduction_blocks(mode_shape, n, bi * bs, block_r)

    x_pad = _pad_axis(_pad_axis(x, 1 + n, bi), 0, bs)
    us = []
    for k in others:
        x_pad = _pad_axis(x_pad, 1 + k, rb[k])
        u = _pad_axis(_pad_axis(factors[k], 1, x_pad.shape[1 + k]), 0, bs)
        if pad_rank_to:
            u = _pad_axis(u, 2, pad_rank_to)
        us.append(u)
    out = matrix_free_batched_kernel(
        x_pad, us, n,
        block_i=bi, blocks=[rb[k] for k in others], block_batch=bs,
        interpret=interp,
    )
    return out[:s_batch, :in_dim, :c].astype(x.dtype)
