"""Pallas TPU kernels for the MTTKRP hot spots the paper optimizes.

- fused_mttkrp: MTTKRP with the KRP formed on-the-fly in VMEM (never in HBM)
- matrix_free:  fully streaming MTTKRP -- no matricization, no KRP at all
- krp_kernel:   tiled explicit KRP (paper Alg. 1's parallel row blocks)
- multi_ttv:    the 2-step algorithm's 2nd step (Alg. 4)

ops.py holds the jit'd wrappers (padding/tiling/dispatch); ref.py the
pure-jnp oracles the tests compare against.
"""

from . import ops, ref
from .fused_mttkrp import fused_mttkrp_bilinear, fused_mttkrp_bilinear_batched
from .krp_kernel import krp_pair
from .matrix_free import (
    matrix_free_batched_kernel,
    matrix_free_kernel,
    matrix_free_mttkrp,
    matrix_free_mttkrp_batched,
)
from .multi_ttv import multi_ttv_batched_kernel, multi_ttv_kernel

__all__ = [
    "ops",
    "ref",
    "fused_mttkrp_bilinear",
    "fused_mttkrp_bilinear_batched",
    "krp_pair",
    "matrix_free_kernel",
    "matrix_free_batched_kernel",
    "matrix_free_mttkrp",
    "matrix_free_mttkrp_batched",
    "multi_ttv_kernel",
    "multi_ttv_batched_kernel",
]
