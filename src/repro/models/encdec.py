"""Whisper-style encoder-decoder backbone (whisper-base).

The conv1d audio frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings (B, S_enc, d) provided by input_specs().
Encoder: +sinusoidal positions, pre-LN bidirectional self-attention + GELU MLP.
Decoder: learned positions, causal self-attention + cross-attention + MLP.
Serving precomputes the cross-attention K/V once from the encoder output and
caches decoder self-attention K/V per step.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from . import attention as attn
from .common import (
    ParamDef,
    mask_vocab_pad,
    norm_apply,
    norm_defs,
    sinusoid_positions,
    vocab_padded,
)
from .ffn import ffn_apply, ffn_defs

Array = jax.Array

MAX_POSITIONS = 32_768  # learned decoder position table bound (covers decode_32k)


def _enc_layer_defs(cfg: ModelConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.norm, cfg.d_model),
        "attn": attn.attn_defs(cfg),
        "ln2": norm_defs(cfg.norm, cfg.d_model),
        "mlp": ffn_defs(cfg),
    }


def _dec_layer_defs(cfg: ModelConfig) -> dict:
    return {
        "ln1": norm_defs(cfg.norm, cfg.d_model),
        "self_attn": attn.attn_defs(cfg),
        "lnx": norm_defs(cfg.norm, cfg.d_model),
        "cross_attn": attn.attn_defs(cfg, cross=True),
        "ln2": norm_defs(cfg.norm, cfg.d_model),
        "mlp": ffn_defs(cfg),
    }


def encdec_defs(cfg: ModelConfig) -> dict:
    v_pad = vocab_padded(cfg.vocab)  # 51865 -> 51968 for even TP shards
    return {
        "embed": ParamDef((v_pad, cfg.d_model), ("tp", None), "small"),
        "pos_embed": ParamDef((MAX_POSITIONS, cfg.d_model), (None, None), "small"),
        "enc_layers": [_enc_layer_defs(cfg) for _ in range(cfg.enc_layers)],
        "enc_norm": norm_defs(cfg.norm, cfg.d_model),
        "dec_layers": [_dec_layer_defs(cfg) for _ in range(cfg.dec_layers)],
        "dec_norm": norm_defs(cfg.norm, cfg.d_model),
        "head": ParamDef((cfg.d_model, v_pad), ("fsdp", "tp")),
    }


def encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, S_enc, d) stubbed frontend output -> encoder states."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s, _ = frames.shape
    h = frames.astype(dt) + sinusoid_positions(s, cfg.d_model).astype(dt)[None]
    h = meshlib.constraint(h, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for lp in params["enc_layers"]:

        def fn(lp_, hh):
            x = norm_apply(cfg.norm, hh, lp_["ln1"])
            hh = hh + attn.attn_sequence(
                lp_["attn"], cfg, x, positions, causal=False, q_chunk=cfg.seq_chunk
            )
            x2 = norm_apply(cfg.norm, hh, lp_["ln2"])
            return hh + ffn_apply(lp_["mlp"], cfg, x2)

        if cfg.remat:
            fn = jax.checkpoint(fn)
        h = fn(lp, h)
    return norm_apply(cfg.norm, h, params["enc_norm"])


def decode_train(
    params: dict, cfg: ModelConfig, tokens: Array, enc_out: Array
) -> Array:
    """Teacher-forced decoder pass -> logits (B, S_dec, V)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b, s = tokens.shape
    h = params["embed"][tokens].astype(dt) + params["pos_embed"][:s].astype(dt)[None]
    h = meshlib.constraint(h, "dp", None, None)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    for lp in params["dec_layers"]:

        def fn(lp_, hh):
            x = norm_apply(cfg.norm, hh, lp_["ln1"])
            hh = hh + attn.attn_sequence(
                lp_["self_attn"], cfg, x, positions, causal=True, q_chunk=cfg.seq_chunk
            )
            xx = norm_apply(cfg.norm, hh, lp_["lnx"])
            kv = attn.cross_attn_kv(lp_["cross_attn"], cfg, enc_out)
            hh = hh + attn.cross_attn(lp_["cross_attn"], cfg, xx, kv)
            x2 = norm_apply(cfg.norm, hh, lp_["ln2"])
            return hh + ffn_apply(lp_["mlp"], cfg, x2)

        if cfg.remat:
            fn = jax.checkpoint(fn)
        h = fn(lp, h)
    h = norm_apply(cfg.norm, h, params["dec_norm"])
    logits = mask_vocab_pad(h @ params["head"].astype(dt), cfg.vocab)
    return meshlib.constraint(logits, "dp", None, "tp")


class EncDecCache(NamedTuple):
    self_kv: list  # per-dec-layer attn.KVCache
    cross_kv: list  # per-dec-layer (k, v) from the encoder output
    length: Array


def init_encdec_cache(
    params: dict, cfg: ModelConfig, enc_out: Array, max_len: int, dtype
) -> EncDecCache:
    b = enc_out.shape[0]
    self_kv = [attn.init_kv_cache(cfg, b, max_len, dtype) for _ in params["dec_layers"]]
    cross_kv = [
        attn.cross_attn_kv(lp["cross_attn"], cfg, enc_out) for lp in params["dec_layers"]
    ]
    return EncDecCache(self_kv, cross_kv, jnp.zeros((), jnp.int32))


def decode_step(
    params: dict, cfg: ModelConfig, tokens: Array, cache: EncDecCache
) -> tuple[Array, EncDecCache]:
    """One decode step.  tokens: (B, 1)."""
    dt = jnp.dtype(cfg.compute_dtype)
    b = tokens.shape[0]
    pos_e = params["pos_embed"][cache.length][None, None, :].astype(dt)
    h = params["embed"][tokens].astype(dt) + pos_e
    new_self = []
    for lp, kv_c, kv_x in zip(params["dec_layers"], cache.self_kv, cache.cross_kv):
        x = norm_apply(cfg.norm, h, lp["ln1"])
        y, kv_new = attn.attn_decode(lp["self_attn"], cfg, x, kv_c, cache.length)
        h = h + y
        new_self.append(kv_new)
        xx = norm_apply(cfg.norm, h, lp["lnx"])
        h = h + attn.cross_attn(lp["cross_attn"], cfg, xx, kv_x)
        x2 = norm_apply(cfg.norm, h, lp["ln2"])
        h = h + ffn_apply(lp["mlp"], cfg, x2)
    h = norm_apply(cfg.norm, h, params["dec_norm"])
    logits = mask_vocab_pad(h @ params["head"].astype(dt), cfg.vocab)
    return logits, EncDecCache(new_self, cache.cross_kv, cache.length + 1)
