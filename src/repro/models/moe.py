"""Mixture-of-Experts FFN with explicit expert parallelism (shard_map).

Design (DESIGN.md S5): experts are sharded over the *model* axis (EP
co-located with TP).  Activations enter the block replicated over 'model'
(they are batch-sharded over 'dp' only), so each model-rank routes ALL of its
data-shard's tokens to its own local experts -- **no all-to-all is required**;
the expert outputs are combined with the same psum a Megatron TP-FFN needs.
Expert weights are additionally FSDP-sharded over 'dp' and all-gathered on
entry (ZeRO-3); the gather transposes to a reduce-scatter in the backward.

Dispatch is sort-free (cumsum-position capacity dispatch):
  1. top-k routing (router logits; padded experts masked to -inf),
  2. per-expert positions via a one-hot cumsum (no argsort -> cheap grads),
  3. tokens beyond capacity C = ceil(T*k/E * cf) are dropped (standard),
  4. scatter into the (E_local, C, d) buffer, dense per-expert GEMMs on the
     MXU, gather back weighted by the routing probabilities.

The expert count is padded to a multiple of 16 so every mesh tp size in
{1,2,4,8,16} divides it (qwen2-moe: 60 -> 64; the 4 pads receive -inf router
logits and are never selected).
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from .common import ParamDef, act_fn

from repro.compat import shard_map as _shard_map

Array = jax.Array

EXPERT_PAD_MULTIPLE = 16


def padded_experts(n: int) -> int:
    return -(-n // EXPERT_PAD_MULTIPLE) * EXPERT_PAD_MULTIPLE


def moe_defs(cfg: ModelConfig) -> dict:
    d, fe = cfg.d_model, cfg.d_ff_expert
    e_pad = padded_experts(cfg.n_experts)
    defs = {
        "router": ParamDef((d, e_pad), (None, None)),
        "w_gate": ParamDef((e_pad, d, fe), ("expert", "fsdp", None)),
        "w_up": ParamDef((e_pad, d, fe), ("expert", "fsdp", None)),
        "w_down": ParamDef((e_pad, fe, d), ("expert", None, "fsdp")),
    }
    if cfg.d_ff_shared:
        fs = cfg.d_ff_shared
        defs["shared"] = {
            "gate": ParamDef((d, fs), ("fsdp", "tp")),
            "up": ParamDef((d, fs), ("fsdp", "tp")),
            "down": ParamDef((fs, d), ("tp", "fsdp")),
        }
        defs["shared_gate"] = ParamDef((d, 1), (None, None))  # qwen2-moe gate
    return defs


def moe_apply(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """Returns (y, aux_loss).  x: (B, S, d) batch-sharded over dp."""
    mesh = meshlib.current_mesh()
    act = act_fn("silu")
    e_pad = padded_experts(cfg.n_experts)
    k = cfg.n_experts_per_tok

    if mesh is None or meshlib.in_manual_mode():
        # no-mesh debugging, or already inside a shard_map (pure-DP trainer):
        # run all experts locally -- correct semantics when 'model' axis is
        # not part of the enclosing manual region's sharding.
        return _moe_local(p, cfg, x, e_loc=e_pad, my_first=jnp.int32(0), act=act)

    dp = meshlib.dp_axes(mesh)
    dspec = meshlib.dp_spec_entry(mesh)
    tp = mesh.shape.get("model", 1)
    if e_pad % tp:
        raise ValueError(f"padded experts {e_pad} not divisible by tp={tp}")
    e_loc = e_pad // tp

    def local_fn(x_blk, router_w, w_gate, w_up, w_down, shared, shared_gate):
        # FSDP all-gather of the expert weights over the dp axes (ZeRO-3).
        w_gate = jax.lax.all_gather(w_gate, dp, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, dp, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, dp, axis=2, tiled=True)
        my_first = (jax.lax.axis_index("model") * e_loc).astype(jnp.int32)
        # Build the local param view from explicit shard_map args only (no
        # accidental closure capture of sharded arrays).
        pl = dict(router=router_w, w_gate=w_gate, w_up=w_up, w_down=w_down)
        if shared is not None:
            pl["shared"] = dict(
                gate=jax.lax.all_gather(shared["gate"], dp, axis=0, tiled=True),
                up=jax.lax.all_gather(shared["up"], dp, axis=0, tiled=True),
                down=jax.lax.all_gather(shared["down"], dp, axis=1, tiled=True),
            )
            pl["shared_gate"] = shared_gate
        y, aux = _moe_local(pl, cfg, x_blk, e_loc=e_loc, my_first=my_first, act=act)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, dp + ("model",))
        return y, aux

    shared = p.get("shared")
    shared_specs = (
        None
        if shared is None
        else dict(gate=P(dspec, "model"), up=P(dspec, "model"), down=P("model", dspec))
    )
    y, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dspec, None, None),
            P(None, None),
            P("model", dspec, None),
            P("model", dspec, None),
            P("model", None, dspec),
            shared_specs,
            None if shared is None else P(None, None),
        ),
        out_specs=(P(dspec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"], shared, p.get("shared_gate"))
    return y, aux


def _moe_local(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    *,
    e_loc: int,
    my_first: Array,
    act,
) -> tuple[Array, Array]:
    """Per-device MoE body.  x: (B_loc, S, d)."""
    b, s, d = x.shape
    t = b * s
    e_pad = padded_experts(cfg.n_experts)
    k = cfg.n_experts_per_tok
    cap = max(8, int(math.ceil(t * k / e_pad * cfg.capacity_factor)))
    dt = x.dtype
    xf = x.reshape(t, d)

    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)
    pad_mask = jnp.arange(e_pad) < cfg.n_experts
    logits = jnp.where(pad_mask[None, :], logits, -jnp.inf)
    top_vals, top_idx = jax.lax.top_k(logits, k)  # (T, k)
    probs = jax.nn.softmax(top_vals, axis=-1).astype(dt)

    # Within-expert positions over the flat (token-major) pair order -- one
    # cumsum over a (T*k, E_pad) int32 one-hot (small); everything (T*k, d)-
    # sized is avoided: dispatch/combine run per k-slice so the largest
    # dispatch intermediates are (T, d), not (T*k, d).
    pair_expert = top_idx.reshape(-1)  # (T*k,)
    onehot = (pair_expert[:, None] == jnp.arange(e_pad)[None, :]).astype(jnp.int32)
    pos_flat = jnp.take_along_axis(
        jnp.cumsum(onehot, 0) - 1, pair_expert[:, None], 1
    ).squeeze(-1)
    pos = pos_flat.reshape(t, k)
    local_e = top_idx - my_first  # (T, k)
    keep = (local_e >= 0) & (local_e < e_loc) & (pos < cap)
    slot = jnp.where(keep, local_e * cap + pos, e_loc * cap)  # (T, k)

    buf = jnp.zeros((e_loc * cap + 1, d), dt)
    for j in range(k):  # scatter each routing choice; slots are unique
        buf = buf.at[slot[:, j]].set(xf, mode="drop")
    buf3 = buf[: e_loc * cap].reshape(e_loc, cap, d)
    h = act(jnp.einsum("ecd,edf->ecf", buf3, p["w_gate"].astype(dt))) * jnp.einsum(
        "ecd,edf->ecf", buf3, p["w_up"].astype(dt)
    )
    y_exp = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))
    y_flat = jnp.concatenate([y_exp.reshape(e_loc * cap, d), jnp.zeros((1, d), dt)], 0)
    out = jnp.zeros((t, d), dt)
    for j in range(k):  # combine: plain gathers, no scatter-add needed
        w_j = (probs[:, j] * keep[:, j].astype(dt))[:, None]
        out = out + y_flat[slot[:, j]] * w_j

    if "shared" in p and p["shared"] is not None:
        sh = p["shared"]
        hs = act(xf @ sh["gate"].astype(dt)) * (xf @ sh["up"].astype(dt))
        ys = hs @ sh["down"].astype(dt)
        gate = jax.nn.sigmoid((xf @ p["shared_gate"].astype(dt)).astype(jnp.float32))
        out = out + ys * gate.astype(dt)

    # Load-balance aux loss (Switch-style): E * sum_e f_e * p_e over real experts.
    probs_full = jax.nn.softmax(logits, axis=-1)  # fp32, pads ~ 0
    frac = jnp.mean(
        (onehot.reshape(t, k, e_pad).sum(1) > 0).astype(jnp.float32), axis=0
    )
    mean_p = jnp.mean(probs_full, axis=0)
    aux = cfg.n_experts * jnp.sum(frac * mean_p)
    return out.reshape(b, s, d), aux
