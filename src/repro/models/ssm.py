"""Mamba-1 selective-SSM block (falcon-mamba-7b).

Block: in_proj -> (x | z); causal depthwise conv4 + SiLU on x; data-dependent
(Delta, B, C); discretize  h_t = exp(Delta A) h_{t-1} + Delta B x_t;
y = C h + D x; out = (y * SiLU(z)) @ out_proj.

TPU adaptation: the recurrence is a *chunked associative scan*
(scan_utils.linear_scan) -- parallel log-depth within chunks, sequential
carry across chunks, bounding the (B, S_c, d_inner, d_state) discretized
tensors to the chunk size.  d_inner is tensor-parallel ('tp'); Delta/B/C
contract over d_inner and GSPMD inserts the psum.  Decode carries an O(1)
state (h: (B, d_inner, d_state), conv tail: (B, K-1, d_inner)) -- the reason
this arch runs the long_500k cell.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from .common import ParamDef
from .scan_utils import causal_conv1d, linear_scan

Array = jax.Array


class SSMState(NamedTuple):
    h: Array  # (B, d_inner, N)
    conv: Array  # (B, K-1, d_inner)


def ssm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.expand * d
    n = cfg.ssm_state
    k = cfg.ssm_conv
    dtr = cfg.dt_rank
    return {
        "in_proj": ParamDef((d, 2 * di), ("fsdp", "tp")),
        "conv_w": ParamDef((di, k), ("tp", None), "normal", 0.2),
        "conv_b": ParamDef((di,), ("tp",), "zeros"),
        "x_proj": ParamDef((di, dtr + 2 * n), ("tp", None)),
        "dt_w": ParamDef((dtr, di), (None, "tp")),
        "dt_b": ParamDef((di,), ("tp",), "ones"),  # softplus(1) ~ healthy init dt
        "a_log": ParamDef((di, n), ("tp", None), "normal", 0.5),
        "d_skip": ParamDef((di,), ("tp",), "ones"),
        "out_proj": ParamDef((di, d), ("tp", "fsdp")),
    }


def _delta_bc(p: dict, cfg: ModelConfig, xc: Array):
    """xc: (B, S, di) conv output -> (delta (B,S,di), B (B,S,N), C (B,S,N))."""
    dt = xc.dtype
    dtr, n = cfg.dt_rank, cfg.ssm_state
    x_db = xc @ p["x_proj"].astype(dt)
    dt_r, b_in, c_in = jnp.split(x_db, [dtr, dtr + n], axis=-1)
    delta = jax.nn.softplus(
        (dt_r @ p["dt_w"].astype(dt)).astype(jnp.float32) + p["dt_b"]
    )
    return delta, b_in.astype(jnp.float32), c_in.astype(jnp.float32)


def ssm_apply(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    state: SSMState | None = None,
    *,
    return_state: bool = False,
):
    """Full-sequence forward.  x: (B, S, d)."""
    dt = x.dtype
    di = cfg.expand * cfg.d_model
    xz = x @ p["in_proj"].astype(dt)
    xz = meshlib.constraint(xz, "dp", None, "tp")
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = causal_conv1d(
        xr, p["conv_w"], p["conv_b"], buf=None if state is None else state.conv
    )
    xc = jax.nn.silu(xc)

    delta, b_in, c_in = _delta_bc(p, cfg, xc)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)
    # Discretize: decay (B,S,di,N), forced (B,S,di,N).  The gate math (exp,
    # softplus) runs fp32; the scanned pair is cast to the compute dtype --
    # these two tensors and the scan's log-depth intermediates dominate the
    # layer's HBM traffic (EXPERIMENTS.md SPerf: 2x byte reduction), and the
    # per-chunk recurrence depth (<= seq_chunk) keeps bf16 error bounded.
    decay = jnp.exp(delta[..., None] * a).astype(dt)
    forced = ((delta * xc.astype(jnp.float32))[..., None] * b_in[:, :, None, :]).astype(dt)
    h0 = None if state is None else state.h.astype(dt)
    # default chunk 128: measured ~7%/14% fewer HLO bytes than 256/512 on the
    # train_4k dry-run (log-depth scan traffic scales with log2(chunk))
    chunk = cfg.seq_chunk or (128 if x.shape[1] > 128 else 0)
    h_all, h_last = linear_scan(decay, forced, h0, axis=1, chunk=chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all.astype(jnp.float32), c_in).astype(dt)
    y = y + xc * p["d_skip"].astype(dt)
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    out = meshlib.constraint(out, "dp", None, None)
    if return_state:
        return out, SSMState(h_last.astype(dt), conv_tail)
    return out


def ssm_decode(
    p: dict, cfg: ModelConfig, x: Array, state: SSMState
) -> tuple[Array, SSMState]:
    """One-token step.  x: (B, 1, d); O(1) state update."""
    dt = x.dtype
    xz = x @ p["in_proj"].astype(dt)
    xr, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = causal_conv1d(xr, p["conv_w"], p["conv_b"], buf=state.conv)
    xc = jax.nn.silu(xc)
    delta, b_in, c_in = _delta_bc(p, cfg, xc)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(delta[:, 0, :, None] * a)  # (B, di, N)
    forced = (delta[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * b_in[:, 0, None, :]
    h = decay * state.h.astype(jnp.float32) + forced
    y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0])[:, None, :].astype(dt)
    y = y + xc * p["d_skip"].astype(dt)
    out = (y * jax.nn.silu(z)) @ p["out_proj"].astype(dt)
    return out, SSMState(h.astype(dt), conv_tail)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    di = cfg.expand * cfg.d_model
    return SSMState(
        jnp.zeros((batch, di, cfg.ssm_state), dtype),
        jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
    )
