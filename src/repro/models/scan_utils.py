"""Linear-recurrence scan  h_t = a_t * h_{t-1} + b_t  (elementwise).

TPU-native parallel scan via ``lax.associative_scan`` (log-depth, MXU-free,
VPU-friendly), optionally chunked along the sequence axis: the chunk bound
caps the materialized (B, S_c, ...) discretized-state intermediates (the
reason falcon-mamba's (B, S, d_inner, d_state) tensor stays off HBM budgets)
while ``lax.scan`` carries the boundary state across chunks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _combine(lhs, rhs):
    a1, b1 = lhs
    a2, b2 = rhs
    return a1 * a2, a2 * b1 + b2


def linear_scan(
    a: Array, b: Array, h0: Array | None = None, *, axis: int = 1, chunk: int = 0
) -> tuple[Array, Array]:
    """Returns (h_all, h_last); a/b shaped (..., S, ...) along ``axis``.

    ``h0`` (same shape as one step) seeds the recurrence.  ``chunk`` > 0 runs
    a sequential lax.scan over S/chunk chunks, each solved with the parallel
    associative scan -- the standard memory/depth trade.
    """
    s = a.shape[axis]
    if h0 is None:
        h0 = jnp.zeros_like(jax.lax.index_in_dim(a, 0, axis, keepdims=False))

    def block(a_blk: Array, b_blk: Array, carry: Array) -> tuple[Array, Array]:
        b0 = jax.lax.index_in_dim(b_blk, 0, axis, keepdims=False)
        a0 = jax.lax.index_in_dim(a_blk, 0, axis, keepdims=False)
        b_blk = jax.lax.dynamic_update_index_in_dim(
            b_blk, b0 + a0 * carry, 0, axis
        )
        _, h = jax.lax.associative_scan(_combine, (a_blk, b_blk), axis=axis)
        return h, jax.lax.index_in_dim(h, -1, axis, keepdims=False)

    if not chunk or s <= chunk or s % chunk != 0:
        return block(a, b, h0)

    n = s // chunk

    def body(carry, idx):
        a_blk = jax.lax.dynamic_slice_in_dim(a, idx * chunk, chunk, axis)
        b_blk = jax.lax.dynamic_slice_in_dim(b, idx * chunk, chunk, axis)
        h, last = block(a_blk, b_blk, carry)
        return last, h

    last, hs = jax.lax.scan(body, h0, jnp.arange(n))
    # hs: (n, ..., chunk, ...) -> concatenate along the sequence axis
    hs = jnp.moveaxis(hs, 0, axis)  # (..., n, chunk, ...)
    shape = list(a.shape)
    h_all = hs.reshape(shape[:axis] + [s] + shape[axis + 1 :])
    return h_all, last


def causal_conv1d(
    x: Array, w: Array, b: Array | None, *, buf: Array | None = None
) -> tuple[Array, Array]:
    """Depthwise causal 1-D conv.  x: (B, S, D); w: (D, K); returns (y, new_buf).

    ``buf`` is the (B, K-1, D) tail of the previous segment (decode carries
    it); the returned new_buf is the updated tail.
    """
    batch, s, d = x.shape
    k = w.shape[1]
    if buf is None:
        buf = jnp.zeros((batch, k - 1, d), x.dtype)
    xp = jnp.concatenate([buf, x], axis=1)  # (B, S+K-1, D)
    y = jnp.zeros_like(x)
    for j in range(k):  # K is 4: unrolled shift-mul-accumulate (VPU friendly)
        y = y + xp[:, j : j + s, :] * w[:, j].astype(x.dtype)[None, None, :]
    if b is not None:
        y = y + b.astype(x.dtype)
    new_buf = xp[:, s:, :] if k > 1 else buf
    return y, new_buf
