"""Model facade: build_model(cfg) -> init / loss / prefill / decode interfaces.

The Model object is what train/serve/dryrun consume; it hides the family
differences (decoder-only vs enc-dec vs attention-free) behind four pure
functions plus the param-definition tree (shapes + logical shardings).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from . import encdec, transformer
from .common import cast_floats, init_tree, norm_apply, spec_tree

Array = jax.Array


def cross_entropy(logits: Array, labels: Array) -> tuple[Array, Array]:
    """Mean next-token CE + accuracy.  logits: (B, S, V); labels: (B, S).

    Sharding note: the vocab axis is tensor-parallel.  The label log-prob is
    extracted with an iota-mask reduction (fuses into a single per-shard pass
    + psum) instead of ``take_along_axis``, whose gather across the sharded
    vocab axis makes GSPMD all-gather the full logits (26 GB/device for
    dbrx-132b at train_4k -- measured).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    mask = vocab_iota == labels[..., None]
    ll = jnp.sum(jnp.where(mask, logits, 0.0), axis=-1)
    loss = jnp.mean(lse - ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    param_defs: Any

    def init(self, key: jax.Array) -> Any:
        return init_tree(self.param_defs, key, jnp.dtype(self.cfg.param_dtype))

    def logical_specs(self) -> Any:
        return spec_tree(self.param_defs)

    def partition_specs(self, mesh, *, drop_fsdp: bool = False) -> Any:
        """``drop_fsdp=True`` keeps only tensor parallelism (weights resident,
        replicated over dp) -- the serving deployment layout: decode/prefill
        read every weight once per step, so ZeRO-3 per-layer all-gathers are
        pure collective overhead there (measured in EXPERIMENTS.md SPerf)."""

        def resolve(spec):
            if drop_fsdp:
                spec = tuple(None if ax == "fsdp" else ax for ax in spec)
            return meshlib.resolve_logical(spec, mesh)

        return jax.tree.map(
            resolve,
            self.logical_specs(),
            is_leaf=lambda x: isinstance(x, tuple),  # logical specs are tuples
        )

    # ---- training ----
    def loss_fn(self, params: Any, batch: dict) -> tuple[Array, dict]:
        cfg = self.cfg
        params = cast_floats(params, cfg.compute_dtype)
        if cfg.is_encdec:
            enc_out = encdec.encode(params, cfg, batch["frames"])
            logits = encdec.decode_train(params, cfg, batch["tokens"][:, :-1], enc_out)
            loss, acc = cross_entropy(logits, batch["tokens"][:, 1:])
            return loss, {"ce": loss, "acc": acc}
        tokens = batch["tokens"]
        positions = batch.get("positions")
        if positions is not None:
            positions = positions[:, : tokens.shape[1] - 1]
        h, aux, _ = transformer.forward(params, cfg, tokens[:, :-1], positions)
        logits = transformer.lm_logits(params, cfg, h)
        loss, acc = cross_entropy(logits, tokens[:, 1:])
        total = loss + cfg.router_aux_weight * aux if cfg.n_experts else loss
        return total, {"ce": loss, "acc": acc, "aux": aux}

    # ---- serving ----
    def prefill(self, params: Any, batch: dict, max_len: int) -> tuple[Any, Array]:
        """Process the prompt; returns (cache, last-token logits)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        if cfg.is_encdec:
            enc_out = encdec.encode(params, cfg, batch["frames"])
            cache = encdec.init_encdec_cache(params, cfg, enc_out, max_len, dt)
            logits, cache = encdec.decode_step(params, cfg, batch["tokens"][:, :1], cache)
            return cache, logits
        tokens = batch["tokens"]
        b, s = tokens.shape
        h, _, collected = transformer.forward(
            params, cfg, tokens, batch.get("positions"), collect_cache=True
        )  # h is already final-normed
        cache = transformer.init_cache(cfg, b, max_len, dt)
        entries = _fill_cache(self.cfg, cache.entries, collected, s)
        logits = transformer.lm_logits(params, cfg, h[:, -1:, :])
        return transformer.DecodeCache(entries, jnp.asarray(s, jnp.int32)), logits

    def decode_step(self, params: Any, tokens: Array, cache: Any):
        cfg = self.cfg
        if cfg.is_encdec:
            return encdec.decode_step(params, cfg, tokens, cache)
        return transformer.decode_step(params, cfg, tokens, cache)

    def init_cache(self, batch: int, max_len: int) -> Any:
        cfg = self.cfg
        assert not cfg.is_encdec, "enc-dec caches come from prefill()"
        return transformer.init_cache(cfg, batch, max_len, jnp.dtype(cfg.compute_dtype))


def _fill_cache(cfg: ModelConfig, entries: Any, collected: Any, s: int) -> Any:
    """Write prefill K/V (or recurrent states) into a fresh decode cache.

    Ring invariant (attention.attn_decode): the token at absolute position p
    lives at slot ``p % W``.  When the prompt is longer than the window we
    keep the last W tokens and roll them so position p lands at slot p % W --
    the next decode write (slot s % W) then correctly evicts the oldest.
    """
    from .attention import KVCache
    from .rglru import LRUState
    from .ssm import SSMState

    def fill_kv(entry: KVCache, col, seq_axis: int) -> KVCache:
        k, v = col  # (..., S, Hk, hd) with seq at seq_axis
        w = entry.k.shape[seq_axis]
        if s >= w:
            idx = [slice(None)] * k.ndim
            idx[seq_axis] = slice(s - w, s)
            k = jnp.roll(k[tuple(idx)], s % w, axis=seq_axis)
            v = jnp.roll(v[tuple(idx)], s % w, axis=seq_axis)
            return KVCache(k.astype(entry.k.dtype), v.astype(entry.v.dtype))
        k_full = jax.lax.dynamic_update_slice_in_dim(
            entry.k, k.astype(entry.k.dtype), 0, seq_axis
        )
        v_full = jax.lax.dynamic_update_slice_in_dim(
            entry.v, v.astype(entry.v.dtype), 0, seq_axis
        )
        return KVCache(k_full, v_full)

    def fill_state(entry, col):
        return type(entry)(*(c.astype(e.dtype) for e, c in zip(entry, col)))

    if isinstance(entries, list):  # loop stacks: seq axis 1, per-layer entries
        out = []
        for e, c in zip(entries, collected):
            if isinstance(e, (SSMState, LRUState)):
                out.append(fill_state(e, c))
            else:
                out.append(fill_kv(e, c, seq_axis=1))
        return out
    # scanned stacks: leaves carry a leading layer dim -> seq axis 2 for KV
    if isinstance(entries, (SSMState, LRUState)):
        return fill_state(entries, collected)
    return fill_kv(entries, collected, seq_axis=2)


def build_model(cfg: ModelConfig) -> Model:
    defs = encdec.encdec_defs(cfg) if cfg.is_encdec else transformer.decoder_defs(cfg)
    return Model(cfg=cfg, param_defs=defs)
