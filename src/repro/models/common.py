"""Shared model substrate: param definitions, norms, rotary embeddings, init."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# --------------------------------------------------------------------------
# Parameter definition trees: shapes + logical sharding specs built together
# so params and their shardings can never diverge.
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    spec: tuple[Any, ...]  # logical axes per dim: "fsdp" | "tp" | "expert" | None
    init: str = "fan_in"  # fan_in | normal | zeros | ones | small
    scale: float = 1.0

    def make(self, key: jax.Array, dtype) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        if self.init == "normal":
            return (self.scale * jax.random.normal(key, self.shape)).astype(dtype)
        if self.init == "small":
            return (0.02 * self.scale * jax.random.normal(key, self.shape)).astype(dtype)
        # fan_in: std = scale / sqrt(fan_in) with fan_in = shape[-2] (or [0])
        fan = self.shape[-2] if len(self.shape) >= 2 else self.shape[0]
        std = self.scale / np.sqrt(max(fan, 1))
        return (std * jax.random.normal(key, self.shape)).astype(dtype)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_tree(defs: Any, key: jax.Array, dtype) -> Any:
    """Initialize a pytree of ParamDefs with per-leaf folded RNG keys."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves)) if leaves else []
    return jax.tree.unflatten(treedef, [d.make(k, dtype) for d, k in zip(leaves, keys)])


def spec_tree(defs: Any) -> Any:
    """Extract the logical-spec pytree matching init_tree's output."""
    return jax.tree.map(lambda d: d.spec, defs, is_leaf=is_def)


def count_params(params: Any) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))


def vocab_padded(vocab: int) -> int:
    """Pad the embedding-table vocab to the 128-lane boundary so the
    tensor-parallel shard is even (whisper: 51865 -> 51968).  Logit positions
    >= the true vocab are masked to -inf (see transformer.lm_logits)."""
    return -(-vocab // 128) * 128


def mask_vocab_pad(logits: Array, vocab: int) -> Array:
    if logits.shape[-1] == vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < vocab, logits, jnp.asarray(-1e9, logits.dtype))


def cast_floats(tree: Any, dtype) -> Any:
    """Mixed-precision entry cast: float leaves -> compute dtype (fp32 masters
    stay in the optimizer).  Casting *before* use means FSDP all-gathers move
    bf16, halving both the gather transients and the wire bytes."""
    dtype = jnp.dtype(dtype)

    def cast(x):
        return x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x

    return jax.tree.map(cast, tree)


# --------------------------------------------------------------------------
# Normalizations
# --------------------------------------------------------------------------
def rms_norm(x: Array, w: Array | None, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(jnp.square(x32), -1, keepdims=True) + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(dt)


def layer_norm(x: Array, w: Array | None, b: Array | None, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, -1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), -1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(dt)


def norm_defs(kind: str, dim: int) -> dict:
    if kind == "rmsnorm":
        return {"w": ParamDef((dim,), (None,), "ones")}
    if kind == "layernorm":
        return {"w": ParamDef((dim,), (None,), "ones"), "b": ParamDef((dim,), (None,), "zeros")}
    if kind == "layernorm_np":  # olmo: non-parametric
        return {}
    raise ValueError(f"unknown norm {kind!r}")


def norm_apply(kind: str, x: Array, p: dict) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["w"])
    if kind == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    if kind == "layernorm_np":
        return layer_norm(x, None, None)
    raise ValueError(f"unknown norm {kind!r}")


# --------------------------------------------------------------------------
# Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE
# --------------------------------------------------------------------------
def _inv_freq(half: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: Array, freqs: Array, out_dtype=None) -> Array:
    """x: (..., hd) fp32; freqs: broadcastable (..., hd//2) angle array.

    The halves are cast to ``out_dtype`` BEFORE the concat so the big
    concatenated tensor never materializes in fp32 (1.9 GB/layer on
    deepseek prefill otherwise -- the trig math itself stays fp32).
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    cos, sin = jnp.cos(freqs), jnp.sin(freqs)
    dt = out_dtype or x.dtype
    out1 = (x1 * cos - x2 * sin).astype(dt)
    out2 = (x2 * cos + x1 * sin).astype(dt)
    rotated = jnp.concatenate([out1, out2], -1)
    if 2 * half < x.shape[-1]:  # odd head_dim (danube hd=120 is even; safety)
        rotated = jnp.concatenate([rotated, x[..., 2 * half :].astype(dt)], -1)
    return rotated


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int."""
    half = x.shape[-1] // 2
    freqs = positions[..., None].astype(jnp.float32) * _inv_freq(half, theta)
    return _rotate(x.astype(jnp.float32), freqs[:, :, None, :], out_dtype=x.dtype)


def apply_mrope(
    x: Array, positions: Array, sections: tuple[int, ...], theta: float
) -> Array:
    """Qwen2-VL M-RoPE.  positions: (B, S, 3) = (temporal, height, width) ids.

    The hd//2 frequency slots are split into len(sections) groups; group g's
    angles use position stream g.  Text tokens carry identical ids in all
    three streams (degenerates to standard RoPE, as in the paper).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = _inv_freq(half, theta)
    parts = []
    start = 0
    for g, sec in enumerate(sections):
        pos_g = positions[..., g].astype(jnp.float32)  # (B, S)
        parts.append(pos_g[..., None] * inv[start : start + sec])
        start += sec
    freqs = jnp.concatenate(parts, -1)  # (B, S, half)
    return _rotate(x.astype(jnp.float32), freqs[:, :, None, :], out_dtype=x.dtype)


def sinusoid_positions(seq: int, dim: int) -> Array:
    """Whisper-encoder style fixed sinusoidal embeddings (S, d)."""
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], -1)
    return jnp.asarray(out, jnp.float32)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------
def act_fn(name: str) -> Callable[[Array], Array]:
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def softcap(x: Array, cap: float) -> Array:
    return jnp.tanh(x / cap) * cap if cap else x
