"""RG-LRU recurrent block (recurrentgemma / Griffin).

Temporal-mixing block: x-branch linear -> causal conv4 -> RG-LRU; gate branch
linear -> GeLU; elementwise product -> out projection.

RG-LRU (Griffin eq. 1-4):
    r_t = sigmoid(BD_a(x_t)),  i_t = sigmoid(BD_x(x_t))        (block-diag gates)
    log a_t = -c * softplus(Lambda) * r_t                       (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise over the LRU width -> a plain parallel
associative scan (no chunking needed: state is (B, S, w), activation-sized).
Decode state is O(1): (h (B, w), conv tail) -- long_500k eligible.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from .common import ParamDef
from .scan_utils import causal_conv1d, linear_scan

Array = jax.Array

LRU_C = 8.0
_NUM_BLOCKS = 0  # resolved from cfg.n_heads


class LRUState(NamedTuple):
    h: Array  # (B, w)
    conv: Array  # (B, K-1, w)


def _nb(cfg: ModelConfig) -> int:
    return max(cfg.n_heads, 1)


def rglru_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    nb = _nb(cfg)
    bw = w // nb
    return {
        "in_x": ParamDef((d, w), ("fsdp", "tp")),
        "in_gate": ParamDef((d, w), ("fsdp", "tp")),
        "conv_w": ParamDef((w, 4), ("tp", None), "normal", 0.2),
        "conv_b": ParamDef((w,), ("tp",), "zeros"),
        # block count = n_heads (10) does not divide tp=16; the gates are tiny
        # (nb * bw^2 ~ 2.6 MB) so they stay replicated.
        "gate_a_w": ParamDef((nb, bw, bw), (None, None, None)),
        "gate_a_b": ParamDef((nb, bw), (None, None), "zeros"),
        "gate_x_w": ParamDef((nb, bw, bw), (None, None, None)),
        "gate_x_b": ParamDef((nb, bw), (None, None), "zeros"),
        "lam": ParamDef((w,), (None,), "normal", 1.0),
        "out": ParamDef((w, d), ("tp", "fsdp")),
    }


def _block_diag(x: Array, w: Array, b: Array, nb: int) -> Array:
    """Block-diagonal linear: x (..., W) with W split into nb blocks."""
    shape = x.shape
    xb = x.reshape(shape[:-1] + (nb, shape[-1] // nb))
    y = jnp.einsum("...nb,nbc->...nc", xb, w.astype(x.dtype)) + b.astype(x.dtype)
    return y.reshape(shape)


def _lru_coeffs(p: dict, cfg: ModelConfig, xc: Array):
    """xc: (B, S, w) conv output -> (a, forced) fp32 recurrence coefficients."""
    nb = _nb(cfg)
    r = jax.nn.sigmoid(
        _block_diag(xc, p["gate_a_w"], p["gate_a_b"], nb).astype(jnp.float32)
    )
    i = jax.nn.sigmoid(
        _block_diag(xc, p["gate_x_w"], p["gate_x_b"], nb).astype(jnp.float32)
    )
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    forced = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6)) * (
        i * xc.astype(jnp.float32)
    )
    return a, forced


def rglru_apply(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    state: LRUState | None = None,
    *,
    return_state: bool = False,
):
    """Full-sequence forward.  x: (B, S, d)."""
    dt = x.dtype
    xb = x @ p["in_x"].astype(dt)
    gate = x @ p["in_gate"].astype(dt)
    xb = meshlib.constraint(xb, "dp", None, "tp")
    xc, conv_tail = causal_conv1d(
        xb, p["conv_w"], p["conv_b"], buf=None if state is None else state.conv
    )
    a, forced = _lru_coeffs(p, cfg, xc)
    h0 = None if state is None else state.h.astype(jnp.float32)
    h_all, h_last = linear_scan(a, forced, h0, axis=1, chunk=cfg.seq_chunk)
    y = h_all.astype(dt) * jax.nn.gelu(gate)
    out = y @ p["out"].astype(dt)
    out = meshlib.constraint(out, "dp", None, None)
    if return_state:
        return out, LRUState(h_last.astype(dt), conv_tail)
    return out


def rglru_decode(
    p: dict, cfg: ModelConfig, x: Array, state: LRUState
) -> tuple[Array, LRUState]:
    """One-token step.  x: (B, 1, d)."""
    dt = x.dtype
    xb = x @ p["in_x"].astype(dt)
    gate = x @ p["in_gate"].astype(dt)
    xc, conv_tail = causal_conv1d(xb, p["conv_w"], p["conv_b"], buf=state.conv)
    a, forced = _lru_coeffs(p, cfg, xc)
    h = a[:, 0] * state.h.astype(jnp.float32) + forced[:, 0]
    y = h[:, None, :].astype(dt) * jax.nn.gelu(gate)
    out = y @ p["out"].astype(dt)
    return out, LRUState(h.astype(dt), conv_tail)


def init_lru_state(cfg: ModelConfig, batch: int, dtype) -> LRUState:
    w = cfg.lru_width or cfg.d_model
    return LRUState(jnp.zeros((batch, w), dtype), jnp.zeros((batch, 3, w), dtype))
