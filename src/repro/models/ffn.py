"""Dense FFN variants (SwiGLU / GeGLU / GELU-MLP) + CP-factorized option.

The CP-factorized path is the paper-technique hook (DESIGN.md
SArch-applicability): with ``cfg.cp_rank = r > 0`` the up/gate/down weights
are replaced by rank-r CP factor pairs  W ~= A @ B^T  (a 2-way CP model, i.e.
columns are the rank-1 terms).  Factor fitting against a trained dense weight
uses repro.core.cp_als; here we only define the parameterization so the
factorized model trains/serves end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from .common import ParamDef, act_fn

Array = jax.Array


def ffn_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    if cfg.cp_rank:
        r = cfg.cp_rank
        return {
            "gate_a": ParamDef((d, r), ("fsdp", None)),
            "gate_b": ParamDef((r, f), (None, "tp")),
            "up_a": ParamDef((d, r), ("fsdp", None)),
            "up_b": ParamDef((r, f), (None, "tp")),
            "down_a": ParamDef((f, r), ("tp", None)),
            "down_b": ParamDef((r, d), (None, "fsdp")),
        }
    if cfg.act in ("swiglu", "geglu"):
        return {
            "gate": ParamDef((d, f), ("fsdp", "tp")),
            "up": ParamDef((d, f), ("fsdp", "tp")),
            "down": ParamDef((f, d), ("tp", "fsdp")),
        }
    # plain MLP (whisper)
    return {
        "up": ParamDef((d, f), ("fsdp", "tp")),
        "down": ParamDef((f, d), ("tp", "fsdp")),
    }


def ffn_apply(p: dict, cfg: ModelConfig, x: Array) -> Array:
    dt = x.dtype
    act = act_fn({"swiglu": "silu", "geglu": "gelu", "gelu": "gelu"}[cfg.act])
    if cfg.cp_rank:
        gate = (x @ p["gate_a"].astype(dt)) @ p["gate_b"].astype(dt)
        up = (x @ p["up_a"].astype(dt)) @ p["up_b"].astype(dt)
        h = act(gate) * up
        h = meshlib.constraint(h, "dp", None, "tp")
        return (h @ p["down_a"].astype(dt)) @ p["down_b"].astype(dt)
    if cfg.act in ("swiglu", "geglu"):
        h = act(x @ p["gate"].astype(dt)) * (x @ p["up"].astype(dt))
        h = meshlib.constraint(h, "dp", None, "tp")
        return h @ p["down"].astype(dt)
    h = act(x @ p["up"].astype(dt))
    h = meshlib.constraint(h, "dp", None, "tp")
    return h @ p["down"].astype(dt)
