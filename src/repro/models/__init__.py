"""LM substrate: composable blocks + the Model facade."""

from .model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
