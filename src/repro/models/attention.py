"""Attention: GQA/MQA, RoPE / M-RoPE, QK-norm, sliding/local windows, caches.

Memory discipline for long sequences: `q_chunk` splits the query axis with a
`lax.scan`; full-attention chunks score against all keys (peak = qc x S), and
windowed variants (h2o-danube SWA, recurrentgemma local attention) slice a
(window + qc) key span with `dynamic_slice`, making prefill cost O(S * window)
instead of O(S^2).  Decode uses a ring-buffer cache of size `window` when a
window is set (the long_500k enabler) and a full cache otherwise.

Sharding: heads are tensor-parallel ('tp'); batch is 'dp'.  Constraints are
applied at the projection boundaries; GSPMD propagates through the einsums.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from .common import ParamDef, apply_mrope, apply_rope, norm_apply, norm_defs, rms_norm

Array = jax.Array
NEG_INF = -1.0e9  # bf16-safe large negative


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------
def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    defs = {
        "wq": ParamDef((d, h * hd), ("fsdp", "tp")),
        "wk": ParamDef((d, hk * hd), ("fsdp", "tp")),
        "wv": ParamDef((d, hk * hd), ("fsdp", "tp")),
        "wo": ParamDef((h * hd, d), ("tp", "fsdp")),
    }
    if cfg.qk_norm and not cross:
        defs["q_norm"] = ParamDef((hd,), (None,), "ones")
        defs["k_norm"] = ParamDef((hd,), (None,), "ones")
    return defs


def _split_heads(x: Array, n: int, hd: int) -> Array:
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd)


def _head_axis_ok(n_heads: int) -> bool:
    """Sharding a head axis smaller than tp makes GSPMD fall back to full
    activation replication (measured: 8.6-17 GB/device buffers); only shard
    the head axis when every device gets >= 1 head."""
    return n_heads >= max(meshlib.tp_size(), 1)


def _project_q(p: dict, cfg: ModelConfig, x: Array, layout: str = "heads") -> Array:
    q = _split_heads(x @ p["wq"].astype(x.dtype), cfg.n_heads, cfg.hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
    if layout == "seq":  # sequence-parallel attention (few-head archs)
        return meshlib.constraint(q, "dp", "tp", None, None)
    if _head_axis_ok(cfg.n_heads):
        return meshlib.constraint(q, "dp", None, "tp", None)
    return meshlib.constraint(q, "dp", None, None, None)


def _project_kv(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    k = _split_heads(x @ p["wk"].astype(x.dtype), cfg.n_kv_heads, cfg.hd)
    v = _split_heads(x @ p["wv"].astype(x.dtype), cfg.n_kv_heads, cfg.hd)
    if "k_norm" in p:
        k = rms_norm(k, p["k_norm"])
    spec = ("dp", None, "tp", None) if _head_axis_ok(cfg.n_kv_heads) else ("dp", None, None, None)
    k = meshlib.constraint(k, *spec)
    v = meshlib.constraint(v, *spec)
    return k, v


def _rope(cfg: ModelConfig, x: Array, positions: Array) -> Array:
    if cfg.is_encdec:  # whisper: absolute embeddings, no rotary
        return x
    if cfg.mrope_sections:
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


# --------------------------------------------------------------------------
# Core scaled-dot-product with GQA grouping
# --------------------------------------------------------------------------
def _attend(q: Array, k: Array, v: Array, mask: Array | None) -> Array:
    """q: (B,Sq,H,hd), k/v: (B,Sk,Hk,hd), mask broadcastable (B,1,1,Sq,Sk).

    GQA sharding rule (Megatron): the kv-head axis only stays folded when it
    divides the tensor-parallel degree; otherwise GSPMD pads the tiny Hk axis
    to tp and falls back to replicating whole activations (measured: 8.6 GB
    per-device batch replication on dbrx).  In that case we expand K/V to the
    full query-head count -- the H axis shards cleanly and the expansion is
    sliced per shard, so per-device K/V size is unchanged.
    """
    b, sq, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    tp = meshlib.tp_size()
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)
    if g > 1 and hk % tp == 0:
        qg = q.reshape(b, sq, hk, g, hd)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * scale
        scores = scores.astype(jnp.float32)
        if mask is not None:
            scores = jnp.where(mask, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
        return out.reshape(b, sq, h, hd)
    if g > 1:  # expand kv heads; sharding depends on the phase
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        if sq == 1:
            # decode: PRESERVE the cache's sequence sharding (split-K /
            # flash-decoding): scores stay local over W-shards, softmax does
            # tiny cross-shard max/sum psums, out is a (B,1,H,hd) psum.
            # Head-wise resharding here all-gathers the entire 32k-token
            # cache in f32 every layer (measured 0.5-1 GB x2 per layer).
            k = meshlib.constraint(k, "dp", "tp", None, None)
            v = meshlib.constraint(v, "dp", "tp", None, None)
        elif _head_axis_ok(h):
            # prefill/train: K/V were computed replicated over 'model', so
            # the head shard is a free local slice -- and it keeps the
            # (B, H, Sq, Sk) score tensors head-sharded (14.7 GB replicated
            # scores measured on deepseek prefill without this).
            k = meshlib.constraint(k, "dp", None, "tp", None)
            v = meshlib.constraint(v, "dp", None, "tp", None)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[:, :, 0], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def _causal_mask(sq: int, sk: int, q_off, window: int) -> Array:
    """(1,1,1,sq,sk) mask; q rows are global rows q_off..q_off+sq-1, k cols
    are global cols 0..sk-1 (full) -- callers with sliced keys pass offsets."""
    i = q_off + jnp.arange(sq)[:, None]
    j = jnp.arange(sk)[None, :]
    m = j <= i
    if window:
        m = m & (j > i - window)
    return m[None, None, None]


# --------------------------------------------------------------------------
# Training / prefill self-attention (full sequence in, full sequence out)
# --------------------------------------------------------------------------
def attn_sequence(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 0,
    return_kv: bool = False,
):
    """Self-attention over a full sequence.  Returns y [, (k, v) for caching]."""
    b, s, _ = x.shape
    # windowed attention with no explicit chunk: chunk at the window size so
    # the scores stay O(s * window) instead of O(s^2)
    if window and not q_chunk and s > window:
        q_chunk = window
    chunked = bool(q_chunk) and s > q_chunk and s % q_chunk == 0
    # Few-head archs (whisper h=8, recurrentgemma h=10 < tp=16): shard the
    # *query sequence* axis over 'model' instead of heads -- attention rows
    # are independent, K/V stay replicated over 'model' (they are small), and
    # the output lands already in the layer-boundary sequence-parallel layout.
    seq_layout = not _head_axis_ok(cfg.n_heads) and s > 1
    layout = "seq" if (seq_layout and not chunked) else "heads"
    q = _rope(cfg, _project_q(p, cfg, x, layout), positions)
    k, v = _project_kv(p, cfg, x)
    k = _rope(cfg, k, positions)

    if not chunked:
        mask = _causal_mask(s, s, 0, window) if causal else None
        y = _attend(q, k, v, mask)
    else:
        n_chunks = s // q_chunk
        span = min(s, window + q_chunk) if window else s

        def body(carry, c):
            q_c = jax.lax.dynamic_slice_in_dim(q, c * q_chunk, q_chunk, 1)
            if seq_layout:  # shard the chunk's rows over 'model'
                q_c = meshlib.constraint(q_c, "dp", "tp", None, None)
            if window and span < s:
                start = jnp.clip(c * q_chunk + q_chunk - span, 0, s - span)
                k_c = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
                v_c = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
                i = (c * q_chunk + jnp.arange(q_chunk))[:, None]
                j = (start + jnp.arange(span))[None, :]
                m = (j <= i) & (j > i - window) if causal else (j >= 0)
                y_c = _attend(q_c, k_c, v_c, m[None, None, None])
            else:
                m = _causal_mask(q_chunk, s, c * q_chunk, window) if causal else None
                y_c = _attend(q_c, k, v, m)
            return carry, y_c

        _, ys = jax.lax.scan(body, None, jnp.arange(n_chunks))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, cfg.n_heads, cfg.hd)

    y = y.reshape(b, s, cfg.n_heads * cfg.hd)
    out = y @ p["wo"].astype(y.dtype)
    out = meshlib.constraint(out, "dp", None, None)
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------------------
# Decode (one token, cache)
# --------------------------------------------------------------------------
class KVCache(NamedTuple):
    """k/v: (B, W, Hk, hd) with W = window (ring) or max_len (full)."""

    k: Array
    v: Array


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    w = min(cfg.sliding_window or max_len, max_len)
    if cfg.local_window:
        w = min(cfg.local_window, max_len)
    shape = (batch, w, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attn_decode(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    cache: KVCache,
    length: Array,
) -> tuple[Array, KVCache]:
    """One decode step.  x: (B, 1, d); length: scalar tokens-so-far.

    The new k/v row is rotated at its absolute position and written at slot
    ``length % W`` (ring semantics when a window bounds W; plain append
    otherwise).  Attention masks invalid (unwritten) slots; slot order is
    irrelevant because positions are encoded in the rotated keys.
    """
    b = x.shape[0]
    w = cache.k.shape[1]
    if cfg.mrope_sections:  # text-only decode: all three streams advance together
        pos = jnp.full((b, 1, len(cfg.mrope_sections)), length, jnp.int32)
    else:
        pos = jnp.full((b, 1), length, jnp.int32)
    q = _rope(cfg, _project_q(p, cfg, x), pos)
    k_new, v_new = _project_kv(p, cfg, x)
    k_new = _rope(cfg, k_new, pos)
    slot = (length % w).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, 1)
    # Slots 0..min(length, W-1) hold data (ring: all slots once length >= W).
    valid = jnp.arange(w) <= jnp.minimum(length, w - 1)  # (W,)
    mask = valid[None, None, None, None, :]  # -> (B, Hk, G, 1, W) by broadcast
    y = _attend(q, k, v, mask)
    y = y.reshape(b, 1, cfg.n_heads * cfg.hd)
    out = y @ p["wo"].astype(y.dtype)
    return out, KVCache(k, v)


# --------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# --------------------------------------------------------------------------
def cross_attn_kv(p: dict, cfg: ModelConfig, enc_out: Array) -> tuple[Array, Array]:
    return _project_kv(p, cfg, enc_out)


def cross_attn(p: dict, cfg: ModelConfig, x: Array, kv: tuple[Array, Array]) -> Array:
    b, s, _ = x.shape
    layout = "seq" if (not _head_axis_ok(cfg.n_heads) and s > 1) else "heads"
    q = _project_q(p, cfg, x, layout)
    y = _attend(q, kv[0], kv[1], None)
    y = y.reshape(b, s, cfg.n_heads * cfg.hd)
    return y @ p["wo"].astype(y.dtype)
