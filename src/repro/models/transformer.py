"""Decoder-only transformer assembly: scan-over-layers, remat, caches.

Homogeneous stacks (dense / vlm / moe / ssm) run under ``lax.scan`` with
stacked per-layer params (constant-size HLO regardless of depth -- essential
for 62-layer dry-run compiles) and per-layer ``jax.checkpoint`` when
``cfg.remat``.  Heterogeneous stacks (recurrentgemma's (rec,rec,attn) cycle)
use a Python loop.

Layer recipes:
  attn   : h += Attn(norm(h));        h += FFN(norm(h))
  moe    : h += Attn(norm(h));        h += MoE(norm(h))   (+aux loss)
  ssm    : h += Mamba(norm(h))                             (no FFN; mamba-1)
  rec    : h += RGLRU(norm(h));       h += FFN(norm(h))
  lattn  : h += LocalAttn(norm(h));   h += FFN(norm(h))    (window attention)
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.launch import mesh as meshlib

from . import attention as attn
from . import moe as moe_mod
from . import rglru as rg
from . import ssm as ssm_mod
from .common import ParamDef, mask_vocab_pad, norm_apply, norm_defs, vocab_padded
from .ffn import ffn_apply, ffn_defs

Array = jax.Array


# --------------------------------------------------------------------------
# Layer type plan
# --------------------------------------------------------------------------
def layer_types(cfg: ModelConfig) -> list[str]:
    if cfg.family == "ssm":
        return ["ssm"] * cfg.n_layers
    if cfg.family == "moe":
        return ["moe"] * cfg.n_layers
    if cfg.family == "hybrid":
        pat = cfg.block_pattern or ("rec",)
        types = [pat[i % len(pat)] for i in range(cfg.n_layers)]
        return ["lattn" if t == "attn" else t for t in types]
    return ["attn"] * cfg.n_layers


def is_scanned(cfg: ModelConfig) -> bool:
    types = layer_types(cfg)
    return cfg.scan_layers and len(set(types)) == 1 and cfg.n_layers > 1


# --------------------------------------------------------------------------
# Parameter definitions
# --------------------------------------------------------------------------
def _layer_defs(cfg: ModelConfig, kind: str) -> dict:
    if kind == "attn":
        return {
            "ln1": norm_defs(cfg.norm, cfg.d_model),
            "attn": attn.attn_defs(cfg),
            "ln2": norm_defs(cfg.norm, cfg.d_model),
            "mlp": ffn_defs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": norm_defs(cfg.norm, cfg.d_model),
            "attn": attn.attn_defs(cfg),
            "ln2": norm_defs(cfg.norm, cfg.d_model),
            "moe": moe_mod.moe_defs(cfg),
        }
    if kind == "ssm":
        return {"ln": norm_defs(cfg.norm, cfg.d_model), "mixer": ssm_mod.ssm_defs(cfg)}
    if kind == "rec":
        return {
            "ln1": norm_defs(cfg.norm, cfg.d_model),
            "rec": rg.rglru_defs(cfg),
            "ln2": norm_defs(cfg.norm, cfg.d_model),
            "mlp": ffn_defs(cfg),
        }
    if kind == "lattn":
        return {
            "ln1": norm_defs(cfg.norm, cfg.d_model),
            "attn": attn.attn_defs(cfg),
            "ln2": norm_defs(cfg.norm, cfg.d_model),
            "mlp": ffn_defs(cfg),
        }
    raise ValueError(kind)


def _stack_defs(defs: Any, n: int) -> Any:
    return jax.tree.map(
        lambda d: ParamDef((n,) + d.shape, (None,) + d.spec, d.init, d.scale),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def decoder_defs(cfg: ModelConfig) -> dict:
    types = layer_types(cfg)
    v_pad = vocab_padded(cfg.vocab)
    embed_spec = ("tp", None)  # vocab-sharded rows; d replicated (cheap lookup)
    defs: dict[str, Any] = {
        "embed": ParamDef((v_pad, cfg.d_model), embed_spec, "small"),
        "final_norm": norm_defs(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, v_pad), ("fsdp", "tp"))
    if is_scanned(cfg):
        defs["layers"] = _stack_defs(_layer_defs(cfg, types[0]), cfg.n_layers)
    else:
        defs["layers"] = [_layer_defs(cfg, t) for t in types]
    return defs


# --------------------------------------------------------------------------
# Layer application (full-sequence)
# --------------------------------------------------------------------------
def _apply_layer(
    p: dict,
    cfg: ModelConfig,
    kind: str,
    h: Array,
    positions: Array,
    *,
    collect: bool,
):
    """Returns (h, aux, cache_entry_or_None)."""
    zero = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        x = norm_apply(cfg.norm, h, p["ln"])
        if collect:
            y, state = ssm_mod.ssm_apply(p["mixer"], cfg, x, return_state=True)
        else:
            y, state = ssm_mod.ssm_apply(p["mixer"], cfg, x), None
        return h + y, zero, state
    if kind == "rec":
        x = norm_apply(cfg.norm, h, p["ln1"])
        if collect:
            y, state = rg.rglru_apply(p["rec"], cfg, x, return_state=True)
        else:
            y, state = rg.rglru_apply(p["rec"], cfg, x), None
        h = h + y
        h = h + ffn_apply(p["mlp"], cfg, norm_apply(cfg.norm, h, p["ln2"]))
        return h, zero, state
    # attention variants
    window = cfg.local_window if kind == "lattn" else cfg.sliding_window
    x = norm_apply(cfg.norm, h, p["ln1"])
    q_chunk = cfg.seq_chunk
    if collect:
        y, (k, v) = attn.attn_sequence(
            p["attn"], cfg, x, positions, window=window, q_chunk=q_chunk, return_kv=True
        )
        cache_entry = (k, v)
    else:
        y = attn.attn_sequence(
            p["attn"], cfg, x, positions, window=window, q_chunk=q_chunk
        )
        cache_entry = None
    h = h + y
    x2 = norm_apply(cfg.norm, h, p["ln2"])
    if kind == "moe":
        y2, aux = moe_mod.moe_apply(p["moe"], cfg, x2)
    else:
        y2, aux = ffn_apply(p["mlp"], cfg, x2), zero
    return h + y2, aux, cache_entry


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,
    positions: Array | None = None,
    *,
    collect_cache: bool = False,
):
    """Token ids -> final hidden states.  Returns (hidden, aux, cache)."""
    types = layer_types(cfg)
    if positions is None:
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)
    # Sequence parallelism (Megatron SP): between blocks the activations live
    # sharded (dp, tp, -); attention/FFN entry points re-gather what they
    # need and GSPMD turns the exits into reduce-scatters.  The lax.scan
    # carry (the remat-saved per-layer input) then costs 1/tp the HBM.
    sp = ("dp", "tp", None) if (cfg.seq_shard and tokens.shape[1] > 1) else ("dp", None, None)
    h = meshlib.constraint(h, *sp)

    if is_scanned(cfg):
        kind = types[0]

        def body(carry, lp):
            hh, aux = carry
            hh, aux_l, cache_e = _apply_layer(
                lp, cfg, kind, hh, positions, collect=collect_cache
            )
            hh = meshlib.constraint(hh, *sp)
            return (hh, aux + aux_l), cache_e

        if cfg.remat:
            body = jax.checkpoint(body)
        (h, aux), cache = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["layers"])
    else:
        aux = jnp.zeros((), jnp.float32)
        cache = []
        for lp, kind in zip(params["layers"], types):

            def fn(lp_, hh, kind=kind):  # params passed explicitly for remat
                return _apply_layer(lp_, cfg, kind, hh, positions, collect=collect_cache)

            if cfg.remat:
                fn = jax.checkpoint(fn, static_argnums=())
            h, aux_l, cache_e = fn(lp, h)
            h = meshlib.constraint(h, *sp)
            aux = aux + aux_l
            cache.append(cache_e)
        if not collect_cache:
            cache = None

    h = norm_apply(cfg.norm, h, params["final_norm"])
    return h, aux, cache


def lm_logits(params: dict, cfg: ModelConfig, h: Array) -> Array:
    dt = h.dtype
    if cfg.tie_embeddings:
        logits = h @ params["embed"].T.astype(dt)
    else:
        logits = h @ params["head"].astype(dt)
    logits = mask_vocab_pad(logits, cfg.vocab)
    return meshlib.constraint(logits, "dp", None, "tp")


# --------------------------------------------------------------------------
# Decode path
# --------------------------------------------------------------------------
class DecodeCache(NamedTuple):
    """Per-model cache pytree.  ``entries``: stacked KVCache / SSMState /
    LRUState for scanned stacks, or a list for loop stacks.  ``length``:
    tokens written so far (scalar int32)."""

    entries: Any
    length: Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> DecodeCache:
    types = layer_types(cfg)

    def one(kind: str):
        if kind == "ssm":
            return ssm_mod.init_ssm_state(cfg, batch, dtype)
        if kind == "rec":
            return rg.init_lru_state(cfg, batch, dtype)
        return attn.init_kv_cache(cfg, batch, max_len, dtype)

    if is_scanned(cfg):
        entry = one(types[0])
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), entry
        )
        return DecodeCache(stacked, jnp.zeros((), jnp.int32))
    return DecodeCache([one(t) for t in types], jnp.zeros((), jnp.int32))


def _decode_layer(p: dict, cfg: ModelConfig, kind: str, h: Array, entry, length):
    if kind == "ssm":
        y, entry = ssm_mod.ssm_decode(p["mixer"], cfg, norm_apply(cfg.norm, h, p["ln"]), entry)
        return h + y, entry
    if kind == "rec":
        y, entry = rg.rglru_decode(p["rec"], cfg, norm_apply(cfg.norm, h, p["ln1"]), entry)
        h = h + y
        h = h + ffn_apply(p["mlp"], cfg, norm_apply(cfg.norm, h, p["ln2"]))
        return h, entry
    x = norm_apply(cfg.norm, h, p["ln1"])
    y, entry = attn.attn_decode(p["attn"], cfg, x, entry, length)
    h = h + y
    x2 = norm_apply(cfg.norm, h, p["ln2"])
    if kind == "moe":
        y2, _ = moe_mod.moe_apply(p["moe"], cfg, x2)
    else:
        y2 = ffn_apply(p["mlp"], cfg, x2)
    return h + y2, entry


def decode_step(
    params: dict, cfg: ModelConfig, tokens: Array, cache: DecodeCache
) -> tuple[Array, DecodeCache]:
    """One decode step.  tokens: (B, 1) int32.  Returns (logits, new cache)."""
    types = layer_types(cfg)
    dt = jnp.dtype(cfg.compute_dtype)
    h = params["embed"][tokens].astype(dt)
    length = cache.length

    if is_scanned(cfg):
        kind = types[0]

        def body(hh, xs):
            lp, entry = xs
            hh, new_entry = _decode_layer(lp, cfg, kind, hh, entry, length)
            return hh, new_entry

        h, new_entries = jax.lax.scan(body, h, (params["layers"], cache.entries))
    else:
        new_entries = []
        for lp, kind, entry in zip(params["layers"], types, cache.entries):
            h, ne = _decode_layer(lp, cfg, kind, h, entry, length)
            new_entries.append(ne)

    h = norm_apply(cfg.norm, h, params["final_norm"])
    logits = lm_logits(params, cfg, h)
    return logits, DecodeCache(new_entries, length + 1)
