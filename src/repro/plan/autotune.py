"""Hardware-measured autotuning: the predict -> measure loop, closed.

The analytic model of :mod:`repro.plan.cost` compares candidates against
*nominal* roofline constants -- good enough to reproduce the paper's
Sec. 5.3.3 dispatch, blind to everything the constants miss (cache effects,
interpreter overhead, real collective latency, Pallas tile efficiency).
This module measures instead of predicting, the way the paper's Sec. 5
benchmarking drives its recommendation:

* :func:`tune` times, on the actual attached device, (a) candidate Pallas
  tilings for ``fused_mttkrp`` / ``multi_ttv`` and (b) every contraction
  node of every candidate (schedule x executor) plan, under a wall-clock
  ``budget_ms`` cap;
* :class:`TuningCache` persists the winners on disk -- keyed by
  ``(backend, shape, rank, dtype, n_devices)`` via :func:`problem_key` --
  with in-memory memoization, so tuning cost is paid once per
  (hardware, problem) pair;
* ``plan_sweep(strategy="autotune")`` resolves the cache through
  :func:`lookup_measurements` and argmins over measurements where a
  comparison set is fully measured, the analytic ``node_cost`` elsewhere;
  measured node times are stamped on ``ModeCost.measured_s`` (and therefore
  in ``SweepPlan.describe()``), tuned tile configs land on
  ``NodePlan.tiles``, and measured sharded/overlapping pairs recalibrate
  the ``serial_fractions`` overlap constants.

Measurement never happens implicitly: ``plan_sweep`` only ever *reads* the
cache (CI and cold starts fall back cleanly to the analytic model); only an
explicit :func:`tune` call runs kernels.
"""

from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Mapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.tensor_ops import dims_split, random_factors, tensor_norm

from .problem import Problem
from .schedule import ROOT, ContractionNode

Array = jax.Array

# Environment variable naming the on-disk cache file of the process-default
# cache (see default_tuning_cache); unset/empty means in-memory only.
CACHE_ENV = "REPRO_TUNING_CACHE"

# Candidate (block_i, block_b, block_batch) tilings for the fused MTTKRP
# kernel.  The default (128, 256, 8) is always measured first; the rest
# bracket it along both matmul axes (MXU-aligned multiples of 128 plus the
# half-tile 64, the small end for short modes).  block_batch sizes the
# batched kernel's leading grid axis and is inert for unbatched problems
# (effective batch tile 1), so dedup on the effective tile keeps unbatched
# tuning timing exactly the same candidate set as before.  Candidates are
# capped by the actual dims and deduped on the effective tile, so tiny
# problems time only what differs.
FUSED_TILE_CANDIDATES = (
    (128, 256, 8),  # the long-standing hard-coded default
    (64, 128, 8),
    (128, 128, 8),
    (256, 256, 8),
    (128, 512, 8),
    (256, 512, 8),
)

# Candidate (block_i, block_r, block_batch) tilings for the matrix-free
# MTTKRP kernel (default (128, 8, 8) first).  block_i sizes the target-mode
# output block held in VMEM, block_r caps every reduction-mode block (the
# wrapper shrinks further when the tensor tile would blow the VMEM budget),
# block_batch slabs the batched kernel's leading grid axis (inert unbatched).
MATRIX_FREE_TILE_CANDIDATES = (
    (128, 8, 8),
    (64, 8, 8),
    (128, 16, 8),
    (256, 8, 8),
    (64, 16, 8),
    (128, 4, 8),
)

# Candidate block_i tilings for the multi-TTV kernel (default 256 first).
TTV_TILE_CANDIDATES = (256, 64, 128, 512)

# Leaf algorithms the tuner measures head-to-head for a full mode-n MTTKRP.
# "fused" and "matrix_free" are measured only on the local executor (the
# Pallas kernels are single-device objects; sharded executors dispatch
# per-mode methods).
_LEAF_ALGORITHMS = ("1step", "2step-left", "2step-right", "fused", "matrix_free")
_EXTERNAL_LEAF_ALGORITHMS = ("1step", "fused", "matrix_free")
_KERNEL_LEAF_ALGORITHMS = ("fused", "matrix_free")


def backend_name() -> str:
    """The jax backend measurements are valid for (``cpu``/``gpu``/``tpu``)."""
    return str(jax.default_backend())


def problem_key(
    problem: Problem, *, backend: str | None = None, n_devices: int | None = None
) -> str:
    """Cache key of one (hardware, problem) pair.

    ``backend|shape|rank|dtype|devices``: measurements are only comparable
    on the same backend, for the same global shape/rank/dtype, on the same
    device count (the per-device blocks and collectives change with it).
    ``n_devices`` defaults to the product of the problem's mesh axis sizes
    (1 when unsharded) -- NOT the runtime device count, so plans for
    detached hardware key consistently.

    The construction itself is :meth:`repro.plan.problem.Problem.signature`
    (the one canonical key, shared with the serving engine's batch buckets);
    this wrapper only fills in the live jax backend.  Batched problems
    append a ``|b{B}`` field; unbatched keys keep the historical 5-field
    layout, so entries tuned before the batch dimension existed keep
    resolving for B=1.
    """
    backend = backend_name() if backend is None else str(backend)
    return problem.signature(backend=backend, n_devices=n_devices)


def node_key(
    node: ContractionNode, algorithm: str, executor: str, collective: str = "flat"
) -> str:
    """Measurement key of one schedule node's contraction.

    Keys on the contraction itself -- executor kind, algorithm, kept range,
    parent range, and whether the source is the raw tensor -- not on the
    schedule it appeared in, so identical nodes shared by several candidate
    trees (e.g. a root leaf present in both the flat and a binary schedule)
    are measured once and recognized everywhere.  Hierarchical-collective
    measurements append a ``|coll=hierarchical`` field; flat keys keep the
    historical layout so entries tuned before two-level collectives existed
    keep resolving.
    """
    src = "root" if node.from_root else "partial"
    key = (
        f"{executor}|{algorithm}|{src}|keep={node.lo}:{node.hi}"
        f"|parent={node.parent_lo}:{node.parent_hi}"
    )
    if collective != "flat":
        key += f"|coll={collective}"
    return key


@dataclass(frozen=True)
class Measurements:
    """One problem's resolved tuning entry, as the planner consumes it.

    ``node_s`` maps :func:`node_key` strings to measured median seconds;
    ``tiles`` maps kernel name (``"fused_mttkrp"`` / ``"matrix_free"`` /
    ``"multi_ttv"``) to its tuned tile config (``{"block_i": ...,
    "block_b": ...}`` / ``{"block_i": ..., "block_r": ...}`` subsets);
    ``serial_fractions`` are the overlap constants recalibrated from
    measured sharded/overlapping node pairs (empty when nothing paired);
    ``pp`` holds the pairwise-perturbation rows (``"build_s"`` for the
    cache materialization, ``"correct_sweep_s"`` for one correction-only
    sweep) when the tuned problem opted in via ``pp_tol``.
    """

    node_s: Mapping[str, float] = field(default_factory=dict)
    tiles: Mapping[str, Mapping[str, int]] = field(default_factory=dict)
    serial_fractions: Mapping[str, float] = field(default_factory=dict)
    pp: Mapping[str, float] = field(default_factory=dict)

    def node_time(
        self,
        node: ContractionNode,
        algorithm: str,
        executor: str,
        collective: str = "flat",
    ) -> float | None:
        """Measured seconds for one node contraction under one collective
        decomposition (``"flat"`` / ``"hierarchical"``), ``None`` if
        unmeasured."""
        return self.node_s.get(node_key(node, algorithm, executor, collective))

    def kernel_tiles(self, kernel: str) -> dict[str, int] | None:
        """Tuned tile config for one kernel name, ``None`` if untuned."""
        t = self.tiles.get(kernel)
        return {k: int(v) for k, v in t.items()} if t else None

    def pp_second(self, key: str) -> float | None:
        """Measured seconds of one PP row (``"build_s"`` /
        ``"correct_sweep_s"``), ``None`` when the entry was tuned without
        pairwise perturbation."""
        v = self.pp.get(key)
        return float(v) if v is not None else None


class TuningCache:
    """Persistent ``{problem_key: entry}`` store with in-memory memoization.

    Entries are plain JSON dicts (see :func:`tune` for the layout).  A cache
    built with ``path=None`` lives in memory only; with a path, every
    :meth:`put` rewrites the file atomically-enough for the single-writer
    tuning workflow, and construction loads whatever the file already holds
    -- so winners measured in one process are visible to the next
    (``REPRO_TUNING_CACHE`` names the process-default file; CI uploads it
    as an artifact next to the benchmark JSON).
    """

    def __init__(self, path: str | os.PathLike | None = None):
        """Load ``path`` if it exists; ``None`` -> in-memory only."""
        self.path = Path(path) if path else None
        self._entries: dict[str, dict] = {}
        if self.path is not None and self.path.exists():
            text = self.path.read_text()
            # a pre-created empty file (mkstemp, `touch`) is an empty store;
            # anything else must parse -- a corrupt cache should be loud
            self._entries = json.loads(text) if text.strip() else {}

    def get(self, key: str) -> dict | None:
        """The entry stored under ``key``, or ``None`` (memoized in memory)."""
        return self._entries.get(key)

    def put(self, key: str, entry: dict) -> None:
        """Store ``entry`` under ``key`` and persist to disk when backed."""
        self._entries[key] = entry
        self.save()

    def save(self) -> None:
        """Write the full store to ``self.path`` (no-op when memory-only)."""
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps(self._entries, indent=1))

    def keys(self) -> list[str]:
        """All problem keys currently held (in-memory view)."""
        return list(self._entries)


_default_cache: TuningCache | None = None


def default_tuning_cache() -> TuningCache:
    """The process-default cache ``plan_sweep(strategy="autotune")`` reads.

    Backed by the file named in ``$REPRO_TUNING_CACHE`` when set (created
    lazily), in-memory otherwise.  Built once per process.
    """
    global _default_cache
    if _default_cache is None:
        _default_cache = TuningCache(os.environ.get(CACHE_ENV) or None)
    return _default_cache


def lookup_measurements(
    problem: Problem, cache: TuningCache | None = None
) -> Measurements | None:
    """Resolve ``problem``'s tuning entry into planner-ready Measurements.

    Reads ``cache`` (the process default when ``None``); returns ``None``
    when the problem was never tuned on this backend/device-count -- the
    planner then falls back to the purely analytic model, which is the CI
    default (measurement never happens implicitly).
    """
    cache = cache or default_tuning_cache()
    entry = cache.get(problem_key(problem))
    if not entry:
        return None
    node_s = {r["key"]: float(r["measured_s"]) for r in entry.get("nodes", [])}
    tiles = {
        k: {
            kk: int(vv)
            for kk, vv in v.items()
            if kk in ("block_i", "block_b", "block_r", "block_batch")
        }
        for k, v in entry.get("tiles", {}).items()
        if v
    }
    return Measurements(
        node_s=node_s,
        tiles=tiles,
        serial_fractions={
            str(k): float(v)
            for k, v in entry.get("serial_fractions", {}).items()
        },
        pp={str(k): float(v) for k, v in entry.get("pp", {}).items()},
    )


# ------------------------------------------------------------ measurement
class _Budget:
    """Wall-clock budget for one tune() call (compile time counts too)."""

    def __init__(self, budget_ms: float | None):
        self.budget_ms = budget_ms
        self.t0 = time.perf_counter()

    def exhausted(self) -> bool:
        if self.budget_ms is None:
            return False
        return (time.perf_counter() - self.t0) * 1e3 >= self.budget_ms


def _time(fn: Callable[[], Any], reps: int) -> float:
    """Median wall seconds of ``fn()`` with one compile/warmup call excluded."""
    jax.block_until_ready(fn())
    times = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _tile_rows(
    candidates: Sequence[tuple[int, ...]],
    effective: Callable[[tuple[int, ...]], tuple[int, ...]],
    run: Callable[[tuple[int, ...]], Any],
    reps: int,
    budget: _Budget,
) -> list[dict]:
    """Time deduped tile candidates; the default candidate is always first."""
    rows: list[dict] = []
    seen: set[tuple[int, ...]] = set()
    for i, cand in enumerate(candidates):
        eff = effective(cand)
        if eff in seen:
            continue
        if i > 0 and budget.exhausted():
            break
        seen.add(eff)
        rows.append(
            {
                "candidate": list(cand),
                "effective": list(eff),
                "is_default": i == 0,
                "measured_s": _time(lambda c=cand: run(c), reps),
            }
        )
    return rows


def _summarize_tiles(rows: list[dict], names: tuple[str, ...], mode: int) -> dict:
    """Best/default summary of one kernel's measured tile rows."""
    best = min(rows, key=lambda r: r["measured_s"])
    default = rows[0]  # the default candidate is always measured first
    out = {nm: best["candidate"][k] for k, nm in enumerate(names)}
    out.update(
        {
            "mode": mode,
            "default_s": default["measured_s"],
            "tuned_s": best["measured_s"],
            "speedup_vs_default": (
                default["measured_s"] / best["measured_s"]
                if best["measured_s"] > 0
                else 1.0
            ),
            "rows": rows,
        }
    )
    return out


def _tune_fused_tiles(
    x: Array, factors: Sequence[Array], *, reps: int, budget: _Budget
) -> dict:
    """Measure candidate fused-MTTKRP tilings on a representative internal
    mode; the winner feeds both ``NodePlan.tiles`` and the tuner's own
    ``fused`` node measurements (so the argmin times what will execute)."""
    from repro.kernels import ops as kops  # lazy: kernels import pallas

    n = x.ndim // 2  # internal mode: the kernel's primary bilinear layout
    _, in_dim, big_r = dims_split(x.shape, n)
    # tuning runs unbatched (batch tile effectively 1), so block_batch never
    # splits the candidate set here; the tuned value rides along for the
    # batched kernel to consume
    rows = _tile_rows(
        FUSED_TILE_CANDIDATES,
        lambda cand: (min(in_dim, cand[0]), min(big_r, cand[1]), 1),
        lambda cand: kops.fused_mttkrp(
            x, list(factors), n, block_i=cand[0], block_b=cand[1]
        ),
        reps,
        budget,
    )
    return _summarize_tiles(rows, ("block_i", "block_b", "block_batch"), n)


def _tune_matrix_free_tiles(
    x: Array, factors: Sequence[Array], *, reps: int, budget: _Budget
) -> dict:
    """Measure candidate matrix-free tilings on the same representative
    internal mode as the fused tuner; the winner feeds ``NodePlan.tiles``
    and the tuner's ``matrix_free`` node measurements."""
    from repro.kernels import ops as kops  # lazy: kernels import pallas

    n = x.ndim // 2
    in_dim = x.shape[n]
    red_max = max(d for k, d in enumerate(x.shape) if k != n)
    # effective tile: block_i clamped to the mode, block_r to the largest
    # reduction extent (batch tile effectively 1; the tuned block_batch
    # rides along for the batched kernel, exactly as with fused)
    rows = _tile_rows(
        MATRIX_FREE_TILE_CANDIDATES,
        lambda cand: (min(in_dim, cand[0]), min(red_max, cand[1]), 1),
        lambda cand: kops.matrix_free_mttkrp(
            x, list(factors), n, block_i=cand[0], block_r=cand[1]
        ),
        reps,
        budget,
    )
    return _summarize_tiles(rows, ("block_i", "block_r", "block_batch"), n)


def _tune_ttv_tiles(
    x: Array, factors: Sequence[Array], *, reps: int, budget: _Budget
) -> dict:
    """Measure candidate multi-TTV tilings (the 2nd step of Alg. 4).

    The winner parameterizes the public kernelized entry point
    ``repro.kernels.ops.mttkrp_2step_kernel(block_i=...)`` -- the planner's
    ``2step-*`` algorithms use the XLA einsum second step, so this runs
    *after* node timing in :func:`tune` and only spends leftover budget.
    """
    from repro.kernels import ops as kops  # lazy: kernels import pallas

    n = x.ndim // 2
    c = factors[0].shape[1]
    big_l, in_dim, big_r = dims_split(x.shape, n)
    # multi-TTV operands at this mode's 2-step shapes: the partial tensor is
    # (min(L,R), I_n, C) and the complementary KRP (min(L,R), C); random
    # payloads -- timing depends on shapes/tiles, not values.
    small = min(big_l, big_r)
    t3 = jax.random.normal(jax.random.PRNGKey(0), (small, in_dim, c), jnp.float32)
    w2 = jax.random.normal(jax.random.PRNGKey(1), (small, c), jnp.float32)
    rows = _tile_rows(
        tuple((b,) for b in TTV_TILE_CANDIDATES),
        lambda cand: (min(in_dim, cand[0]),),
        lambda cand: kops.multi_ttv(t3, w2, block_i=cand[0]),
        reps,
        budget,
    )
    return _summarize_tiles(rows, ("block_i",), n)


def _leaf_algorithms(problem: Problem, node: ContractionNode, kind: str) -> tuple[str, ...]:
    """Algorithm candidates the tuner measures for one root-leaf MTTKRP."""
    algs = (
        _EXTERNAL_LEAF_ALGORITHMS
        if problem.external_mode(node.mode)
        else _LEAF_ALGORITHMS
    )
    # the Pallas kernels are single-device objects; measure them locally only
    if kind == "local":
        return algs
    return tuple(a for a in algs if a not in _KERNEL_LEAF_ALGORITHMS)


def _tune_nodes(
    problem: Problem,
    x: Array,
    factors: Sequence[Array],
    *,
    mesh,
    mode_axes,
    reps: int,
    budget: _Budget,
    fused_tiles: Mapping[str, int] | None = None,
    matrix_free_tiles: Mapping[str, int] | None = None,
) -> list[dict]:
    """Measure every node of every candidate (schedule x executor) plan.

    Walks each candidate schedule exactly like the sweep engine (parents'
    outputs cached for their children, carry-bearing executors measured
    through their carry path), timing each deduped :func:`node_key` once.
    Root leaves are measured under every competing algorithm -- ``fused``
    with ``fused_tiles`` and ``matrix_free`` with ``matrix_free_tiles``
    (the already-tuned tilings), so the argmin times exactly the
    configuration the resulting plan will execute.  On two-level problems
    (``Problem.intra_axes``) every node whose reduction spans both levels
    is additionally measured under ``collective="hierarchical"``, so the
    planner's per-node flat-vs-hierarchical pick argmins over measured
    head-to-head times rather than modeled bandwidths.  Stops
    cleanly when ``budget`` runs out -- unmeasured nodes simply keep their
    analytic costs at plan time.
    """
    from .cost import hierarchical_applicable  # lazy: cost imports schedule
    from .executor import make_executor  # lazy: avoids an import cycle
    from .planner import plan_sweep
    from .schedule import enumerate_schedules

    kinds = (
        ("sharded", "overlapping", "compressed") if problem.sharded else ("local",)
    )
    # flat first: its leaves are the full per-mode MTTKRPs every tree shares,
    # so a tight budget still measures the comparisons that matter most
    schedules = sorted(enumerate_schedules(problem), key=lambda s: not s.is_flat)
    rows: list[dict] = []
    seen: set[str] = set()
    for kind in kinds:
        ex = make_executor(kind, mesh, mode_axes, node_axis=problem.node_axis)
        xs, fs = ex.prepare(problem, x, list(factors))
        for sched in schedules:
            plan = plan_sweep(problem, schedule=sched, executor=kind)
            carry = (
                ex.init_carry(plan, xs, fs) if hasattr(ex, "init_carry") else None
            )
            cache: dict[int, Array] = {ROOT: xs}
            for node in sched.walk():
                src = cache[node.parent]
                planned = plan.node_plan(node.id).algorithm
                algs = (
                    _leaf_algorithms(problem, node, kind)
                    if node.from_root and node.is_leaf
                    else (planned,)
                )
                colls = (
                    ("flat", "hierarchical")
                    if kind != "local"
                    and hierarchical_applicable(problem, node.reduce_axes)
                    else ("flat",)
                )
                out = None
                for alg in algs:
                    if alg == "fused":
                        tl = fused_tiles
                    elif alg == "matrix_free":
                        tl = matrix_free_tiles
                    else:
                        tl = None
                    run_out = None
                    for coll in colls:
                        key = node_key(node, alg, kind, coll)
                        if carry is not None:
                            fn = jax.jit(
                                lambda s, f, c, node=node, alg=alg, tl=tl, coll=coll: (
                                    ex.contract_carry(
                                        node, s, f, alg, c, tiles=tl, collective=coll
                                    )
                                )
                            )
                            if key not in seen and not budget.exhausted():
                                seen.add(key)
                                rows.append(
                                    {
                                        "key": key,
                                        "executor": kind,
                                        "algorithm": alg,
                                        "collective": coll,
                                        "schedule": sched.name,
                                        "node": node.id,
                                        "measured_s": _time(
                                            lambda: fn(src, fs, carry)[0], reps
                                        ),
                                    }
                                )
                            if alg == planned and coll == "flat":
                                run_out, carry = fn(src, fs, carry)
                        else:
                            fn = jax.jit(
                                lambda s, f, node=node, alg=alg, tl=tl, coll=coll: (
                                    ex.contract(
                                        node, s, f, alg, tiles=tl, collective=coll
                                    )
                                )
                            )
                            if key not in seen and not budget.exhausted():
                                seen.add(key)
                                rows.append(
                                    {
                                        "key": key,
                                        "executor": kind,
                                        "algorithm": alg,
                                        "collective": coll,
                                        "schedule": sched.name,
                                        "node": node.id,
                                        "measured_s": _time(
                                            lambda: fn(src, fs), reps
                                        ),
                                    }
                                )
                            if alg == planned and coll == "flat":
                                run_out = fn(src, fs)
                    if run_out is not None:
                        out = run_out
                if not node.is_leaf:
                    cache[node.id] = out
    return rows


def _recalibrate_serial_fractions(
    problem: Problem, rows: Sequence[Mapping[str, Any]]
) -> dict[str, float]:
    """Fit the overlapping executor's unhidable fraction from measured pairs.

    For every node measured under both ``sharded`` and ``overlapping`` the
    bounded-overlap model says ``t_sh - t_ov = (1 - f) * min(compute,
    collective)``; the hidable term comes from the analytic predictions of
    the same node (``(pred_sh - pred_ov) / predicted_overlap_efficiency``).
    Median over pairs, clamped to [0, 1]; empty when nothing paired (e.g.
    local problems).  Same estimator as ``bench_mttkrp --calibrate``, fed by
    node measurements instead of the bench's dedicated overlap loop.
    """
    from .cost import node_cost  # lazy: cost imports schedule, not us
    from .schedule import enumerate_schedules

    if not problem.sharded:
        return {}
    by_key = {r["key"]: float(r["measured_s"]) for r in rows}
    nodes_by_sig: dict[str, ContractionNode] = {}
    for sched in enumerate_schedules(problem):
        for node in sched.walk():
            if node.is_root:
                continue
            sig = node_key(node, "x", "x")
            nodes_by_sig.setdefault(sig, node)
    fits: list[float] = []
    for r in rows:
        if r["executor"] != "sharded":
            continue
        ov_key = r["key"].replace("sharded|", "overlapping|", 1)
        t_ov = by_key.get(ov_key)
        if t_ov is None:
            continue
        node = nodes_by_sig.get(node_key_from(r["key"]))
        if node is None:
            continue
        alg = r["algorithm"]
        kw = dict(algorithm=alg) if node.from_root and node.is_leaf else {}
        pred_sh = node_cost(problem, node, "sharded", **kw)
        pred_ov = node_cost(problem, node, "overlapping", **kw)
        eff = pred_ov.predicted_overlap_efficiency
        if eff <= 0.0:
            continue
        min_term = (pred_sh.predicted_s - pred_ov.predicted_s) / eff
        if min_term <= 0.0:
            continue
        f = 1.0 - (float(r["measured_s"]) - t_ov) / min_term
        fits.append(min(1.0, max(0.0, f)))
    if not fits:
        return {}
    fits.sort()
    return {"sharded": 1.0, "overlapping": fits[len(fits) // 2]}


def node_key_from(key: str) -> str:
    """Normalize a measurement key to its executor/algorithm-free signature
    (the node topology part), for pairing measurements across executors."""
    _, _, rest = key.split("|", 2)
    return f"x|x|{rest}"


def _tune_pp(
    problem: Problem,
    x: Array,
    factors: Sequence[Array],
    *,
    mesh,
    mode_axes,
    reps: int,
    budget: _Budget,
) -> dict[str, float]:
    """Measure the two pairwise-perturbation phases for a ``pp_tol > 0``
    problem: ``build_s`` (cache materialization -- pairwise intermediates +
    bases, i.e. what every exact sweep additionally pays) and
    ``correct_sweep_s`` (one correction-only approximate sweep -- what
    replaces the exact sweep while drifts stay under tolerance).  These are
    the measured inputs of :func:`repro.plan.cost.pp_amortized_cost`."""
    from . import sweep as sweeplib  # lazy: sweep imports planner/executor
    from .executor import make_executor
    from .planner import plan_sweep

    kind = "sharded" if problem.sharded else "local"
    ex = make_executor(kind, mesh, mode_axes)
    xs, fs = ex.prepare(problem, x, list(factors))
    build = jax.jit(lambda t, f: sweeplib._pp_materialize(problem, ex, t, f, 0))
    rows: dict[str, float] = {}
    if budget.exhausted():
        return rows
    rows["build_s"] = _time(lambda: build(xs, fs), reps)
    if budget.exhausted():
        return rows
    plan = plan_sweep(problem, executor=kind, schedule="flat")
    state = sweeplib.SweepState(
        x=xs,
        factors=list(fs),
        weights=jnp.ones((problem.rank,), xs.dtype),
        norm_x=tensor_norm(xs).astype(xs.dtype),
        it=jnp.asarray(0),
        grams=sweeplib.grams(fs),
        pp=build(xs, fs),
    )
    corr = jax.jit(lambda st: sweeplib._pp_sweep(problem, plan, st))
    rows["correct_sweep_s"] = _time(lambda: corr(state), reps)
    return rows


def tune(
    x: Array,
    rank: int,
    *,
    factors: Sequence[Array] | None = None,
    mesh=None,
    mode_axes: Mapping[int, str] | None = None,
    cache: TuningCache | None = None,
    budget_ms: float | None = 2000.0,
    reps: int = 3,
    seed: int = 0,
    pp_tol: float = 0.0,
    intra_axes: Sequence[str] = (),
) -> dict:
    """Measure tiles + candidate plans for ``x``'s problem; persist winners.

    The one measuring entry point (nothing else runs kernels): in budget
    priority order, times candidate fused-MTTKRP tilings
    (:data:`FUSED_TILE_CANDIDATES`) and matrix-free tilings
    (:data:`MATRIX_FREE_TILE_CANDIDATES`), then every contraction node of
    every candidate (schedule x executor) plan -- ``fused`` /
    ``matrix_free`` leaves under the just-tuned tilings, so the argmin
    times what will execute -- then candidate multi-TTV tilings
    (:data:`TTV_TILE_CANDIDATES`; consumed by the public
    ``mttkrp_2step_kernel``, so it only spends leftover budget).
    Capped by ``budget_ms`` of wall clock (compile time included; ``None``
    = no cap); recalibrates ``serial_fractions`` from measured
    sharded/overlapping pairs, and stores the entry in ``cache`` (the
    process default when ``None``) under :func:`problem_key`.  Pass
    ``mesh`` + ``mode_axes`` to tune a sharded problem; ``factors`` default
    to random ones (timing depends on shapes, not values).  ``pp_tol > 0``
    tunes the pairwise-perturbation variant of the problem (its own cache
    key, via the signature's ``|pp`` field) and additionally measures the
    PP cache build and one correction-only sweep into the entry's ``pp``
    rows, which ``plan_sweep`` then prefers over the analytic PP estimates.
    ``intra_axes`` declares the fast (intra-node) mesh axes of a two-level
    mesh, exactly as on :class:`Problem`: nodes whose reductions span both
    levels are then measured under flat AND hierarchical collectives, and
    the resulting entry keys include the node-topology field so two-level
    measurements never collide with single-level ones.
    Returns the stored entry dict.
    """
    cache = cache or default_tuning_cache()
    problem = Problem.from_tensor(
        x, rank, mode_axes=mode_axes, mesh=mesh, pp_tol=pp_tol,
        intra_axes=intra_axes,
    )
    if factors is None:
        factors = random_factors(jax.random.PRNGKey(seed), x.shape, rank, x.dtype)
    budget = _Budget(budget_ms)
    fused = _tune_fused_tiles(x, factors, reps=reps, budget=budget)
    mfree = _tune_matrix_free_tiles(x, factors, reps=reps, budget=budget)
    rows = _tune_nodes(
        problem, x, factors, mesh=mesh, mode_axes=mode_axes, reps=reps,
        budget=budget,
        fused_tiles={
            "block_i": fused["block_i"],
            "block_b": fused["block_b"],
            "block_batch": fused["block_batch"],
        },
        matrix_free_tiles={
            "block_i": mfree["block_i"],
            "block_r": mfree["block_r"],
            "block_batch": mfree["block_batch"],
        },
    )
    tiles = {
        "fused_mttkrp": fused,
        "matrix_free": mfree,
        "multi_ttv": _tune_ttv_tiles(x, factors, reps=reps, budget=budget),
    }
    pp_rows = (
        _tune_pp(
            problem, x, factors, mesh=mesh, mode_axes=mode_axes,
            reps=reps, budget=budget,
        )
        if problem.pp_tol > 0.0
        else {}
    )
    entry = {
        "backend": backend_name(),
        "n_devices": (
            math.prod(problem.axis_sizes.values()) if problem.axis_sizes else 1
        ),
        "budget_ms": budget_ms,
        "reps": reps,
        "elapsed_ms": (time.perf_counter() - budget.t0) * 1e3,
        "tiles": tiles,
        "nodes": rows,
        "serial_fractions": _recalibrate_serial_fractions(problem, rows),
        "pp": pp_rows,
    }
    cache.put(problem_key(problem), entry)
    return entry
