"""``repro.plan`` -- one front door: Problem -> SweepPlan -> Executor.

The solver API redesigned around four pieces:

* :class:`Problem` -- immutable descriptor (shape, rank, dtype, optional
  mode->mesh-axis mapping) every planner call keys on.
* :class:`Schedule` -- the contraction-schedule IR: a tree of
  :class:`ContractionNode` GEMMs whose leaves are the N mode updates.  The
  flat per-mode sweep and the binary dimension tree are two degenerate
  shapes (:func:`flat_schedule` / :func:`binary_schedule`); multi-level
  trees (:func:`chain_schedule`, :func:`build_schedule`) reuse partial
  contractions across levels.
* :func:`plan_sweep` -- the cost-model planner: jointly argmins the tree
  shape (:func:`enumerate_schedules`), each root leaf's MTTKRP algorithm
  (1-step / 2-step-left / 2-step-right / fused), and -- via
  :func:`select_executor` -- the executor kind (local / sharded /
  overlapping / compressed) under the bounded-overlap model, per-node
  (:func:`node_cost`); :meth:`SweepPlan.describe` exposes the predictions
  so benchmarks report predicted-vs-measured, and calibrated
  ``serial_fractions`` from ``bench_mttkrp --calibrate`` feed back in.
* :class:`Executor` -- where contractions run: :class:`LocalExecutor`
  (single device), :class:`ShardedExecutor` (``shard_map`` + minimal
  per-node psum over a device mesh), :class:`OverlappingExecutor` (chunked
  psums hidden behind the local GEMMs -- full MTTKRPs and tree partials
  alike; exact), or :class:`CompressedShardedExecutor` (int8 error-feedback
  collectives with per-node residuals; approximate).
  :func:`make_executor` builds the instance a ``SweepPlan.executor`` kind
  names.

* :mod:`repro.plan.autotune` -- the measured side of the loop:
  :func:`tune` times candidate Pallas tilings and candidate
  (schedule x executor) plans on the attached device, a persistent
  :class:`TuningCache` (keyed by backend/shape/rank/dtype/device-count)
  remembers the winners, and ``plan_sweep(strategy="autotune")`` argmins
  over the measurements where available (stamping ``measured_s`` into
  ``SweepPlan.describe()`` and tuned tiles onto ``NodePlan.tiles``),
  falling back to the analytic model everywhere else.

* Two-level collectives (Ballard/Knight/Rouse, arXiv 1708.07401): problems
  built with ``intra_axes`` declare a fast intra-node level of the mesh;
  the cost model then prices each node psum's intra/inter traffic
  separately (:func:`collective_level_bytes`), the planner picks flat vs
  hierarchical per node (:func:`hierarchical_applicable` gates it),
  enumerates alternative mode->axis mappings, and certifies the winner
  against the per-node communication lower bound
  (:func:`mttkrp_comm_lower_bound`) -- stamped as
  ``SweepPlan.certified_bandwidth_optimal``.

* Pairwise perturbation (Ma & Solomonik, arXiv 2010.12056):
  ``Problem(pp_tol > 0)`` opts a problem into approximate sweeps that
  reuse cached pairwise intermediates (:func:`pp_pairs` describes them,
  :class:`PPState` carries them) plus first-order corrections while every
  factor's drift stays under tolerance, re-materializing exactly when one
  crosses it.  :func:`pp_amortized_cost` prices the amortized sweep so
  ``plan_sweep`` can argmin PP against the exact strategies
  (``strategy="pp"`` forces it); ``pp_tol=0`` problems never build the
  cache and stay bitwise identical to classic exact ALS.

Exactly one :func:`als_sweep` engine (a schedule walker) and one
:func:`cp_als` driver (sync-free: ``sweeps_per_sync`` sweeps per device
dispatch under ``lax.scan``, bitwise-identical iterates) consume them; the pre-redesign entry points
(``core.cpals.cp_als``, ``core.dimtree.dimtree_sweep``,
``dist.dist_mttkrp.dist_cp_als`` / ``dist_dimtree_sweep``) remain as frozen
thin wrappers that build the corresponding plan.
"""

from .autotune import (
    Measurements,
    TuningCache,
    default_tuning_cache,
    lookup_measurements,
    tune,
)
from .cost import (
    ALGORITHMS,
    DEFAULT_OVERLAP_CHUNKS,
    EXECUTORS,
    PP_EXACT_FRACTION,
    ModeCost,
    collective_level_bytes,
    compressed_allgather_bytes,
    dimtree_mode_cost,
    executor_mode_cost,
    hierarchical_applicable,
    mode_cost,
    mttkrp_comm_lower_bound,
    node_cost,
    pp_amortized_cost,
    pp_build_cost,
    pp_correction_cost,
    ring_allreduce_bytes,
    validate_executor,
)
from .executor import (
    CompressedShardedExecutor,
    Executor,
    LocalExecutor,
    OverlappingExecutor,
    ShardedExecutor,
    make_executor,
)
from .planner import (
    SCHEDULE_NAMES,
    STRATEGIES,
    ModePlan,
    NodePlan,
    SweepPlan,
    plan_sweep,
    select_executor,
)
from .problem import Problem
from .schedule import (
    ContractionNode,
    PPPair,
    Schedule,
    binary_schedule,
    build_schedule,
    chain_schedule,
    enumerate_schedules,
    flat_schedule,
    pp_pairs,
)
from .sweep import PPState, SweepState, als_sweep, cp_als, legacy_sweep

__all__ = [
    "ALGORITHMS",
    "DEFAULT_OVERLAP_CHUNKS",
    "EXECUTORS",
    "PP_EXACT_FRACTION",
    "SCHEDULE_NAMES",
    "STRATEGIES",
    "CompressedShardedExecutor",
    "ContractionNode",
    "Executor",
    "LocalExecutor",
    "Measurements",
    "ModeCost",
    "ModePlan",
    "NodePlan",
    "OverlappingExecutor",
    "PPPair",
    "PPState",
    "Problem",
    "Schedule",
    "ShardedExecutor",
    "SweepPlan",
    "SweepState",
    "TuningCache",
    "als_sweep",
    "binary_schedule",
    "build_schedule",
    "chain_schedule",
    "collective_level_bytes",
    "compressed_allgather_bytes",
    "cp_als",
    "default_tuning_cache",
    "dimtree_mode_cost",
    "enumerate_schedules",
    "executor_mode_cost",
    "flat_schedule",
    "hierarchical_applicable",
    "legacy_sweep",
    "lookup_measurements",
    "make_executor",
    "mode_cost",
    "mttkrp_comm_lower_bound",
    "node_cost",
    "plan_sweep",
    "pp_amortized_cost",
    "pp_build_cost",
    "pp_correction_cost",
    "pp_pairs",
    "ring_allreduce_bytes",
    "select_executor",
    "tune",
    "validate_executor",
]
