"""``repro.plan`` -- one front door: Problem -> SweepPlan -> Executor.

The solver API redesigned around three pieces:

* :class:`Problem` -- immutable descriptor (shape, rank, dtype, optional
  mode->mesh-axis mapping) every planner call keys on.
* :func:`plan_sweep` -- picks each mode's MTTKRP algorithm (1-step /
  2-step-left / 2-step-right / dimension-tree / fused) from the analytic
  flop/byte/collective cost model, and -- via :func:`select_executor` --
  the executor kind (local / sharded / overlapping / compressed) under the
  bounded-overlap model; :meth:`SweepPlan.describe` exposes the predictions
  so benchmarks report predicted-vs-measured.
* :class:`Executor` -- where contractions run: :class:`LocalExecutor`
  (single device), :class:`ShardedExecutor` (``shard_map`` + minimal psum
  over a device mesh), :class:`OverlappingExecutor` (chunked psums hidden
  behind the local GEMMs; exact), or :class:`CompressedShardedExecutor`
  (int8 error-feedback factor all-reduce; approximate).
  :func:`make_executor` builds the instance a ``SweepPlan.executor`` kind
  names.

Exactly one :func:`als_sweep` engine and one :func:`cp_als` driver consume
them; the pre-redesign entry points (``core.cpals.cp_als``,
``core.dimtree.dimtree_sweep``, ``dist.dist_mttkrp.dist_cp_als`` /
``dist_dimtree_sweep``) remain as frozen thin wrappers that build the
corresponding plan.
"""

from .cost import (
    ALGORITHMS,
    DEFAULT_OVERLAP_CHUNKS,
    EXECUTORS,
    ModeCost,
    compressed_allgather_bytes,
    dimtree_mode_cost,
    executor_mode_cost,
    mode_cost,
    ring_allreduce_bytes,
)
from .executor import (
    CompressedShardedExecutor,
    Executor,
    LocalExecutor,
    OverlappingExecutor,
    ShardedExecutor,
    make_executor,
)
from .planner import STRATEGIES, ModePlan, SweepPlan, plan_sweep, select_executor
from .problem import Problem
from .sweep import SweepState, als_sweep, cp_als, legacy_sweep

__all__ = [
    "ALGORITHMS",
    "DEFAULT_OVERLAP_CHUNKS",
    "EXECUTORS",
    "STRATEGIES",
    "CompressedShardedExecutor",
    "Executor",
    "LocalExecutor",
    "ModeCost",
    "ModePlan",
    "OverlappingExecutor",
    "Problem",
    "ShardedExecutor",
    "SweepPlan",
    "SweepState",
    "als_sweep",
    "compressed_allgather_bytes",
    "cp_als",
    "dimtree_mode_cost",
    "executor_mode_cost",
    "legacy_sweep",
    "make_executor",
    "mode_cost",
    "plan_sweep",
    "ring_allreduce_bytes",
    "select_executor",
]
