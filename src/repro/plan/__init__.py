"""``repro.plan`` -- one front door: Problem -> SweepPlan -> Executor.

The solver API redesigned around three pieces:

* :class:`Problem` -- immutable descriptor (shape, rank, dtype, optional
  mode->mesh-axis mapping) every planner call keys on.
* :func:`plan_sweep` -- picks each mode's MTTKRP algorithm (1-step /
  2-step-left / 2-step-right / dimension-tree / fused) from the analytic
  flop/byte/collective cost model; :meth:`SweepPlan.describe` exposes the
  predictions so benchmarks report predicted-vs-measured.
* :class:`Executor` -- where contractions run: :class:`LocalExecutor`
  (single device) or :class:`ShardedExecutor` (``shard_map`` + minimal psum
  over a device mesh).

Exactly one :func:`als_sweep` engine and one :func:`cp_als` driver consume
them; the pre-redesign entry points (``core.cpals.cp_als``,
``core.dimtree.dimtree_sweep``, ``dist.dist_mttkrp.dist_cp_als`` /
``dist_dimtree_sweep``) remain as thin wrappers that build the
corresponding plan.
"""

from .cost import ALGORITHMS, ModeCost, dimtree_mode_cost, mode_cost, ring_allreduce_bytes
from .executor import Executor, LocalExecutor, ShardedExecutor
from .planner import STRATEGIES, ModePlan, SweepPlan, plan_sweep
from .problem import Problem
from .sweep import SweepState, als_sweep, cp_als, legacy_sweep

__all__ = [
    "ALGORITHMS",
    "STRATEGIES",
    "Executor",
    "LocalExecutor",
    "ModeCost",
    "ModePlan",
    "Problem",
    "ShardedExecutor",
    "SweepPlan",
    "SweepState",
    "als_sweep",
    "cp_als",
    "dimtree_mode_cost",
    "legacy_sweep",
    "mode_cost",
    "plan_sweep",
    "ring_allreduce_bytes",
]
