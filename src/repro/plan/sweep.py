"""THE ALS sweep: the one copy of the update algebra, plan- and executor-driven.

Per mode-n update (alternating least squares, paper Sec. 2.2):
    M   = MTTKRP(X, {U_k}, n)               (bottleneck; executor + plan decide how)
    H   = *_{k != n} (U_k^T U_k)            (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda
with the fit tracked through the factored identity reusing the last MTTKRP.

The engine walks the plan's contraction schedule (:mod:`repro.plan.schedule`)
node by node -- the flat per-mode sweep and every dimension-tree shape are
the same walk over different trees.  This module replaces the four
hand-written sweeps (``core.cpals.als_sweep``, ``core.dimtree.dimtree_sweep``,
``dist.dist_mttkrp.dist_als_sweep`` and ``dist_dimtree_sweep``), which
survive as thin wrappers building the corresponding plan + executor.  The
Gram/Hadamard/pinv/normalize/fit algebra exists ONLY here.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, MutableMapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.cpals import (
    CPState,
    fit_from_last_mttkrp,
    grams,
    hadamard_except,
    normalize_columns,
)
from repro.core.tensor_ops import random_factors, tensor_norm

from .executor import Executor, LocalExecutor, ShardedExecutor
from .planner import SweepPlan, plan_sweep
from .problem import Problem
from .schedule import ROOT, pp_pairs as pp_pair_meta

Array = jax.Array

# THE host-synchronization point of the cp_als driver: exactly one call per
# dispatched chunk of sweeps.  Module-level so tests can count syncs.
_block_until_ready = jax.block_until_ready


@dataclass
class SweepState:
    """Pytree carried across sweeps: the tensor rides along unchanged so the
    jitted sweep is a pure ``state -> state`` function.

    ``carry`` is executor-private state threaded through the sweep (e.g. the
    per-mode error-feedback residuals of
    :class:`repro.plan.executor.CompressedShardedExecutor`); ``None`` for
    stateless executors.  ``grams`` carries the per-factor Gram matrices
    ``U_k^T U_k`` across sweeps: each mode's update refreshes its own Gram,
    so the next sweep starts from exact values without recomputing all N --
    ``None`` (the single-shot default) recomputes them from the factors.

    ``pp`` is the pairwise-perturbation cache (:class:`PPState`) when the
    plan enabled PP sweeps, ``None`` otherwise -- and ``None`` keeps the
    sweep graph literally the classic exact one (the ``pp_tol=0`` bitwise
    guarantee is *by construction*, not by tolerance).
    """

    x: Array
    factors: list[Array]
    weights: Array
    norm_x: Array
    it: Array
    fit: Array | float = 0.0
    carry: Any = None
    grams: list[Array] | None = None
    pp: Any = None


jax.tree_util.register_pytree_node(
    SweepState,
    lambda s: (
        (s.x, s.factors, s.weights, s.norm_x, s.it, s.fit, s.carry, s.grams, s.pp),
        None,
    ),
    lambda _, c: SweepState(*c),
)


@dataclass
class PPState:
    """Pairwise-perturbation cache (Ma & Solomonik, arXiv 2010.12056).

    Captured at the end of every *exact* sweep and carried across the
    approximate ones: ``ref`` are the factor iterates the cache was built
    from, ``pairs`` maps ``(n, m)`` (``n < m``) to the pairwise intermediate
    ``M_{n,m}[i_n, i_m, c] = sum X * prod_{k not in {n,m}} V_k[i_k, c]``,
    and ``base`` is each mode's exact MTTKRP at the reference point
    (``pairs`` contracted with one more reference factor).  ``drift`` is the
    per-factor relative drift ``||U_n - V_n||_F / ||V_n||_F`` since the
    capture (float32, max over the batch for batched problems; +inf before
    the first capture so the run always opens with an exact sweep), and
    ``n_exact`` counts exact (re-materializing) sweeps -- the measured
    exact-sweep fraction the bench reports against the planner's assumption.
    """

    ref: list[Array]
    pairs: dict[tuple[int, int], Array]
    base: list[Array]
    drift: Array
    n_exact: Array


jax.tree_util.register_pytree_node(
    PPState,
    lambda s: ((s.ref, s.pairs, s.base, s.drift, s.n_exact), None),
    lambda _, c: PPState(*c),
)


def _pp_drift(factors: Sequence[Array], ref: Sequence[Array]) -> Array:
    """Per-factor relative drift ``||U_n - V_n||_F / ||V_n||_F`` as an
    ``(ndim,)`` float32 vector (max over the batch when batched) -- the
    quantity the PP gate compares against ``Problem.pp_tol``."""
    ds = []
    for u, v in zip(factors, ref):
        du = (u - v).astype(jnp.float32)
        num = jnp.sqrt(jnp.sum(du * du, axis=(-2, -1)))
        den = jnp.sqrt(jnp.sum(v.astype(jnp.float32) ** 2, axis=(-2, -1)))
        ds.append(jnp.max(num / jnp.maximum(den, 1e-30)))
    return jnp.stack(ds)


def _pp_contract_second(pair: Array, v: Array) -> Array:
    """``M_{n,m} . v_m -> (I_n, C)``: contract the rank-major pair
    ``(..., C, I_n, I_m)`` with a factor ``(..., I_m, C)`` over the m
    index.  The stored layout makes this one stride-1 batched GEMM over
    the rank axis -- an index-major pair would force a transpose of the
    (large) pair per correction, which on CPU costs more than the GEMM."""
    vt = jnp.swapaxes(v, -1, -2)  # (..., C, I_m)
    out = jnp.matmul(pair, vt[..., :, :, None])[..., 0]  # (..., C, I_n)
    return jnp.swapaxes(out, -1, -2)


def _pp_contract_first(pair: Array, v: Array) -> Array:
    """``M_{m,n} . v_m -> (I_n, C)`` when the partner is the pair's FIRST
    index (``m < n``): same stride-1 batched GEMM, contracting the
    ``(..., C, I_m, I_n)`` pair with ``(..., I_m, C)`` over ``I_m``."""
    vt = jnp.swapaxes(v, -1, -2)  # (..., C, I_m)
    out = jnp.matmul(vt[..., :, None, :], pair)[..., 0, :]  # (..., C, I_n)
    return jnp.swapaxes(out, -1, -2)


def _pp_base(
    pairs: dict[tuple[int, int], Array], ref: Sequence[Array], n: int
) -> Array:
    """Mode-``n`` exact MTTKRP at the reference point, recovered from one
    pairwise intermediate: contract ``M_{n,m}`` with reference factor
    ``V_m`` (any partner ``m`` works; the smallest index is used)."""
    m = 1 if n == 0 else 0
    if n < m:
        return _pp_contract_second(pairs[(n, m)], ref[m])
    return _pp_contract_first(pairs[(m, n)], ref[m])


def _pp_materialize(problem: Problem, executor, x, factors, n_exact) -> "PPState":
    """Build the PP cache at the current iterates: pairwise intermediates
    via ``executor.pp_pairs`` (local einsum, or shard_map + per-pair psum),
    per-mode bases, zero drift, ``n_exact`` exact-sweep count."""
    pairs = executor.pp_pairs(problem, x, factors)
    base = [_pp_base(pairs, factors, n) for n in range(problem.ndim)]
    return PPState(
        ref=list(factors),
        pairs=pairs,
        base=base,
        drift=jnp.zeros((problem.ndim,), jnp.float32),
        n_exact=jnp.asarray(n_exact, jnp.int32),
    )


def _pp_init(problem: Problem, x, factors) -> "PPState":
    """Zero-filled PP cache with +inf drift: structurally identical to a
    materialized one (so ``lax.cond``/``scan`` carry one pytree shape) but
    guaranteed to route the first sweep through the exact branch."""
    lead = (problem.batch,) if problem.batched else ()
    pairs = {
        (p.n, p.m): jnp.zeros(lead + p.shape, x.dtype)
        for p in pp_pair_meta(problem)
    }
    return PPState(
        ref=[jnp.zeros_like(u) for u in factors],
        pairs=pairs,
        base=[jnp.zeros_like(u) for u in factors],
        drift=jnp.full((problem.ndim,), jnp.inf, jnp.float32),
        n_exact=jnp.asarray(0, jnp.int32),
    )


def _update_factor(
    plan: SweepPlan, factors: list[Array], gs: list[Array], weights: Array,
    n: int, m_n: Array, it: Array,
) -> Array:
    """THE per-mode factor update (paper Sec. 2.2), shared by the exact and
    the pairwise-perturbation sweeps: solve ``U H = M`` via pinv on the
    C x C Gram-Hadamard, optionally column-normalize into the lambdas, and
    refresh exactly the changed factor's Gram.  Mutates ``factors``/``gs``
    in place; returns the (possibly updated) weights."""
    h = hadamard_except(gs, n)
    u = m_n @ jnp.linalg.pinv(h)
    if plan.normalize:
        u, norms = normalize_columns(u, it)
        weights = norms
    factors[n] = u
    gs[n] = jnp.swapaxes(u, -1, -2) @ u
    return weights


def _exact_sweep(
    problem: Problem, plan: SweepPlan, executor: Executor, state: SweepState
) -> SweepState:
    """The exact schedule-walking sweep (see :func:`als_sweep`); passes
    ``state.pp`` through untouched."""
    x = state.x
    factors = list(state.factors)
    weights = state.weights
    it = state.it
    carry = state.carry
    use_carry = hasattr(executor, "contract_carry")
    gs = list(state.grams) if state.grams is not None else grams(factors)
    m_last = None

    sched = plan.resolved_schedule
    cache: dict[int, Array] = {ROOT: x}
    for node in sched.walk():
        src = cache[node.parent]
        if plan.nodes:
            np_ = plan.node_plan(node.id)
            alg, tiles, coll = np_.algorithm, np_.tiles, np_.collective
        else:
            alg, tiles, coll = "auto", None, "flat"
        if use_carry:
            out, carry = executor.contract_carry(
                node, src, factors, alg, carry, tiles=tiles, collective=coll
            )
        else:
            out = executor.contract(
                node, src, factors, alg, tiles=tiles, collective=coll
            )
        if node.is_leaf:
            m_last = out
            weights = _update_factor(plan, factors, gs, weights, node.mode, m_last, it)
        else:
            cache[node.id] = out

    # Fit from the last MTTKRP (standard trick; avoids forming the model).
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], state.norm_x)
    return SweepState(
        x=x, factors=factors, weights=weights, norm_x=state.norm_x, it=it, fit=fit,
        carry=carry, grams=gs, pp=state.pp,
    )


def _pp_sweep(
    problem: Problem, plan: SweepPlan, state: SweepState
) -> SweepState:
    """One approximate sweep from the PP cache: per mode ``n`` the MTTKRP is
    the cached base plus one small GEMM per perturbed factor,
    ``M_n ~= base_n + sum_{m != n} M_{n,m} . (U_m - V_m)``
    (first order in the drifts -- the neglected terms are products of two or
    more deltas, hence the O(drift^2) error the property suite checks).  The
    factor update itself is the shared exact algebra; the tensor is never
    touched, which is the whole point.  Returns the state with refreshed
    drifts; the cache (``ref``/``pairs``/``base``/``n_exact``) rides along
    unchanged.
    """
    pp = state.pp
    factors = list(state.factors)
    weights = state.weights
    it = state.it
    gs = list(state.grams) if state.grams is not None else grams(factors)
    m_last = None
    for n in range(problem.ndim):
        m_n = pp.base[n]
        for m in range(problem.ndim):
            if m == n:
                continue
            du = factors[m] - pp.ref[m]
            if n < m:
                m_n = m_n + _pp_contract_second(pp.pairs[(n, m)], du)
            else:
                m_n = m_n + _pp_contract_first(pp.pairs[(m, n)], du)
        m_last = m_n
        weights = _update_factor(plan, factors, gs, weights, n, m_n, it)
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], state.norm_x)
    new_pp = PPState(
        ref=pp.ref, pairs=pp.pairs, base=pp.base,
        drift=_pp_drift(factors, pp.ref), n_exact=pp.n_exact,
    )
    return SweepState(
        x=state.x, factors=factors, weights=weights, norm_x=state.norm_x,
        it=it, fit=fit, carry=state.carry, grams=gs, pp=new_pp,
    )


def _with_payload(state: SweepState, payload) -> SweepState:
    """Rebuild a :class:`SweepState` from the sweep-mutable payload tuple
    (the ``lax.cond`` outputs of the PP gate), keeping the tensor, the PP
    cache, and the other sweep-invariant fields from ``state``."""
    factors, weights, fit, carry, gs = payload
    return SweepState(
        x=state.x, factors=list(factors), weights=weights, norm_x=state.norm_x,
        it=state.it, fit=fit, carry=carry, grams=gs, pp=state.pp,
    )


def als_sweep(
    problem: Problem, plan: SweepPlan, executor: Executor, state: SweepState
) -> SweepState:
    """One full ALS sweep over all modes, following ``plan`` on ``executor``.

    The engine is a *schedule walker*: it visits the plan's contraction
    tree in evaluation order (pre-order), materializing each internal
    node's partial tensor through ``executor.contract`` and caching it for
    its children (the reuse that makes dimension trees pay), and updating
    one factor at each leaf.  The flat per-mode sweep and the classic
    binary two-partial split are just two tree shapes; because children
    partition their parent's range in order and nodes materialize right
    before their first descendant leaf, every contracted factor is exactly
    as fresh as standard ALS requires -- any valid schedule reproduces the
    standard iterates (see :mod:`repro.plan.schedule`).

    Executors implementing the carry extension (``contract_carry``; see the
    :class:`repro.plan.executor.Executor` protocol) have their private state
    -- e.g. per-node error-feedback residuals -- threaded through
    ``state.carry`` across every node contraction, partials included.

    Gram matrices ride ``state.grams`` when the caller threads them across
    sweeps (``cp_als`` does): each update refreshes exactly the changed
    factor's Gram, so carried Grams are identical to recomputing all N from
    the factors -- which is what happens when ``state.grams is None``.

    With a PP cache on ``state.pp`` the sweep becomes a traced two-way
    gate (``lax.cond``): while every factor's drift since the last exact
    sweep stays below ``problem.pp_tol``, the approximate
    :func:`_pp_sweep` runs (no tensor contraction at all); once any drift
    crosses the threshold, the exact walk above runs verbatim and the
    cache is re-materialized at the fresh iterates.  ``state.pp is None``
    (every ``pp_tol=0`` plan) skips the gate entirely -- the graph is the
    classic exact sweep, bitwise.

    Gate structure: cond outputs cannot alias their operands, so everything
    routed through a cond's output is a fresh buffer every sweep.  The
    per-sweep gate therefore carries only what a sweep actually rewrites --
    factors, weights, fit, carry, grams; the tensor (and norm_x/it) and the
    pair cache stay outside.  The cache (``ref``/``pairs``/``base``, by far
    the largest conditional state) crosses exactly one minimal cond whose
    predicate -- "this was an exact sweep whose own step settled under the
    tolerance" -- is false on every approximate sweep, with a pure identity
    keep-branch, instead of riding the two-way sweep gate's carry on every
    iteration.  The drift/n_exact bookkeeping is recomputed outside the
    gate from the same quantities the branches used, bitwise identical to
    the nested-cond formulation (``test_property.py`` pins this).
    """
    if state.pp is None:
        return _exact_sweep(problem, plan, executor, state)

    pp0 = state.pp
    use_pp = jnp.max(pp0.drift) < problem.pp_tol

    def _payload(st: SweepState):
        return (st.factors, st.weights, st.fit, st.carry, st.grams)

    def exact_branch(payload):
        out = _exact_sweep(problem, plan, executor, _with_payload(state, payload))
        return _payload(out)

    def pp_branch(payload):
        out = _pp_sweep(problem, plan, _with_payload(state, payload))
        return _payload(out)

    payload = jax.lax.cond(use_pp, pp_branch, exact_branch, _payload(state))
    new_factors = list(payload[0])

    # rebuild the cache only when an exact sweep's own step settled under
    # the tolerance -- i.e. the next sweeps would actually stay in the PP
    # regime.  During the early large-step phase the build would be
    # invalidated immediately, so keep the stale cache (drift = inf keeps
    # routing through the exact branch) and pay nothing extra.
    step = _pp_drift(new_factors, state.factors)
    rebuild = jnp.logical_and(
        jnp.logical_not(use_pp), jnp.max(step) < problem.pp_tol
    )

    def build(_):
        new = _pp_materialize(problem, executor, state.x, new_factors, 0)
        return (new.ref, new.pairs, new.base)

    def keep(_):
        return (pp0.ref, pp0.pairs, pp0.base)

    ref, pairs, base = jax.lax.cond(rebuild, build, keep, None)
    # drift after the sweep: vs the (kept) reference on approximate sweeps
    # (what _pp_sweep refreshes), exactly zero right after a rebuild (the
    # reference IS the fresh iterate), +inf while the cache is stale.
    drift = jnp.where(
        use_pp,
        _pp_drift(new_factors, pp0.ref),
        jnp.where(
            rebuild,
            jnp.zeros_like(pp0.drift),
            jnp.full_like(pp0.drift, jnp.inf),
        ),
    )
    n_exact = pp0.n_exact + jnp.where(use_pp, 0, 1).astype(pp0.n_exact.dtype)
    out = _with_payload(state, payload)
    return SweepState(
        x=out.x, factors=out.factors, weights=out.weights, norm_x=out.norm_x,
        it=out.it, fit=out.fit, carry=out.carry, grams=out.grams,
        pp=PPState(ref=ref, pairs=pairs, base=base, drift=drift, n_exact=n_exact),
    )


def legacy_sweep(
    x: Array,
    factors: Sequence[Array],
    weights: Array,
    norm_x: Array,
    it,
    *,
    strategy: str,
    normalize: bool = True,
    split: int | None = None,
    mode_axes=None,
    mesh=None,
) -> tuple[list[Array], Array, Array]:
    """The one bridge behind the pre-redesign sweep signatures.

    Builds the Problem/plan/executor for an old-style ``(x, factors,
    weights, norm_x, it)`` call -- sharded when ``mesh`` is given -- runs
    the engine, and returns the historical ``(factors, weights, fit)``
    triple.  All four back-compat wrappers delegate here so the legacy
    plumbing exists once.
    """
    problem = Problem.from_tensor(
        x, factors[0].shape[1], mode_axes=mode_axes, mesh=mesh
    )
    # legacy wrappers are frozen on the exact executors AND the pre-schedule
    # tree shapes (flat per-mode, or the binary split for dimtree): plan and
    # execution must keep matching the pre-redesign behavior.
    plan = plan_sweep(
        problem, strategy=strategy, split=split, normalize=normalize,
        executor="sharded" if mesh is not None else "local",
        schedule=None if strategy == "dimtree" else "flat",
    )
    executor = ShardedExecutor(mesh, mode_axes) if mesh is not None else LocalExecutor()
    state = SweepState(
        x=x, factors=list(factors), weights=weights, norm_x=norm_x, it=jnp.asarray(it)
    )
    out = als_sweep(problem, plan, executor, state)
    return out.factors, out.weights, out.fit


def cp_als(
    x: Array,
    plan: SweepPlan,
    *,
    executor: Executor | None = None,
    n_iters: int = 50,
    tol: float = 1.0e-5,
    seed: int = 0,
    track_fit: bool = True,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
    sweeps_per_sync: int = 1,
    dispatch_cache: MutableMapping[Any, Callable] | None = None,
    dispatch_key: Any = None,
) -> CPState:
    """THE CP-ALS driver: init, sync-free chunked sweep loop, convergence stop.

    Replaces both ``core.cpals.cp_als`` and ``dist.dist_mttkrp.dist_cp_als``
    (which wrap it).  ``executor`` defaults to :class:`LocalExecutor` for
    local plans; for sharded plans pass the matching instance (build one
    from ``plan.executor`` with :func:`repro.plan.executor.make_executor`)
    -- ``prepare`` places the tensor/factors before the loop, and executors
    with carry state (compressed collectives) have it initialized here and
    threaded across iterations.  Per-iteration wall times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them.

    ``sweeps_per_sync`` makes the hot loop sync-free: each device dispatch
    runs that many sweeps inside one compiled ``lax.scan`` (factor, Gram,
    weight and carry buffers donated off-CPU) and the host blocks exactly
    once per chunk -- the per-sweep iterates are bitwise identical to
    ``sweeps_per_sync=1``, only the host round-trips change (one per chunk
    instead of one per sweep).  Convergence is checked against the chunk's
    per-sweep fits at each sync point, so a run may execute up to
    ``sweeps_per_sync - 1`` sweeps past the first converged one; the
    callback still fires once per executed sweep (with the chunk's mean
    per-sweep seconds).

    Batched problems (``plan.problem.batched``) expect ``x`` of shape
    ``(batch, *problem.shape)`` and run ALL problems through the same
    compiled dispatches: factors/weights/Grams gain a leading batch axis,
    the fit is per-problem (``CPState.fit`` has shape ``(batch,)``), the
    callback receives the batch-mean fit, and convergence requires every
    problem's fit delta below ``tol`` (problems are independent, so the
    shared stop is the price of one fused dispatch -- at most a few extra
    sweeps for the fastest converger).

    Plans with ``plan.pp`` (built from a ``Problem(pp_tol > 0)``) run the
    pairwise-perturbation loop: the scan carries the PP cache next to the
    factors, each sweep gates exact-vs-approximate on the traced drifts (so
    chunks stay sync-free), and ``CPState.pp_exact_sweeps`` reports how many
    sweeps re-materialized the cache -- ``pp_exact_sweeps / it`` is the
    measured exact-sweep fraction the bench compares against the planner's
    amortization assumption.  ``pp_tol=0`` plans never build the cache, so
    their iterates are bitwise identical to classic exact ALS.

    ``dispatch_cache`` (with ``dispatch_key``) lets a caller that drives
    many same-signature runs -- the serving engine of
    :mod:`repro.serve.cp_service` -- reuse ONE jitted sweep-chunk across
    calls: each ``cp_als`` call otherwise builds a fresh ``jax.jit`` wrapper
    and recompiles.  The compiled chunk closes over ``(plan, executor)``, so
    the caller must key the cache such that one key never maps two distinct
    plans/executors (the service keys on the problem signature and memoizes
    plan + executor under the same key).  A cache hit makes the call
    compile-free for shapes already traced.
    """
    problem = plan.problem
    if executor is None:
        if plan.executor != "local":
            raise ValueError(
                f"plan.executor={plan.executor!r} needs an executor instance: "
                "the Problem carries only axis sizes, so build one with "
                "repro.plan.make_executor(plan.executor, mesh, mode_axes)"
            )
        executor = LocalExecutor()
    k = int(sweeps_per_sync)
    if k < 1:
        raise ValueError(f"sweeps_per_sync must be >= 1, got {sweeps_per_sync}")
    key = jax.random.PRNGKey(seed)
    if problem.batched:
        expected = (problem.batch,) + problem.shape
        if tuple(x.shape) != expected:
            raise ValueError(
                f"batched problem expects x.shape {expected}, got {tuple(x.shape)}"
            )
        factors = init_factors or random_factors(
            key, problem.shape, problem.rank, x.dtype, batch=problem.batch
        )
    else:
        factors = init_factors or random_factors(key, x.shape, problem.rank, x.dtype)
    x, factors = executor.prepare(problem, x, factors)
    # donated buffers are deleted after the first dispatch; prepare() may
    # pass caller arrays through unchanged (LocalExecutor), so donation is
    # keyed off the backend (a no-op-with-warning on CPU) and caller-owned
    # init_factors are copied once rather than invalidated under the caller.
    donate = (3, 4, 5, 6, 7) if jax.default_backend() != "cpu" else ()
    if donate and init_factors is not None:
        factors = [jnp.array(u, copy=True) for u in factors]
    if problem.batched:
        weights = jnp.ones((problem.batch, problem.rank), x.dtype)
        norm_x = tensor_norm(x, batched=True).astype(x.dtype)
    else:
        weights = jnp.ones((problem.rank,), x.dtype)
        norm_x = tensor_norm(x).astype(x.dtype)
    carry = (
        executor.init_carry(plan, x, factors)
        if hasattr(executor, "init_carry")
        else None
    )
    # Grams are computed once here and carried across sweeps (each update
    # refreshes exactly the changed factor's Gram inside the sweep).
    gs = grams(factors)
    # PP plans carry the cache through the same scan (zeros + inf drift, so
    # the first sweep is exact); pp stays None otherwise and the chunk
    # graph is the classic exact one, bitwise.
    pp = _pp_init(problem, x, factors) if plan.pp else None

    # One dispatch = `length` sweeps under lax.scan.  jit only the evolving
    # buffers out (returning x from the compiled fn would make XLA emit a
    # full-tensor copy every chunk); donate them in so off-CPU backends
    # update factors/Grams/carry/PP-cache in place.
    def _chunk(x, norm_x, it0, factors, weights, gs, carry, pp, length):
        def body(c, _):
            factors, weights, gs, carry, pp, it = c
            state = SweepState(
                x=x, factors=factors, weights=weights, norm_x=norm_x,
                it=it, carry=carry, grams=gs, pp=pp,
            )
            out = als_sweep(problem, plan, executor, state)
            return (
                (out.factors, out.weights, out.grams, out.carry, out.pp, it + 1),
                out.fit,
            )

        init = (factors, weights, gs, carry, pp, it0)
        (factors, weights, gs, carry, pp, _), fits = jax.lax.scan(
            body, init, None, length=length
        )
        return factors, weights, gs, carry, pp, fits

    if dispatch_cache is not None and dispatch_key in dispatch_cache:
        chunk = dispatch_cache[dispatch_key]
    else:
        chunk = jax.jit(_chunk, static_argnames=("length",), donate_argnums=donate)
        if dispatch_cache is not None:
            dispatch_cache[dispatch_key] = chunk

    fit_prev = -math.inf
    fit = jnp.asarray(0.0, x.dtype)
    it = 0
    done = False
    while it < n_iters and not done:
        length = min(k, n_iters - it)
        t0 = time.perf_counter()
        factors, weights, gs, carry, pp, fits = chunk(
            x, norm_x, jnp.asarray(it), factors, weights, gs, carry, pp,
            length=length,
        )
        fits = _block_until_ready(fits)  # the chunk's single host sync
        dt = time.perf_counter() - t0
        for j in range(length):
            if problem.batched:
                # per-problem fits (B,); stop only when EVERY problem's
                # fit delta clears tol (one fused dispatch, shared stop).
                f = fits[j]
                if callback is not None:
                    callback(it + j, float(jnp.mean(f)), dt / length)
                if track_fit and bool(jnp.max(jnp.abs(f - fit_prev)) < tol):
                    done = True
                fit_prev = f
            else:
                f = float(fits[j])
                if callback is not None:
                    callback(it + j, f, dt / length)
                if track_fit and abs(f - fit_prev) < tol:
                    done = True
                fit_prev = f
        it += length
        fit = fits[length - 1]
    return CPState(
        factors=factors, weights=weights, fit=fit, it=it,
        pp_exact_sweeps=int(pp.n_exact) if pp is not None else None,
    )
