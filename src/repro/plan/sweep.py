"""THE ALS sweep: the one copy of the update algebra, plan- and executor-driven.

Per mode-n update (alternating least squares, paper Sec. 2.2):
    M   = MTTKRP(X, {U_k}, n)               (bottleneck; executor + plan decide how)
    H   = *_{k != n} (U_k^T U_k)            (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda
with the fit tracked through the factored identity reusing the last MTTKRP.

The engine walks the plan's contraction schedule (:mod:`repro.plan.schedule`)
node by node -- the flat per-mode sweep and every dimension-tree shape are
the same walk over different trees.  This module replaces the four
hand-written sweeps (``core.cpals.als_sweep``, ``core.dimtree.dimtree_sweep``,
``dist.dist_mttkrp.dist_als_sweep`` and ``dist_dimtree_sweep``), which
survive as thin wrappers building the corresponding plan + executor.  The
Gram/Hadamard/pinv/normalize/fit algebra exists ONLY here.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.cpals import (
    CPState,
    fit_from_last_mttkrp,
    grams,
    hadamard_except,
    normalize_columns,
)
from repro.core.tensor_ops import random_factors, tensor_norm

from .executor import Executor, LocalExecutor, ShardedExecutor
from .planner import SweepPlan, plan_sweep
from .problem import Problem
from .schedule import ROOT

Array = jax.Array


@dataclass
class SweepState:
    """Pytree carried across sweeps: the tensor rides along unchanged so the
    jitted sweep is a pure ``state -> state`` function.

    ``carry`` is executor-private state threaded through the sweep (e.g. the
    per-mode error-feedback residuals of
    :class:`repro.plan.executor.CompressedShardedExecutor`); ``None`` for
    stateless executors.
    """

    x: Array
    factors: list[Array]
    weights: Array
    norm_x: Array
    it: Array
    fit: Array | float = 0.0
    carry: Any = None


jax.tree_util.register_pytree_node(
    SweepState,
    lambda s: ((s.x, s.factors, s.weights, s.norm_x, s.it, s.fit, s.carry), None),
    lambda _, c: SweepState(*c),
)


def als_sweep(
    problem: Problem, plan: SweepPlan, executor: Executor, state: SweepState
) -> SweepState:
    """One full ALS sweep over all modes, following ``plan`` on ``executor``.

    The engine is a *schedule walker*: it visits the plan's contraction
    tree in evaluation order (pre-order), materializing each internal
    node's partial tensor through ``executor.contract`` and caching it for
    its children (the reuse that makes dimension trees pay), and updating
    one factor at each leaf.  The flat per-mode sweep and the classic
    binary two-partial split are just two tree shapes; because children
    partition their parent's range in order and nodes materialize right
    before their first descendant leaf, every contracted factor is exactly
    as fresh as standard ALS requires -- any valid schedule reproduces the
    standard iterates (see :mod:`repro.plan.schedule`).

    Executors implementing the carry extension (``contract_carry``; see the
    :class:`repro.plan.executor.Executor` protocol) have their private state
    -- e.g. per-node error-feedback residuals -- threaded through
    ``state.carry`` across every node contraction, partials included.
    """
    x = state.x
    factors = list(state.factors)
    weights = state.weights
    it = state.it
    carry = state.carry
    use_carry = hasattr(executor, "contract_carry")
    gs = grams(factors)
    m_last = None

    def update(n: int, m: Array, weights: Array) -> Array:
        h = hadamard_except(gs, n)
        # Solve U H = M  via pinv on the C x C Gram-Hadamard (paper Sec. 2.2).
        u = m @ jnp.linalg.pinv(h)
        if plan.normalize:
            u, norms = normalize_columns(u, it)
            weights = norms
        factors[n] = u
        gs[n] = u.T @ u
        return weights

    sched = plan.resolved_schedule
    cache: dict[int, Array] = {ROOT: x}
    for node in sched.walk():
        src = cache[node.parent]
        alg = plan.node_plan(node.id).algorithm if plan.nodes else "auto"
        if use_carry:
            out, carry = executor.contract_carry(node, src, factors, alg, carry)
        else:
            out = executor.contract(node, src, factors, alg)
        if node.is_leaf:
            m_last = out
            weights = update(node.mode, m_last, weights)
        else:
            cache[node.id] = out

    # Fit from the last MTTKRP (standard trick; avoids forming the model).
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], state.norm_x)
    return SweepState(
        x=x, factors=factors, weights=weights, norm_x=state.norm_x, it=it, fit=fit,
        carry=carry,
    )


def legacy_sweep(
    x: Array,
    factors: Sequence[Array],
    weights: Array,
    norm_x: Array,
    it,
    *,
    strategy: str,
    normalize: bool = True,
    split: int | None = None,
    mode_axes=None,
    mesh=None,
) -> tuple[list[Array], Array, Array]:
    """The one bridge behind the pre-redesign sweep signatures.

    Builds the Problem/plan/executor for an old-style ``(x, factors,
    weights, norm_x, it)`` call -- sharded when ``mesh`` is given -- runs
    the engine, and returns the historical ``(factors, weights, fit)``
    triple.  All four back-compat wrappers delegate here so the legacy
    plumbing exists once.
    """
    problem = Problem.from_tensor(
        x, factors[0].shape[1], mode_axes=mode_axes, mesh=mesh
    )
    # legacy wrappers are frozen on the exact executors AND the pre-schedule
    # tree shapes (flat per-mode, or the binary split for dimtree): plan and
    # execution must keep matching the pre-redesign behavior.
    plan = plan_sweep(
        problem, strategy=strategy, split=split, normalize=normalize,
        executor="sharded" if mesh is not None else "local",
        schedule=None if strategy == "dimtree" else "flat",
    )
    executor = ShardedExecutor(mesh, mode_axes) if mesh is not None else LocalExecutor()
    state = SweepState(
        x=x, factors=list(factors), weights=weights, norm_x=norm_x, it=jnp.asarray(it)
    )
    out = als_sweep(problem, plan, executor, state)
    return out.factors, out.weights, out.fit


def cp_als(
    x: Array,
    plan: SweepPlan,
    *,
    executor: Executor | None = None,
    n_iters: int = 50,
    tol: float = 1.0e-5,
    seed: int = 0,
    track_fit: bool = True,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
) -> CPState:
    """THE CP-ALS driver: init, jitted sweep loop, convergence stop.

    Replaces both ``core.cpals.cp_als`` and ``dist.dist_mttkrp.dist_cp_als``
    (which wrap it).  ``executor`` defaults to :class:`LocalExecutor` for
    local plans; for sharded plans pass the matching instance (build one
    from ``plan.executor`` with :func:`repro.plan.executor.make_executor`)
    -- ``prepare`` places the tensor/factors before the loop, and executors
    with carry state (compressed collectives) have it initialized here and
    threaded across iterations.  Per-iteration wall times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them.
    """
    problem = plan.problem
    if executor is None:
        if plan.executor != "local":
            raise ValueError(
                f"plan.executor={plan.executor!r} needs an executor instance: "
                "the Problem carries only axis sizes, so build one with "
                "repro.plan.make_executor(plan.executor, mesh, mode_axes)"
            )
        executor = LocalExecutor()
    key = jax.random.PRNGKey(seed)
    factors = init_factors or random_factors(key, x.shape, problem.rank, x.dtype)
    x, factors = executor.prepare(problem, x, factors)
    weights = jnp.ones((problem.rank,), x.dtype)
    norm_x = tensor_norm(x).astype(x.dtype)
    carry = (
        executor.init_carry(plan, x, factors)
        if hasattr(executor, "init_carry")
        else None
    )

    # jit only the (factors, weights, fit, carry) outputs: returning state.x
    # from the compiled fn would make XLA emit a full-tensor copy every
    # iteration.
    def _sweep(state: SweepState):
        out = als_sweep(problem, plan, executor, state)
        return out.factors, out.weights, out.fit, out.carry

    sweep = jax.jit(_sweep)

    fit_prev = -math.inf
    fit = jnp.asarray(0.0, x.dtype)
    it = 0
    for it in range(n_iters):
        t0 = time.perf_counter()
        state = SweepState(
            x=x, factors=factors, weights=weights, norm_x=norm_x,
            it=jnp.asarray(it), carry=carry,
        )
        factors, weights, fit, carry = sweep(state)
        fit = jax.block_until_ready(fit)
        dt = time.perf_counter() - t0
        if callback is not None:
            callback(it, float(fit), dt)
        if track_fit and abs(float(fit) - float(fit_prev)) < tol:
            break
        fit_prev = float(fit)
    return CPState(factors=factors, weights=weights, fit=fit, it=it + 1)
