"""THE ALS sweep: the one copy of the update algebra, plan- and executor-driven.

Per mode-n update (alternating least squares, paper Sec. 2.2):
    M   = MTTKRP(X, {U_k}, n)               (bottleneck; executor + plan decide how)
    H   = *_{k != n} (U_k^T U_k)            (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda
with the fit tracked through the factored identity reusing the last MTTKRP.

This module replaces the four hand-written sweeps (``core.cpals.als_sweep``,
``core.dimtree.dimtree_sweep``, ``dist.dist_mttkrp.dist_als_sweep`` and
``dist_dimtree_sweep``), which survive as thin wrappers building the
corresponding plan + executor.  The Gram/Hadamard/pinv/normalize/fit algebra
exists ONLY here.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.cpals import (
    CPState,
    fit_from_last_mttkrp,
    grams,
    hadamard_except,
    normalize_columns,
)
from repro.core.dimtree import mttkrp_from_partial
from repro.core.tensor_ops import random_factors, tensor_norm

from .executor import Executor, LocalExecutor, ShardedExecutor
from .planner import SweepPlan, plan_sweep
from .problem import Problem

Array = jax.Array


@dataclass
class SweepState:
    """Pytree carried across sweeps: the tensor rides along unchanged so the
    jitted sweep is a pure ``state -> state`` function.

    ``carry`` is executor-private state threaded through the sweep (e.g. the
    per-mode error-feedback residuals of
    :class:`repro.plan.executor.CompressedShardedExecutor`); ``None`` for
    stateless executors.
    """

    x: Array
    factors: list[Array]
    weights: Array
    norm_x: Array
    it: Array
    fit: Array | float = 0.0
    carry: Any = None


jax.tree_util.register_pytree_node(
    SweepState,
    lambda s: ((s.x, s.factors, s.weights, s.norm_x, s.it, s.fit, s.carry), None),
    lambda _, c: SweepState(*c),
)


def als_sweep(
    problem: Problem, plan: SweepPlan, executor: Executor, state: SweepState
) -> SweepState:
    """One full ALS sweep over all modes, following ``plan`` on ``executor``.

    Per-mode plans run one planned MTTKRP per mode; dimension-tree plans run
    the two half-partials (left half from the *old* right factors, right half
    from the *fresh* left factors -- the schedule that reproduces exact
    standard-ALS iterates while reading X twice instead of N times).

    Executors implementing the carry extension (``mttkrp_carry``; see the
    :class:`repro.plan.executor.Executor` protocol) have their private state
    threaded through ``state.carry`` across the per-mode updates.
    """
    x = state.x
    factors = list(state.factors)
    weights = state.weights
    it = state.it
    carry = state.carry
    use_carry = hasattr(executor, "mttkrp_carry")
    n_modes = len(factors)
    gs = grams(factors)
    m_last = None

    def update(n: int, m: Array, weights: Array) -> Array:
        h = hadamard_except(gs, n)
        # Solve U H = M  via pinv on the C x C Gram-Hadamard (paper Sec. 2.2).
        u = m @ jnp.linalg.pinv(h)
        if plan.normalize:
            u, norms = normalize_columns(u, it)
            weights = norms
        factors[n] = u
        gs[n] = u.T @ u
        return weights

    if plan.kind == "dimtree":
        split = plan.split
        # left half: T_L depends only on the (old) right factors
        t_left = executor.partial_right(x, factors[split:])
        for n in range(split):
            sib = [factors[k] for k in range(split) if k != n]
            m_last = mttkrp_from_partial(t_left, sib, n)
            weights = update(n, m_last, weights)
        # right half: T_R from the freshly updated left factors
        t_right = executor.partial_left(x, factors[:split])
        for n in range(split, n_modes):
            sib = [factors[k] for k in range(split, n_modes) if k != n]
            m_last = mttkrp_from_partial(t_right, sib, n - split)
            weights = update(n, m_last, weights)
    else:
        for mp in plan.modes:
            if use_carry:
                m_last, carry = executor.mttkrp_carry(x, factors, mp, carry)
            else:
                m_last = executor.mttkrp(x, factors, mp)
            weights = update(mp.mode, m_last, weights)

    # Fit from the last MTTKRP (standard trick; avoids forming the model).
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], state.norm_x)
    return SweepState(
        x=x, factors=factors, weights=weights, norm_x=state.norm_x, it=it, fit=fit,
        carry=carry,
    )


def legacy_sweep(
    x: Array,
    factors: Sequence[Array],
    weights: Array,
    norm_x: Array,
    it,
    *,
    strategy: str,
    normalize: bool = True,
    split: int | None = None,
    mode_axes=None,
    mesh=None,
) -> tuple[list[Array], Array, Array]:
    """The one bridge behind the pre-redesign sweep signatures.

    Builds the Problem/plan/executor for an old-style ``(x, factors,
    weights, norm_x, it)`` call -- sharded when ``mesh`` is given -- runs
    the engine, and returns the historical ``(factors, weights, fit)``
    triple.  All four back-compat wrappers delegate here so the legacy
    plumbing exists once.
    """
    problem = Problem.from_tensor(
        x, factors[0].shape[1], mode_axes=mode_axes, mesh=mesh
    )
    # legacy wrappers are frozen on the exact executors: plan costs and
    # execution must keep matching the pre-redesign behavior bit for bit.
    plan = plan_sweep(
        problem, strategy=strategy, split=split, normalize=normalize,
        executor="sharded" if mesh is not None else "local",
    )
    executor = ShardedExecutor(mesh, mode_axes) if mesh is not None else LocalExecutor()
    state = SweepState(
        x=x, factors=list(factors), weights=weights, norm_x=norm_x, it=jnp.asarray(it)
    )
    out = als_sweep(problem, plan, executor, state)
    return out.factors, out.weights, out.fit


def cp_als(
    x: Array,
    plan: SweepPlan,
    *,
    executor: Executor | None = None,
    n_iters: int = 50,
    tol: float = 1.0e-5,
    seed: int = 0,
    track_fit: bool = True,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
) -> CPState:
    """THE CP-ALS driver: init, jitted sweep loop, convergence stop.

    Replaces both ``core.cpals.cp_als`` and ``dist.dist_mttkrp.dist_cp_als``
    (which wrap it).  ``executor`` defaults to :class:`LocalExecutor` for
    local plans; for sharded plans pass the matching instance (build one
    from ``plan.executor`` with :func:`repro.plan.executor.make_executor`)
    -- ``prepare`` places the tensor/factors before the loop, and executors
    with carry state (compressed collectives) have it initialized here and
    threaded across iterations.  Per-iteration wall times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them.
    """
    problem = plan.problem
    if executor is None:
        if plan.executor != "local":
            raise ValueError(
                f"plan.executor={plan.executor!r} needs an executor instance: "
                "the Problem carries only axis sizes, so build one with "
                "repro.plan.make_executor(plan.executor, mesh, mode_axes)"
            )
        executor = LocalExecutor()
    key = jax.random.PRNGKey(seed)
    factors = init_factors or random_factors(key, x.shape, problem.rank, x.dtype)
    x, factors = executor.prepare(problem, x, factors)
    weights = jnp.ones((problem.rank,), x.dtype)
    norm_x = tensor_norm(x).astype(x.dtype)
    carry = (
        executor.init_carry(problem, x, factors)
        if hasattr(executor, "init_carry")
        else None
    )

    # jit only the (factors, weights, fit, carry) outputs: returning state.x
    # from the compiled fn would make XLA emit a full-tensor copy every
    # iteration.
    def _sweep(state: SweepState):
        out = als_sweep(problem, plan, executor, state)
        return out.factors, out.weights, out.fit, out.carry

    sweep = jax.jit(_sweep)

    fit_prev = -math.inf
    fit = jnp.asarray(0.0, x.dtype)
    it = 0
    for it in range(n_iters):
        t0 = time.perf_counter()
        state = SweepState(
            x=x, factors=factors, weights=weights, norm_x=norm_x,
            it=jnp.asarray(it), carry=carry,
        )
        factors, weights, fit, carry = sweep(state)
        fit = jax.block_until_ready(fit)
        dt = time.perf_counter() - t0
        if callback is not None:
            callback(it, float(fit), dt)
        if track_fit and abs(float(fit) - float(fit_prev)) < tol:
            break
        fit_prev = float(fit)
    return CPState(factors=factors, weights=weights, fit=fit, it=it + 1)
