"""THE ALS sweep: the one copy of the update algebra, plan- and executor-driven.

Per mode-n update (alternating least squares, paper Sec. 2.2):
    M   = MTTKRP(X, {U_k}, n)               (bottleneck; executor + plan decide how)
    H   = *_{k != n} (U_k^T U_k)            (Hadamard of Gram matrices)
    U_n = M @ pinv(H);  column-normalize -> lambda
with the fit tracked through the factored identity reusing the last MTTKRP.

The engine walks the plan's contraction schedule (:mod:`repro.plan.schedule`)
node by node -- the flat per-mode sweep and every dimension-tree shape are
the same walk over different trees.  This module replaces the four
hand-written sweeps (``core.cpals.als_sweep``, ``core.dimtree.dimtree_sweep``,
``dist.dist_mttkrp.dist_als_sweep`` and ``dist_dimtree_sweep``), which
survive as thin wrappers building the corresponding plan + executor.  The
Gram/Hadamard/pinv/normalize/fit algebra exists ONLY here.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Any, Callable, MutableMapping, Sequence

import jax
import jax.numpy as jnp

from repro.core.cpals import (
    CPState,
    fit_from_last_mttkrp,
    grams,
    hadamard_except,
    normalize_columns,
)
from repro.core.tensor_ops import random_factors, tensor_norm

from .executor import Executor, LocalExecutor, ShardedExecutor
from .planner import SweepPlan, plan_sweep
from .problem import Problem
from .schedule import ROOT

Array = jax.Array

# THE host-synchronization point of the cp_als driver: exactly one call per
# dispatched chunk of sweeps.  Module-level so tests can count syncs.
_block_until_ready = jax.block_until_ready


@dataclass
class SweepState:
    """Pytree carried across sweeps: the tensor rides along unchanged so the
    jitted sweep is a pure ``state -> state`` function.

    ``carry`` is executor-private state threaded through the sweep (e.g. the
    per-mode error-feedback residuals of
    :class:`repro.plan.executor.CompressedShardedExecutor`); ``None`` for
    stateless executors.  ``grams`` carries the per-factor Gram matrices
    ``U_k^T U_k`` across sweeps: each mode's update refreshes its own Gram,
    so the next sweep starts from exact values without recomputing all N --
    ``None`` (the single-shot default) recomputes them from the factors.
    """

    x: Array
    factors: list[Array]
    weights: Array
    norm_x: Array
    it: Array
    fit: Array | float = 0.0
    carry: Any = None
    grams: list[Array] | None = None


jax.tree_util.register_pytree_node(
    SweepState,
    lambda s: (
        (s.x, s.factors, s.weights, s.norm_x, s.it, s.fit, s.carry, s.grams),
        None,
    ),
    lambda _, c: SweepState(*c),
)


def als_sweep(
    problem: Problem, plan: SweepPlan, executor: Executor, state: SweepState
) -> SweepState:
    """One full ALS sweep over all modes, following ``plan`` on ``executor``.

    The engine is a *schedule walker*: it visits the plan's contraction
    tree in evaluation order (pre-order), materializing each internal
    node's partial tensor through ``executor.contract`` and caching it for
    its children (the reuse that makes dimension trees pay), and updating
    one factor at each leaf.  The flat per-mode sweep and the classic
    binary two-partial split are just two tree shapes; because children
    partition their parent's range in order and nodes materialize right
    before their first descendant leaf, every contracted factor is exactly
    as fresh as standard ALS requires -- any valid schedule reproduces the
    standard iterates (see :mod:`repro.plan.schedule`).

    Executors implementing the carry extension (``contract_carry``; see the
    :class:`repro.plan.executor.Executor` protocol) have their private state
    -- e.g. per-node error-feedback residuals -- threaded through
    ``state.carry`` across every node contraction, partials included.

    Gram matrices ride ``state.grams`` when the caller threads them across
    sweeps (``cp_als`` does): each update refreshes exactly the changed
    factor's Gram, so carried Grams are identical to recomputing all N from
    the factors -- which is what happens when ``state.grams is None``.
    """
    x = state.x
    factors = list(state.factors)
    weights = state.weights
    it = state.it
    carry = state.carry
    use_carry = hasattr(executor, "contract_carry")
    gs = list(state.grams) if state.grams is not None else grams(factors)
    m_last = None

    def update(n: int, m: Array, weights: Array) -> Array:
        h = hadamard_except(gs, n)
        # Solve U H = M  via pinv on the C x C Gram-Hadamard (paper Sec. 2.2).
        u = m @ jnp.linalg.pinv(h)
        if plan.normalize:
            u, norms = normalize_columns(u, it)
            weights = norms
        factors[n] = u
        gs[n] = jnp.swapaxes(u, -1, -2) @ u
        return weights

    sched = plan.resolved_schedule
    cache: dict[int, Array] = {ROOT: x}
    for node in sched.walk():
        src = cache[node.parent]
        if plan.nodes:
            np_ = plan.node_plan(node.id)
            alg, tiles = np_.algorithm, np_.tiles
        else:
            alg, tiles = "auto", None
        if use_carry:
            out, carry = executor.contract_carry(
                node, src, factors, alg, carry, tiles=tiles
            )
        else:
            out = executor.contract(node, src, factors, alg, tiles=tiles)
        if node.is_leaf:
            m_last = out
            weights = update(node.mode, m_last, weights)
        else:
            cache[node.id] = out

    # Fit from the last MTTKRP (standard trick; avoids forming the model).
    fit = fit_from_last_mttkrp(gs, weights, m_last, factors[-1], state.norm_x)
    return SweepState(
        x=x, factors=factors, weights=weights, norm_x=state.norm_x, it=it, fit=fit,
        carry=carry, grams=gs,
    )


def legacy_sweep(
    x: Array,
    factors: Sequence[Array],
    weights: Array,
    norm_x: Array,
    it,
    *,
    strategy: str,
    normalize: bool = True,
    split: int | None = None,
    mode_axes=None,
    mesh=None,
) -> tuple[list[Array], Array, Array]:
    """The one bridge behind the pre-redesign sweep signatures.

    Builds the Problem/plan/executor for an old-style ``(x, factors,
    weights, norm_x, it)`` call -- sharded when ``mesh`` is given -- runs
    the engine, and returns the historical ``(factors, weights, fit)``
    triple.  All four back-compat wrappers delegate here so the legacy
    plumbing exists once.
    """
    problem = Problem.from_tensor(
        x, factors[0].shape[1], mode_axes=mode_axes, mesh=mesh
    )
    # legacy wrappers are frozen on the exact executors AND the pre-schedule
    # tree shapes (flat per-mode, or the binary split for dimtree): plan and
    # execution must keep matching the pre-redesign behavior.
    plan = plan_sweep(
        problem, strategy=strategy, split=split, normalize=normalize,
        executor="sharded" if mesh is not None else "local",
        schedule=None if strategy == "dimtree" else "flat",
    )
    executor = ShardedExecutor(mesh, mode_axes) if mesh is not None else LocalExecutor()
    state = SweepState(
        x=x, factors=list(factors), weights=weights, norm_x=norm_x, it=jnp.asarray(it)
    )
    out = als_sweep(problem, plan, executor, state)
    return out.factors, out.weights, out.fit


def cp_als(
    x: Array,
    plan: SweepPlan,
    *,
    executor: Executor | None = None,
    n_iters: int = 50,
    tol: float = 1.0e-5,
    seed: int = 0,
    track_fit: bool = True,
    init_factors: list[Array] | None = None,
    callback: Callable[[int, float, float], None] | None = None,
    sweeps_per_sync: int = 1,
    dispatch_cache: MutableMapping[Any, Callable] | None = None,
    dispatch_key: Any = None,
) -> CPState:
    """THE CP-ALS driver: init, sync-free chunked sweep loop, convergence stop.

    Replaces both ``core.cpals.cp_als`` and ``dist.dist_mttkrp.dist_cp_als``
    (which wrap it).  ``executor`` defaults to :class:`LocalExecutor` for
    local plans; for sharded plans pass the matching instance (build one
    from ``plan.executor`` with :func:`repro.plan.executor.make_executor`)
    -- ``prepare`` places the tensor/factors before the loop, and executors
    with carry state (compressed collectives) have it initialized here and
    threaded across iterations.  Per-iteration wall times go through
    ``callback(it, fit, seconds)`` so benchmarks can record them.

    ``sweeps_per_sync`` makes the hot loop sync-free: each device dispatch
    runs that many sweeps inside one compiled ``lax.scan`` (factor, Gram,
    weight and carry buffers donated off-CPU) and the host blocks exactly
    once per chunk -- the per-sweep iterates are bitwise identical to
    ``sweeps_per_sync=1``, only the host round-trips change (one per chunk
    instead of one per sweep).  Convergence is checked against the chunk's
    per-sweep fits at each sync point, so a run may execute up to
    ``sweeps_per_sync - 1`` sweeps past the first converged one; the
    callback still fires once per executed sweep (with the chunk's mean
    per-sweep seconds).

    Batched problems (``plan.problem.batched``) expect ``x`` of shape
    ``(batch, *problem.shape)`` and run ALL problems through the same
    compiled dispatches: factors/weights/Grams gain a leading batch axis,
    the fit is per-problem (``CPState.fit`` has shape ``(batch,)``), the
    callback receives the batch-mean fit, and convergence requires every
    problem's fit delta below ``tol`` (problems are independent, so the
    shared stop is the price of one fused dispatch -- at most a few extra
    sweeps for the fastest converger).

    ``dispatch_cache`` (with ``dispatch_key``) lets a caller that drives
    many same-signature runs -- the serving engine of
    :mod:`repro.serve.cp_service` -- reuse ONE jitted sweep-chunk across
    calls: each ``cp_als`` call otherwise builds a fresh ``jax.jit`` wrapper
    and recompiles.  The compiled chunk closes over ``(plan, executor)``, so
    the caller must key the cache such that one key never maps two distinct
    plans/executors (the service keys on the problem signature and memoizes
    plan + executor under the same key).  A cache hit makes the call
    compile-free for shapes already traced.
    """
    problem = plan.problem
    if executor is None:
        if plan.executor != "local":
            raise ValueError(
                f"plan.executor={plan.executor!r} needs an executor instance: "
                "the Problem carries only axis sizes, so build one with "
                "repro.plan.make_executor(plan.executor, mesh, mode_axes)"
            )
        executor = LocalExecutor()
    k = int(sweeps_per_sync)
    if k < 1:
        raise ValueError(f"sweeps_per_sync must be >= 1, got {sweeps_per_sync}")
    key = jax.random.PRNGKey(seed)
    if problem.batched:
        expected = (problem.batch,) + problem.shape
        if tuple(x.shape) != expected:
            raise ValueError(
                f"batched problem expects x.shape {expected}, got {tuple(x.shape)}"
            )
        factors = init_factors or random_factors(
            key, problem.shape, problem.rank, x.dtype, batch=problem.batch
        )
    else:
        factors = init_factors or random_factors(key, x.shape, problem.rank, x.dtype)
    x, factors = executor.prepare(problem, x, factors)
    # donated buffers are deleted after the first dispatch; prepare() may
    # pass caller arrays through unchanged (LocalExecutor), so donation is
    # keyed off the backend (a no-op-with-warning on CPU) and caller-owned
    # init_factors are copied once rather than invalidated under the caller.
    donate = (3, 4, 5, 6) if jax.default_backend() != "cpu" else ()
    if donate and init_factors is not None:
        factors = [jnp.array(u, copy=True) for u in factors]
    if problem.batched:
        weights = jnp.ones((problem.batch, problem.rank), x.dtype)
        norm_x = tensor_norm(x, batched=True).astype(x.dtype)
    else:
        weights = jnp.ones((problem.rank,), x.dtype)
        norm_x = tensor_norm(x).astype(x.dtype)
    carry = (
        executor.init_carry(plan, x, factors)
        if hasattr(executor, "init_carry")
        else None
    )
    # Grams are computed once here and carried across sweeps (each update
    # refreshes exactly the changed factor's Gram inside the sweep).
    gs = grams(factors)

    # One dispatch = `length` sweeps under lax.scan.  jit only the evolving
    # buffers out (returning x from the compiled fn would make XLA emit a
    # full-tensor copy every chunk); donate them in so off-CPU backends
    # update factors/Grams/carry in place.
    def _chunk(x, norm_x, it0, factors, weights, gs, carry, length):
        def body(c, _):
            factors, weights, gs, carry, it = c
            state = SweepState(
                x=x, factors=factors, weights=weights, norm_x=norm_x,
                it=it, carry=carry, grams=gs,
            )
            out = als_sweep(problem, plan, executor, state)
            return (out.factors, out.weights, out.grams, out.carry, it + 1), out.fit

        init = (factors, weights, gs, carry, it0)
        (factors, weights, gs, carry, _), fits = jax.lax.scan(
            body, init, None, length=length
        )
        return factors, weights, gs, carry, fits

    if dispatch_cache is not None and dispatch_key in dispatch_cache:
        chunk = dispatch_cache[dispatch_key]
    else:
        chunk = jax.jit(_chunk, static_argnames=("length",), donate_argnums=donate)
        if dispatch_cache is not None:
            dispatch_cache[dispatch_key] = chunk

    fit_prev = -math.inf
    fit = jnp.asarray(0.0, x.dtype)
    it = 0
    done = False
    while it < n_iters and not done:
        length = min(k, n_iters - it)
        t0 = time.perf_counter()
        factors, weights, gs, carry, fits = chunk(
            x, norm_x, jnp.asarray(it), factors, weights, gs, carry, length=length
        )
        fits = _block_until_ready(fits)  # the chunk's single host sync
        dt = time.perf_counter() - t0
        for j in range(length):
            if problem.batched:
                # per-problem fits (B,); stop only when EVERY problem's
                # fit delta clears tol (one fused dispatch, shared stop).
                f = fits[j]
                if callback is not None:
                    callback(it + j, float(jnp.mean(f)), dt / length)
                if track_fit and bool(jnp.max(jnp.abs(f - fit_prev)) < tol):
                    done = True
                fit_prev = f
            else:
                f = float(fits[j])
                if callback is not None:
                    callback(it + j, f, dt / length)
                if track_fit and abs(f - fit_prev) < tol:
                    done = True
                fit_prev = f
        it += length
        fit = fits[length - 1]
    return CPState(factors=factors, weights=weights, fit=fit, it=it)
