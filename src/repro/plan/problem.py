"""``Problem``: the immutable descriptor every planner/executor call keys on.

A Problem captures everything the analytic cost model needs -- tensor shape,
CP rank, element dtype, and (for sharded problems) the mode -> mesh-axis
mapping plus the mesh axis sizes.  It deliberately does NOT hold the tensor
or the mesh object itself: planning is pure arithmetic on static metadata,
so plans can be built for hardware that isn't attached (capacity planning,
dry-runs) and inside ``jit`` traces (shapes are static under tracing).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Iterable, Mapping

import numpy as np

from repro.analysis.roofline import dtype_itemsize


@dataclass(frozen=True)
class Problem:
    """Descriptor of one CP-ALS / MTTKRP problem.

    ``mode_axes`` maps tensor modes to mesh axis names (the block
    distribution of ``repro.dist``); ``axis_sizes`` maps each mesh axis name
    to its device count.  Both empty means a single-device problem.

    ``batch`` stacks B same-shaped tensors along a leading axis (default 1:
    a single tensor, and every array keeps its classic unbatched rank).
    ``batch_axes`` names the mesh axes the batch is sharded over -- the
    third mesh-axis role next to mode axes: batch entries never contract
    against each other, so a pure batch-parallel placement moves zero
    reduce traffic while a mode-parallel placement pays psum volume x B.

    ``intra_axes`` declares a *two-level* mesh topology: the named axes span
    the devices within one node (fast ICI), every other mesh axis crosses
    nodes (slow DCN).  Empty (the default) means a flat single-level network
    -- all collective traffic is priced at ICI bandwidth and nothing about
    planning changes.  Non-empty, the cost model prices intra- and
    inter-node wire volume separately, the planner enumerates alternative
    mode -> axis mappings against the Ballard-Knight-Rouse communication
    lower bound, and executors may complete psums hierarchically
    (:func:`repro.dist.collectives.hierarchical_psum`).

    ``pp_tol`` opts into pairwise-perturbation sweeps (Ma & Solomonik,
    arXiv 2010.12056): while every factor's relative drift since the last
    exact sweep stays below it, MTTKRPs are approximated from cached
    pairwise intermediates plus first-order corrections.  The default 0.0
    disables the approximation entirely -- the sweep engine then runs the
    classic exact path with *bitwise identical* iterates by construction.
    """

    shape: tuple[int, ...]
    rank: int
    dtype: Any = "float32"
    mode_axes: Mapping[int, str] = field(default_factory=dict)
    axis_sizes: Mapping[str, int] = field(default_factory=dict)
    batch: int = 1
    batch_axes: tuple[str, ...] = ()
    pp_tol: float = 0.0
    intra_axes: tuple[str, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(d) for d in self.shape))
        object.__setattr__(self, "rank", int(self.rank))
        object.__setattr__(
            self, "mode_axes", {int(m): str(a) for m, a in dict(self.mode_axes).items()}
        )
        object.__setattr__(
            self, "axis_sizes", {str(a): int(s) for a, s in dict(self.axis_sizes).items()}
        )
        object.__setattr__(self, "batch", int(self.batch))
        object.__setattr__(
            self, "batch_axes", tuple(str(a) for a in self.batch_axes)
        )
        object.__setattr__(self, "pp_tol", float(self.pp_tol))
        object.__setattr__(
            self, "intra_axes", tuple(str(a) for a in self.intra_axes)
        )
        self._validate()

    def __hash__(self):
        # the generated frozen-dataclass hash would include the dict fields
        # (unhashable); hash the canonical projections instead so plans can
        # be cached/memoized keyed on the Problem
        return hash(
            (
                self.shape,
                self.rank,
                self.dtype_str,
                tuple(sorted(self.mode_axes.items())),
                tuple(sorted(self.axis_sizes.items())),
                self.batch,
                self.batch_axes,
                self.pp_tol,
                self.intra_axes,
            )
        )

    def _validate(self) -> None:
        if self.rank < 1:
            raise ValueError(f"rank must be >= 1, got {self.rank}")
        if self.batch < 1:
            raise ValueError(f"batch must be >= 1, got {self.batch}")
        if not self.pp_tol >= 0.0:  # also rejects NaN
            raise ValueError(f"pp_tol must be >= 0, got {self.pp_tol}")
        self.itemsize  # fail at construction on an unresolvable dtype
        mode_axis_names = set(self.mode_axes.values())
        for axis in self.batch_axes:
            if axis not in self.axis_sizes:
                raise ValueError(
                    f"no size known for batch mesh axis {axis!r} "
                    f"(axes: {sorted(self.axis_sizes)})"
                )
            if axis in mode_axis_names:
                raise ValueError(
                    f"mesh axis {axis!r} cannot shard both a mode and the batch"
                )
        if len(set(self.batch_axes)) != len(self.batch_axes):
            raise ValueError(f"duplicate batch axes in {self.batch_axes}")
        if len(set(self.intra_axes)) != len(self.intra_axes):
            raise ValueError(f"duplicate intra axes in {self.intra_axes}")
        for axis in self.intra_axes:
            if axis not in self.axis_sizes:
                raise ValueError(
                    f"no size known for intra-node mesh axis {axis!r} "
                    f"(axes: {sorted(self.axis_sizes)})"
                )
        if self.batch % self.batch_shards:
            raise ValueError(
                f"batch {self.batch} not divisible by the "
                f"{self.batch_shards} devices of batch axes {self.batch_axes}"
            )
        seen: dict[str, int] = {}
        for mode, axis in self.mode_axes.items():
            if not 0 <= mode < self.ndim:
                raise ValueError(
                    f"mode {mode} out of range for order-{self.ndim} tensor"
                )
            if axis not in self.axis_sizes:
                raise ValueError(
                    f"no size known for mesh axis {axis!r} "
                    f"(axes: {sorted(self.axis_sizes)})"
                )
            if axis in seen:
                raise ValueError(
                    f"mesh axis {axis!r} mapped to modes {seen[axis]} and {mode}"
                )
            seen[axis] = mode
            if self.shape[mode] % self.axis_sizes[axis]:
                raise ValueError(
                    f"mode {mode} dim {self.shape[mode]} not divisible by "
                    f"axis {axis!r} size {self.axis_sizes[axis]}"
                )

    @classmethod
    def from_tensor(
        cls, x, rank: int, mode_axes=None, mesh=None, *, batch=1, batch_axes=(),
        pp_tol: float = 0.0, intra_axes=(),
    ) -> "Problem":
        """Build a Problem from an array (or tracer / ShapeDtypeStruct).

        Pass ``mode_axes`` + ``mesh`` for a block-distributed problem; the
        mesh contributes only its axis sizes (the object stays with the
        executor).  With ``batch=B > 1`` the array's leading axis is the
        batch (``x.shape[0] == B``) and the tensor shape is ``x.shape[1:]``;
        ``batch_axes`` optionally shards that axis over mesh axes.
        ``pp_tol > 0`` opts into pairwise-perturbation sweeps and
        ``intra_axes`` declares the mesh axes spanning one node of a
        two-level topology (see the class docstring).
        """
        batch = int(batch)
        shape = tuple(x.shape)
        if batch > 1:
            if not shape or shape[0] != batch:
                raise ValueError(
                    f"leading axis {shape[:1]} does not match batch={batch}"
                )
            shape = shape[1:]
        return cls(
            shape=shape,
            rank=rank,
            dtype=x.dtype,
            mode_axes=mode_axes or {},
            axis_sizes=dict(mesh.shape) if mesh is not None else {},
            batch=batch,
            batch_axes=tuple(batch_axes),
            pp_tol=pp_tol,
            intra_axes=tuple(intra_axes),
        )

    # ------------------------------------------------------------- derived
    @property
    def ndim(self) -> int:
        """Tensor order (number of modes)."""
        return len(self.shape)

    @property
    def itemsize(self) -> float:
        """Bytes per element of ``dtype``.

        ``dtype_itemsize`` also accepts HLO-style ('bf16') and numpy-name
        ('bfloat16') strings, matching ``analysis.roofline.mttkrp_roofline``.
        """
        return float(dtype_itemsize(self.dtype))

    @property
    def dtype_str(self) -> str:
        """Canonical dtype name for describe()/JSON output."""
        try:
            return str(np.dtype(self.dtype))
        except TypeError:
            return str(self.dtype)  # HLO-style names np.dtype can't resolve

    @property
    def sharded(self) -> bool:
        """True when any mode or the batch is mapped to a mesh axis."""
        return bool(self.mode_axes) or bool(self.batch_axes)

    @property
    def batched(self) -> bool:
        """True when the problem stacks more than one tensor (batch > 1)."""
        return self.batch > 1

    @property
    def batch_shards(self) -> int:
        """Device count the batch axis is split over (1 when unsharded)."""
        p = 1
        for axis in self.batch_axes:
            p *= self.axis_sizes[axis]
        return p

    @property
    def local_batch(self) -> int:
        """Per-device batch extent under the ``batch_axes`` distribution."""
        return self.batch // self.batch_shards

    @property
    def intra_shards(self) -> int:
        """Devices per node (product of ``intra_axes`` sizes; 1 when flat)."""
        p = 1
        for axis in self.intra_axes:
            p *= self.axis_sizes[axis]
        return p

    @property
    def n_nodes(self) -> int:
        """Node count of a two-level mesh: the product of every non-intra
        mesh axis size (1 when the topology is flat or single-node)."""
        if not self.intra_axes:
            return 1
        p = 1
        for axis, size in self.axis_sizes.items():
            if axis not in self.intra_axes:
                p *= size
        return p

    @property
    def node_axis(self) -> str | None:
        """The intra-node mesh axis executors reduce-scatter over --
        the first of ``intra_axes``, ``None`` for flat topologies."""
        return self.intra_axes[0] if self.intra_axes else None

    def signature(
        self, *, backend: str = "any", n_devices: int | None = None
    ) -> str:
        """THE canonical signature string of this problem.

        ``backend|shape|rank|dtype|devices`` (plus ``|b{B}`` for batched
        problems and ``|pp{tol}`` when pairwise perturbation is enabled;
        defaults keep the historical 5-field layout, so old on-disk keys
        keep resolving) -- the one key construction shared by the tuning
        cache
        (:func:`repro.plan.autotune.problem_key`, which fills in the live
        jax backend) and the serving engine's batch buckets
        (:class:`repro.serve.cp_service.CPService`): two problems with equal
        signatures are interchangeable in one compiled batched dispatch and
        comparable under one set of hardware measurements.

        ``n_devices`` defaults to the product of the problem's mesh axis
        sizes (1 when unsharded) -- NOT the runtime device count, so plans
        for detached hardware key consistently.
        """
        if n_devices is None:
            n_devices = (
                math.prod(self.axis_sizes.values()) if self.axis_sizes else 1
            )
        shape = "x".join(str(d) for d in self.shape)
        key = f"{backend}|{shape}|r{self.rank}|{self.dtype_str}|d{int(n_devices)}"
        if self.batch > 1:
            key += f"|b{self.batch}"
        if self.pp_tol > 0.0:
            key += f"|pp{self.pp_tol:g}"
        if self.intra_axes:
            # two-level topologies measure/bucket separately from flat ones
            # on the same device count (the collectives differ); flat
            # problems keep the historical layout so old keys resolve
            key += f"|node{self.intra_shards}"
        return key

    def mode_shards(self, n: int) -> int:
        """Device count along the axis of mode ``n`` (1 when unmapped)."""
        axis = self.mode_axes.get(n)
        return self.axis_sizes[axis] if axis is not None else 1

    @property
    def local_shape(self) -> tuple[int, ...]:
        """Per-device block dims under the ``mode_axes`` distribution."""
        return tuple(d // self.mode_shards(m) for m, d in enumerate(self.shape))

    def reduce_participants(self, keep_modes: Iterable[int]) -> int:
        """Devices participating in the psum that completes a contraction
        keeping only ``keep_modes`` -- the product of the axis sizes of every
        mapped mode that is contracted away."""
        keep = set(keep_modes)
        p = 1
        for mode in self.mode_axes:
            if mode not in keep:
                p *= self.mode_shards(mode)
        return p

    def reduce_axes_for(self, n: int) -> tuple[str, ...]:
        """Mesh axes the mode-``n`` MTTKRP psums over, in mode order.

        These are the axes of every mapped mode other than ``n`` -- the
        contracted modes whose partial sums the collective completes.  Empty
        when mode ``n`` is the only mapped mode (the output rows ride its own
        axis; no collective is needed) or the problem is unsharded.  Matches
        the axis order :func:`repro.dist.dist_mttkrp.dist_mttkrp` reduces
        over, so cost terms and executors agree on the participant set.
        """
        return tuple(
            self.mode_axes[m] for m in sorted(self.mode_axes) if m != n
        )

    def external_mode(self, n: int) -> bool:
        """External modes (first/last) are where 2-step degenerates to 1-step."""
        return n in (0, self.ndim - 1)
