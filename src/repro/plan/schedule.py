"""Contraction-schedule IR: general dimension trees as planner currency.

The paper's Sec. 6 names dimension trees as the natural next step beyond
per-mode MTTKRP; Ma & Solomonik (arXiv:2010.12056) show *multi-level* trees
with partial reuse are where the real per-sweep savings live for order >= 4.
This module makes the tree shape itself a first-class plan object:

* :class:`ContractionNode` -- one GEMM over a mode subset: the contiguous
  mode range it keeps, the modes it contracts away from its parent, its
  reuse edges (children), and the psum axes/volume its placement requires.
* :class:`Schedule` -- a validated tree of nodes whose leaves are the N
  per-mode updates of one ALS sweep, in increasing mode order.

The flat per-mode sweep and the classic binary two-partial split are just
two degenerate trees (:func:`flat_schedule`, :func:`binary_schedule`);
:func:`chain_schedule` builds the maximal-reuse caterpillar tree, and
:func:`enumerate_schedules` is the candidate set ``plan_sweep`` argmins
over.  Arbitrary shapes come from :func:`build_schedule`'s nested spec.

Correctness invariant (why *any* schedule reproduces exact ALS iterates):
children partition their parent's contiguous range **in order**, and the
engine walks nodes in pre-order, materializing each node just before its
first descendant leaf updates.  At that moment every contracted mode below
the leaf's index is fresh (already updated this sweep) and every contracted
mode above it still holds its pre-sweep value -- exactly the factor state
standard ALS uses for that mode's update.  The binary tree's familiar
"T_L from old right factors, T_R from fresh left factors" recipe is the
two-node instance of this rule.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence

from .problem import Problem

# id of the schedule root (the raw tensor X; never contracted, never costed)
ROOT = 0


def ring_allreduce_bytes(block_bytes: float, participants: int) -> float:
    """Per-device wire bytes of a ring all-reduce of a ``block_bytes`` blob."""
    if participants <= 1:
        return 0.0
    return 2.0 * block_bytes * (participants - 1) / participants


@dataclass(frozen=True)
class ContractionNode:
    """One contraction of a schedule: a GEMM over a mode subset.

    The node keeps the contiguous tensor-mode range ``[lo, hi)`` and
    contracts ``contracted`` (the rest of its parent's range) with those
    modes' factors.  ``children`` are its reuse edges -- every child reads
    this node's output instead of recomputing it from the raw tensor.
    Placement metadata is stamped at build time from the Problem:
    ``reduce_axes`` are the mesh axes mapped to the modes contracted *here*
    (the psum that completes this node), ``psum_participants`` their device
    product, and ``psum_bytes`` the per-device ring all-reduce volume of the
    node's local output block.
    """

    id: int
    parent: int  # ROOT for children of the raw tensor; -1 on the root itself
    lo: int
    hi: int  # kept modes are range(lo, hi)
    parent_lo: int
    parent_hi: int
    contracted: tuple[int, ...]
    children: tuple[int, ...]
    shape: tuple[int, ...]  # global kept dims + (rank,); raw dims on the root
    local_shape: tuple[int, ...]  # per-device block dims of ``shape``
    reduce_axes: tuple[str, ...]
    psum_participants: int
    psum_bytes: float

    @property
    def modes(self) -> tuple[int, ...]:
        """The tensor modes surviving in this node's output, in order."""
        return tuple(range(self.lo, self.hi))

    @property
    def is_root(self) -> bool:
        """True for the schedule root (the raw tensor; not a contraction)."""
        return self.parent < 0

    @property
    def is_leaf(self) -> bool:
        """True when this node is one mode's MTTKRP (a factor update site)."""
        return not self.is_root and not self.children

    @property
    def mode(self) -> int:
        """The single kept mode of a leaf node."""
        if not self.is_leaf:
            raise ValueError(f"node {self.id} keeps modes {self.modes}, not one")
        return self.lo

    @property
    def from_root(self) -> bool:
        """True when this node contracts the raw tensor (not a partial)."""
        return self.parent == ROOT

    def as_dict(self) -> dict:
        """JSON-ready projection: topology + placement metadata."""
        return {
            "node": self.id,
            "parent": self.parent,
            "modes": list(self.modes),
            "contracted": list(self.contracted),
            "children": list(self.children),
            "shape": list(self.shape),
            "reduce_axes": list(self.reduce_axes),
            "psum_participants": self.psum_participants,
            "psum_bytes": self.psum_bytes,
        }


@dataclass(frozen=True)
class Schedule:
    """A contraction tree whose leaves are the N mode updates of one sweep.

    ``nodes`` is stored in pre-order (the engine's evaluation order): node 0
    is the root (the raw tensor), and every other node appears immediately
    after its parent and before its own subtree.  Validation enforces the
    ALS-exactness invariant -- contiguous kept ranges, children partitioning
    their parent's range in increasing order -- so every valid Schedule
    reproduces standard-ALS iterates by construction.
    """

    problem: Problem
    nodes: tuple[ContractionNode, ...]
    name: str = "custom"

    def __post_init__(self):
        object.__setattr__(self, "nodes", tuple(self.nodes))
        self._validate()

    def _validate(self) -> None:
        n = self.problem.ndim
        if not self.nodes or self.nodes[0].parent != -1:
            raise ValueError("schedule must start with the root node")
        root = self.nodes[0]
        if (root.lo, root.hi) != (0, n):
            raise ValueError(f"root must keep all modes [0, {n})")
        by_id = {node.id: node for node in self.nodes}
        if sorted(by_id) != list(range(len(self.nodes))):
            raise ValueError("node ids must be consecutive from 0")
        leaves: list[int] = []
        for node in self.nodes[1:]:
            parent = by_id[node.parent]
            if not parent.lo <= node.lo < node.hi <= parent.hi:
                raise ValueError(
                    f"node {node.id} range [{node.lo}, {node.hi}) escapes its "
                    f"parent's [{parent.lo}, {parent.hi})"
                )
            if node.is_leaf:
                leaves.append(node.lo)
        for node in self.nodes:
            if node.children:
                if len(node.children) < 2:
                    raise ValueError(f"node {node.id} has a single child")
                spans = [(by_id[c].lo, by_id[c].hi) for c in node.children]
                bounds = [node.lo]
                for a, b in spans:
                    if a != bounds[-1]:
                        raise ValueError(
                            f"children of node {node.id} do not partition "
                            f"[{node.lo}, {node.hi}) in order"
                        )
                    bounds.append(b)
                if bounds[-1] != node.hi:
                    raise ValueError(
                        f"children of node {node.id} do not cover [{node.lo}, "
                        f"{node.hi})"
                    )
        if leaves != list(range(n)):
            raise ValueError(f"leaves must be modes 0..{n - 1} in order, got {leaves}")

    @property
    def root(self) -> ContractionNode:
        """The root node (the raw tensor)."""
        return self.nodes[0]

    def walk(self) -> tuple[ContractionNode, ...]:
        """Every contraction in evaluation order (pre-order, root excluded)."""
        return self.nodes[1:]

    def leaves(self) -> tuple[ContractionNode, ...]:
        """The N leaf nodes in increasing mode order."""
        return tuple(node for node in self.nodes if node.is_leaf)

    def leaf_for_mode(self, n: int) -> ContractionNode:
        """The leaf node updating mode ``n``."""
        for node in self.nodes:
            if node.is_leaf and node.lo == n:
                return node
        raise ValueError(f"no leaf for mode {n}")

    @property
    def is_flat(self) -> bool:
        """True for the degenerate tree: every leaf hangs off the root."""
        return all(node.is_leaf for node in self.nodes[1:])

    @property
    def split(self) -> int | None:
        """The binary half boundary, when the tree is the classic two-partial
        split: the root has exactly two children and each is a leaf or a
        one-level half (all grandchildren leaves).  ``None`` for every other
        shape (flat, chains, deeper trees)."""
        kids = self.root.children
        if self.is_flat or len(kids) != 2:
            return None
        for cid in kids:
            child = self.nodes[cid]
            if any(not self.nodes[g].is_leaf for g in child.children):
                return None
        return self.nodes[kids[1]].lo

    def describe(self) -> dict:
        """JSON-ready topology summary (name + per-node metadata rows)."""
        return {
            "name": self.name,
            "n_nodes": len(self.nodes) - 1,
            "nodes": [node.as_dict() for node in self.nodes[1:]],
        }


def _span(spec) -> tuple[int, int]:
    """Contiguous ``[lo, hi)`` covered by a nested spec; raises on gaps."""
    if isinstance(spec, int):
        return spec, spec + 1
    parts = list(spec)
    if not parts:
        raise ValueError("empty schedule spec")
    lo, hi = _span(parts[0])
    for sub in parts[1:]:
        a, b = _span(sub)
        if a != hi:
            raise ValueError(f"spec modes not contiguous/increasing at {a} (expected {hi})")
        hi = b
    return lo, hi


def build_schedule(problem: Problem, spec, name: str = "custom") -> Schedule:
    """Build a Schedule from a nested mode spec.

    ``spec`` is a nested sequence of tensor modes: an ``int`` is a leaf, a
    sequence is an internal node whose children are its elements, e.g.
    ``[0, 1, 2]`` (flat order-3), ``[[0, 1], [2, 3]]`` (binary order-4),
    ``[[[0, 1], 2], 3]`` (the chain).  Modes must appear exactly once, in
    increasing order, in contiguous runs -- the validity condition under
    which any tree reproduces exact ALS iterates.
    """
    lo, hi = _span(spec)
    if (lo, hi) != (0, problem.ndim):
        raise ValueError(
            f"spec covers modes [{lo}, {hi}), problem has [0, {problem.ndim})"
        )
    nodes: list[ContractionNode] = []

    def make(sub, parent_id: int, parent_lo: int, parent_hi: int) -> int:
        lo, hi = _span(sub)
        nid = len(nodes)
        contracted = tuple(
            m for m in range(parent_lo, parent_hi) if not lo <= m < hi
        )
        mapped = [m for m in sorted(problem.mode_axes) if m in set(contracted)]
        axes = tuple(problem.mode_axes[m] for m in mapped)
        participants = math.prod(problem.axis_sizes[a] for a in axes) if axes else 1
        local = tuple(problem.local_shape[m] for m in range(lo, hi))
        # batched problems psum one partial per local batch entry, so the
        # per-device wire volume scales with local_batch (zero for pure
        # batch-parallel placements, where no mode is mapped at all)
        block_bytes = (
            math.prod(local) * problem.rank * problem.itemsize
            * problem.local_batch
        )
        nodes.append(
            ContractionNode(
                id=nid,
                parent=parent_id,
                lo=lo,
                hi=hi,
                parent_lo=parent_lo,
                parent_hi=parent_hi,
                contracted=contracted,
                children=(),  # patched below once children exist
                shape=tuple(problem.shape[m] for m in range(lo, hi))
                + (problem.rank,),
                local_shape=local + (problem.rank,),
                reduce_axes=axes,
                psum_participants=participants,
                psum_bytes=ring_allreduce_bytes(block_bytes, participants),
            )
        )
        if not isinstance(sub, int):
            kids = tuple(make(s, nid, lo, hi) for s in sub)
            object.__setattr__(nodes[nid], "children", kids)
        return nid

    # the root: keeps everything, contracts nothing, shape = the raw tensor
    nodes.append(
        ContractionNode(
            id=ROOT,
            parent=-1,
            lo=0,
            hi=problem.ndim,
            parent_lo=0,
            parent_hi=problem.ndim,
            contracted=(),
            children=(),
            shape=tuple(problem.shape),
            local_shape=tuple(problem.local_shape),
            reduce_axes=(),
            psum_participants=1,
            psum_bytes=0.0,
        )
    )
    kids = tuple(make(s, ROOT, 0, problem.ndim) for s in spec)
    object.__setattr__(nodes[ROOT], "children", kids)
    return Schedule(problem=problem, nodes=tuple(nodes), name=name)


@dataclass(frozen=True)
class PPPair:
    """Placement metadata of one pairwise-perturbation intermediate.

    ``M_{n,m}[c, i_n, i_m] = sum X * prod_{k not in {n, m}} V_k[i_k, c]`` --
    the cached two-mode partial of Ma & Solomonik's pairwise perturbation
    (arXiv 2010.12056), built once per exact sweep and reused by every
    approximate sweep until factor drift crosses ``Problem.pp_tol``.  The
    stored layout is rank-major ``(C, I_n, I_m)`` so every per-sweep
    correction contraction is a stride-1 batched GEMM over the rank axis
    (the index-major layout forces a transpose per correction, which on
    CPU costs more than the GEMM itself).  Like :class:`ContractionNode`,
    placement is stamped at build time: ``reduce_axes`` are the mesh axes
    mapped to the modes contracted away (everything but ``n`` and ``m``),
    ``psum_participants`` their device product, and ``psum_bytes`` the
    per-device ring all-reduce volume of the local ``(C, I_n, I_m)`` block.
    """

    n: int
    m: int
    shape: tuple[int, int, int]  # global (rank, I_n, I_m)
    local_shape: tuple[int, int, int]  # per-device block dims of ``shape``
    reduce_axes: tuple[str, ...]
    psum_participants: int
    psum_bytes: float

    def as_dict(self) -> dict:
        """JSON-ready projection: pair topology + placement metadata."""
        return {
            "pair": [self.n, self.m],
            "shape": list(self.shape),
            "reduce_axes": list(self.reduce_axes),
            "psum_participants": self.psum_participants,
            "psum_bytes": self.psum_bytes,
        }


def pp_pairs(problem: Problem) -> tuple[PPPair, ...]:
    """Every pairwise intermediate of one PP cache, in ``(n, m)`` order.

    One :class:`PPPair` per unordered mode pair ``n < m`` -- the reuse set a
    pairwise-perturbation sweep reads: mode ``n``'s approximate MTTKRP takes
    its base term plus one small correction GEMM against ``M_{n,m}`` for
    every other mode ``m``.  The psum metadata mirrors the schedule nodes'
    convention (ring all-reduce over the axes mapped to contracted modes),
    so sharded PP builds need only the same per-node collectives.
    """
    c = problem.rank
    s = problem.itemsize
    lb = problem.local_batch
    out = []
    for n in range(problem.ndim):
        for m in range(n + 1, problem.ndim):
            mapped = [
                k for k in sorted(problem.mode_axes) if k != n and k != m
            ]
            axes = tuple(problem.mode_axes[k] for k in mapped)
            participants = (
                math.prod(problem.axis_sizes[a] for a in axes) if axes else 1
            )
            local = (
                c, problem.local_shape[n], problem.local_shape[m]
            )
            block_bytes = math.prod(local) * s * lb
            out.append(
                PPPair(
                    n=n,
                    m=m,
                    shape=(c, problem.shape[n], problem.shape[m]),
                    local_shape=local,
                    reduce_axes=axes,
                    psum_participants=participants,
                    psum_bytes=ring_allreduce_bytes(block_bytes, participants),
                )
            )
    return tuple(out)


def flat_schedule(problem: Problem) -> Schedule:
    """The degenerate tree of the per-mode sweep: N leaves off the root."""
    return build_schedule(problem, list(range(problem.ndim)), name="flat")


def binary_schedule(problem: Problem, split: int | None = None) -> Schedule:
    """The classic two-partial dimension tree with the half boundary at
    ``split`` (default: the balanced half).  Size-1 halves degenerate to
    leaves hanging directly off the root -- that half's "partial" *is* the
    mode's full MTTKRP."""
    n = problem.ndim
    m = split if split is not None else (n + 1) // 2
    if not 0 < m < n:
        raise ValueError(f"split {m} out of range for order-{n} tensor")
    left = list(range(m)) if m > 1 else 0
    right = list(range(m, n)) if n - m > 1 else m
    return build_schedule(problem, [left, right], name=f"binary@{m}")


def chain_schedule(problem: Problem) -> Schedule:
    """The maximal-reuse caterpillar tree (Ma & Solomonik's deep chain):
    each level contracts exactly one trailing mode, so the partial for modes
    ``[0, k)`` is reused -- not recomputed -- by every level below it."""
    n = problem.ndim
    if n < 3:
        return flat_schedule(problem)
    spec = [0, 1]
    for m in range(2, n):
        spec = [spec, m]
    return build_schedule(problem, spec, name="chain")


def enumerate_schedules(problem: Problem) -> list[Schedule]:
    """The planner's candidate tree shapes for ``problem``.

    Flat, the binary split at every boundary, and -- for order >= 4, where
    multi-level reuse starts paying (Ma & Solomonik) -- the chain tree.
    Order-3 already yields 3 distinct shapes; order-4 yields 5.
    """
    scheds = [flat_schedule(problem)]
    if problem.ndim >= 3:
        for m in range(1, problem.ndim):
            scheds.append(binary_schedule(problem, m))
    if problem.ndim >= 4:
        scheds.append(chain_schedule(problem))
    return scheds
