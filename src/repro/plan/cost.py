"""Analytic per-mode cost model behind ``plan_sweep``.

Extends the flop/byte model of :func:`repro.core.mttkrp.mttkrp_flops` with
the algorithm-specific intermediate traffic (the full-KRP materialization of
1-step, the partial tensor of 2-step, the half-tensors of the dimension
tree) and -- for sharded problems -- the per-mode psum volume the
``mode_axes`` placement requires (ring all-reduce over the axes mapped to
contracted modes, per Ballard/Knight/Rouse's collective-volume accounting).

Seconds are predicted against the roofline constants of
``repro.analysis.roofline`` with a *bounded-overlap* model:

    predicted_s = max(compute_s, collective_s)
                + serial_fraction * min(compute_s, collective_s)

where ``compute_s = flops/PEAK + bytes/HBM`` and ``collective_s =
collective_bytes/ICI``.  ``serial_fraction`` is the per-executor fraction
of the smaller term that cannot be hidden behind the larger one: 1.0 for
the plain sharded executor (psum strictly after the local GEMM -- the model
degenerates to the old additive sum), ``1/n_chunks`` for the overlapping
executor (chunk ``k``'s psum runs under chunk ``k+1``'s GEMM; only the
first GEMM and the last psum stay exposed).  :func:`executor_mode_cost`
applies these per-executor adjustments -- including the compressed
executor's int8 wire volume -- on top of the per-algorithm terms of
:func:`mode_cost`.

Absolute numbers are hardware-nominal; the planner only ever compares
costs of the same mode across algorithms/executors, where shared terms
cancel.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace

from repro.analysis.roofline import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.core.mttkrp import mttkrp_flops
from repro.core.tensor_ops import dims_split

from .problem import Problem

ALGORITHMS = (
    "1step",
    "2step",
    "2step-left",
    "2step-right",
    "dimtree",
    "fused",
    "einsum",
    "baseline",
)

# Executor kinds the planner dispatches over (repro.plan.executor classes).
EXECUTORS = ("local", "sharded", "overlapping", "compressed")

# Default chunk count of the overlapping executor's double-buffered psum
# pipeline: the serialization fraction is ~1/n_chunks, so 4 already hides
# 75% of the hidable term while keeping per-chunk GEMMs large enough to
# stay compute-efficient.
DEFAULT_OVERLAP_CHUNKS = 4

# compressed_psum payload: 1 int8 byte per element + one fp32 scale per
# sender (so the wire ratio vs the uncompressed dtype depends on itemsize:
# 1/4 for fp32, 1/2 for bf16, 1/8 for f64).
_INT8_ITEMSIZE = 1.0
_SCALE_BYTES = 4.0


@dataclass(frozen=True)
class ModeCost:
    """Cost terms for one mode-n MTTKRP under one algorithm.

    ``gemm_flops`` / ``krp_flops`` / ``second_step_flops`` are the terms of
    ``mttkrp_flops`` (local block dims for sharded problems); ``bytes`` is
    total HBM traffic including intermediates; ``collective_bytes`` is the
    per-device wire volume (0 on unsharded problems).  ``serial_fraction``
    is the executor's unhidable share of the smaller of compute/collective
    time (1.0 = no overlap, the additive model).
    """

    gemm_flops: float
    krp_flops: float
    second_step_flops: float
    bytes: float
    collective_bytes: float = 0.0
    serial_fraction: float = 1.0

    @property
    def flops(self) -> float:
        """Total floating-point operations across all three terms."""
        return self.gemm_flops + self.krp_flops + self.second_step_flops

    @property
    def compute_s(self) -> float:
        """Local roofline time: GEMM/KRP flops + HBM traffic, no collectives."""
        return self.flops / PEAK_FLOPS + self.bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        """Wire time of the completing collective at nominal ICI bandwidth."""
        return self.collective_bytes / ICI_BW

    @property
    def predicted_s(self) -> float:
        """Bounded-overlap roofline: ``max + serial_fraction * min`` of the
        compute and collective terms (``serial_fraction=1`` recovers the
        additive no-overlap model)."""
        c, q = self.compute_s, self.collective_s
        return max(c, q) + self.serial_fraction * min(c, q)

    @property
    def predicted_overlap_efficiency(self) -> float:
        """Fraction of the hidable (smaller) term actually hidden:
        ``1 - serial_fraction`` when there is a collective to hide, else 0."""
        if self.collective_bytes <= 0.0:
            return 0.0
        return 1.0 - self.serial_fraction

    def as_dict(self) -> dict:
        """JSON-ready projection of all terms plus the derived predictions."""
        return {
            "gemm_flops": self.gemm_flops,
            "krp_flops": self.krp_flops,
            "second_step_flops": self.second_step_flops,
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "serial_fraction": self.serial_fraction,
            "compute_s": self.compute_s,
            "collective_s": self.collective_s,
            "predicted_overlap_efficiency": self.predicted_overlap_efficiency,
            "predicted_s": self.predicted_s,
        }


def ring_allreduce_bytes(block_bytes: float, participants: int) -> float:
    """Per-device wire bytes of a ring all-reduce of a ``block_bytes`` blob."""
    if participants <= 1:
        return 0.0
    return 2.0 * block_bytes * (participants - 1) / participants


def compressed_allgather_bytes(
    block_bytes: float, participants: int, itemsize: float = 4.0
) -> float:
    """Per-device wire bytes of ``dist.collectives.compressed_psum``.

    The compressed collective is an all-gather of int8 payloads (scales are
    private per sender, so summation happens after dequantization on every
    receiver): each device receives ``participants - 1`` remote blocks at
    one byte per element -- ``block_bytes / itemsize`` -- plus one fp32
    scale each.  Versus the fp32 ring all-reduce (``2 B (p-1)/p``) the
    ratio is ``p/8`` -- a real win for few participants (4x at p=2) that
    *vanishes at p=8* and inverts beyond, which is exactly why executor
    selection is cost-driven rather than a flag.  Pass the problem's
    ``itemsize`` for non-fp32 dtypes (bf16 compresses only 2x per element).
    """
    if participants <= 1:
        return 0.0
    payload = block_bytes * _INT8_ITEMSIZE / itemsize
    return (participants - 1) * (payload + _SCALE_BYTES)


def _fused_krp_dims(local_shape, n: int) -> tuple[int, int]:
    """Row counts of the two partial KRPs the fused Pallas kernel streams
    (internal modes: the L/R sides; external modes: the log-balanced split
    used by ``repro.kernels.ops.fused_mttkrp``)."""
    L, _, R = dims_split(local_shape, n)
    if 0 < n < len(local_shape) - 1:
        return L, R
    from repro.kernels.ops import balanced_split  # lazy: kernels import pallas

    dims = [d for k, d in enumerate(local_shape) if k != n]
    if len(dims) < 2:
        return dims[0] if dims else 1, 1
    s = balanced_split(dims)
    return math.prod(dims[:s]), math.prod(dims[s:])


def mode_cost(problem: Problem, n: int, algorithm: str) -> ModeCost:
    """Cost of one mode-``n`` MTTKRP under ``algorithm``.

    Computed on the per-device block dims; the psum volume for sharded
    problems is the ring all-reduce of the local partial result over the
    axes mapped to contracted modes (no collective when mode ``n`` itself is
    the only mapped mode -- its axis carries the output rows).
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r} (choose from {ALGORITHMS})")
    shape = problem.local_shape
    c = problem.rank
    s = problem.itemsize
    base = mttkrp_flops(shape, c, n, itemsize=s)
    L, In, R = dims_split(shape, n)
    out_bytes = In * c * s
    coll = ring_allreduce_bytes(out_bytes, problem.reduce_participants((n,)))

    if algorithm == "2step" and not problem.external_mode(n):
        # forced 2-step resolves its order by cost, like the Alg. 4 line-4 rule
        left = mode_cost(problem, n, "2step-left")
        right = mode_cost(problem, n, "2step-right")
        return left if left.predicted_s < right.predicted_s else right

    if algorithm == "1step" or (
        problem.external_mode(n) and algorithm in ("2step", "2step-left", "2step-right")
    ):
        # explicit KRP: L*R*C materialized (written once, read once by the GEMM)
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=base["krp_flops"],
            second_step_flops=0.0,
            bytes=base["tensor_bytes"] + 2.0 * base["krp_bytes"] + out_bytes,
            collective_bytes=coll,
        )
    if algorithm in ("2step-left", "2step-right"):
        # left-first contracts K_L in the GEMM, multi-TTVs over R (and vice
        # versa); intermediate is In * contracted-side * C.
        second_side = R if algorithm == "2step-left" else L
        intermediate = In * second_side * c * s
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=float((L + R) * c),  # two small KRPs instead of one huge
            second_step_flops=2.0 * In * second_side * c,
            bytes=base["tensor_bytes"] + 2.0 * intermediate + (L + R) * c * s + out_bytes,
            collective_bytes=coll,
        )
    if algorithm == "fused":
        da, db = _fused_krp_dims(shape, n)
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=float((da + db) * c),
            second_step_flops=0.0,
            # the full KRP never hits HBM -- only the two partials stream in
            bytes=base["tensor_bytes"] + (da + db) * c * s + out_bytes,
            collective_bytes=coll,
        )
    if algorithm == "einsum":
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=0.0,
            second_step_flops=0.0,
            bytes=base["tensor_bytes"] + (L + In + R) * c * s + out_bytes,
            collective_bytes=coll,
        )
    if algorithm == "baseline":
        # reorder (transpose copy: read + write) then one GEMM over the copy
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=base["krp_flops"],
            second_step_flops=0.0,
            bytes=3.0 * base["tensor_bytes"] + 2.0 * base["krp_bytes"] + out_bytes,
            collective_bytes=coll,
        )
    # "dimtree" needs the half split, which only the planner knows.
    raise ValueError("dimtree mode costs are built by plan_sweep via dimtree_mode_cost")


def executor_mode_cost(
    problem: Problem,
    n: int,
    algorithm: str,
    executor: str = "sharded",
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
) -> ModeCost:
    """Cost of one mode-``n`` MTTKRP under ``algorithm`` on ``executor``.

    Applies the executor's placement-specific adjustments on top of
    :func:`mode_cost`:

    * ``"local"`` / ``"sharded"`` -- the per-algorithm terms unchanged
      (``serial_fraction`` 1.0: the psum waits for the whole local GEMM).
    * ``"overlapping"`` -- same flops/bytes/wire volume, but the chunked
      double-buffered pipeline hides all but ``1/n_chunks`` of the smaller
      of compute/collective time (chunk count is capped by the local row
      count of mode ``n``).
    * ``"compressed"`` -- the fp32 ring all-reduce is replaced by the int8
      error-feedback all-gather: wire bytes become
      :func:`compressed_allgather_bytes`, and HBM traffic grows by the
      quantize/dequantize passes (write + read the int8 block, read the
      gathered payloads).
    """
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r} (choose from {EXECUTORS})")
    if executor == "local" and problem.sharded:
        raise ValueError("executor 'local' cannot run a sharded problem")
    if executor in ("overlapping", "compressed") and not problem.sharded:
        raise ValueError(f"executor {executor!r} needs a sharded problem")
    base = mode_cost(problem, n, algorithm)
    if executor in ("local", "sharded") or base.collective_bytes <= 0.0:
        return base
    if executor == "overlapping":
        in_local = problem.local_shape[n]
        chunks = max(1, min(int(n_chunks), in_local))
        return replace(base, serial_fraction=1.0 / chunks)
    # compressed: recompute the wire term from the output block size, over
    # exactly the axes the executor's collective reduces
    _, in_local, _ = dims_split(problem.local_shape, n)
    s = problem.itemsize
    block = in_local * problem.rank * s
    p = math.prod(problem.axis_sizes[a] for a in problem.reduce_axes_for(n))
    # quantize (read+write the int8 block) and dequantize (read the p-1
    # gathered payloads), at one byte per element
    int8_block = block * _INT8_ITEMSIZE / s
    quant_bytes = (p + 1) * int8_block
    return replace(
        base,
        collective_bytes=compressed_allgather_bytes(block, p, s),
        bytes=base.bytes + quant_bytes,
    )


def dimtree_mode_cost(problem: Problem, n: int, split: int) -> ModeCost:
    """Dimension-tree cost of mode ``n`` given the half split at ``split``.

    The first mode of each half carries the half's partial contraction (one
    X-sized GEMM + its psum); every mode then pays the multi-TTV over its
    half's partial tensor.
    """
    shape = problem.local_shape
    c = problem.rank
    s = problem.itemsize
    in_left = n < split
    half_modes = range(split) if in_left else range(split, problem.ndim)
    half_elems = math.prod(shape[m] for m in half_modes)
    t_bytes = half_elems * c * s
    out_bytes = shape[n] * c * s

    # multi-TTV: contract every sibling mode of the half away from T
    ttv_flops = 2.0 * half_elems * c if len(list(half_modes)) > 1 else 0.0
    gemm = krp = 0.0
    coll = 0.0
    if n == (0 if in_left else split):  # first mode of the half: build T
        total = math.prod(shape)
        gemm = 2.0 * total * c
        other = [m for m in range(problem.ndim) if (m >= split) == in_left]
        # KRP of the other half: prod(other dims) x C elements (~1 hadamard
        # multiply per element with the reuse fold -- same convention as
        # mttkrp_flops' krp_flops)
        krp_elems = math.prod(shape[m] for m in other) * c if other else 0
        krp = float(krp_elems)
        coll = ring_allreduce_bytes(t_bytes, problem.reduce_participants(half_modes))
        bytes_ = total * s + 2.0 * krp_elems * s + 2.0 * t_bytes + out_bytes
    else:
        bytes_ = t_bytes + out_bytes
    return ModeCost(
        gemm_flops=gemm,
        krp_flops=krp,
        second_step_flops=ttv_flops,
        bytes=bytes_,
        collective_bytes=coll,
    )
