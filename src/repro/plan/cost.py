"""Analytic per-mode / per-node cost model behind ``plan_sweep``.

Extends the flop/byte model of :func:`repro.core.mttkrp.mttkrp_flops` with
the algorithm-specific intermediate traffic (the full-KRP materialization of
1-step, the partial tensor of 2-step, the partial tensors of a contraction
schedule) and -- for sharded problems -- the per-node psum volume the
``mode_axes`` placement requires (ring all-reduce over the axes mapped to
contracted modes, per Ballard/Knight/Rouse's collective-volume accounting).

Seconds are predicted against the roofline constants of
``repro.analysis.roofline`` with a *bounded-overlap* model:

    predicted_s = max(compute_s, collective_s)
                + serial_fraction * min(compute_s, collective_s)

where ``compute_s = flops/PEAK + bytes/HBM`` and ``collective_s =
collective_bytes/ICI``.  ``serial_fraction`` is the per-executor fraction
of the smaller term that cannot be hidden behind the larger one: 1.0 for
the plain sharded executor (psum strictly after the local GEMM -- the model
degenerates to the old additive sum), ``1/n_chunks`` for the overlapping
executor (chunk ``k``'s psum runs under chunk ``k+1``'s GEMM; only the
first GEMM and the last psum stay exposed).  Measured constants fitted by
``bench_mttkrp --calibrate`` enter through the ``serial_fractions`` mapping
every costing entry point accepts (and ``plan_sweep`` threads through).

:func:`node_cost` is the single coster for schedule nodes -- leaf-off-root
MTTKRPs, root-level partial GEMMs, and partial-to-partial multi-TTVs alike
-- and :func:`validate_executor` is the one validity predicate every
(schedule, executor) pair passes through: a pair is either costed or
rejected here, never special-cased downstream.

Absolute numbers are hardware-nominal; the planner only ever compares
costs of the same contraction across algorithms/executors/schedules, where
shared terms cancel.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Mapping

from repro.analysis.roofline import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS
from repro.core.mttkrp import mttkrp_flops
from repro.core.tensor_ops import dims_split

from .problem import Problem
from .schedule import (
    ContractionNode,
    binary_schedule,
    pp_pairs,
    ring_allreduce_bytes,
)

ALGORITHMS = (
    "1step",
    "2step",
    "2step-left",
    "2step-right",
    "dimtree",
    "fused",
    "matrix_free",
    "einsum",
    "baseline",
)

# Executor kinds the planner dispatches over (repro.plan.executor classes).
EXECUTORS = ("local", "sharded", "overlapping", "compressed")

# Default chunk count of the overlapping executor's double-buffered psum
# pipeline: the serialization fraction is ~1/n_chunks, so 4 already hides
# 75% of the hidable term while keeping per-chunk GEMMs large enough to
# stay compute-efficient.
DEFAULT_OVERLAP_CHUNKS = 4

# compressed_psum payload: 1 int8 byte per element + one fp32 scale per
# sender (so the wire ratio vs the uncompressed dtype depends on itemsize:
# 1/4 for fp32, 1/2 for bf16, 1/8 for f64).
_INT8_ITEMSIZE = 1.0
_SCALE_BYTES = 4.0

# Assumed long-run fraction of pairwise-perturbation sweeps that
# re-materialize the cache (factor drift crossing ``pp_tol``).  Late ALS
# sweeps drift little, so re-materialization is rare once past the initial
# transient; 1-in-8 is a conservative planning assumption -- the bench's
# measured exact fraction (``bench_mttkrp --pp``) is the ground truth.
PP_EXACT_FRACTION = 0.125


def validate_executor(problem: Problem, executor: str) -> None:
    """THE validity predicate for (problem, executor) pairings.

    Every (schedule, executor) pair is either costed or rejected here --
    schedules themselves never restrict the executor (any node's psum can
    be overlapped or compressed), so validity depends only on the problem's
    placement: ``local`` cannot run sharded problems, and the
    communication-hiding kinds need a sharded problem to have anything to
    hide.  Raises a single-format ``ValueError`` on rejection.
    """
    if executor not in EXECUTORS:
        raise ValueError(f"unknown executor {executor!r} (choose from {EXECUTORS})")
    reason = None
    if executor == "local" and problem.sharded:
        reason = "it runs on one device but the problem maps modes/batch to mesh axes"
    elif executor in ("overlapping", "compressed") and not problem.mode_axes:
        # batch-parallel-only placements have zero reduce traffic: nothing
        # to overlap or compress (mode_axes, not sharded, is the predicate)
        reason = "it reschedules/compresses psums but the problem has none"
    if reason is not None:
        raise ValueError(f"executor {executor!r} cannot run this problem: {reason}")


@dataclass(frozen=True)
class ModeCost:
    """Cost terms for one contraction (a mode's MTTKRP or a schedule node).

    ``gemm_flops`` / ``krp_flops`` / ``second_step_flops`` are the terms of
    ``mttkrp_flops`` (local block dims for sharded problems); ``bytes`` is
    total HBM traffic including intermediates; ``collective_bytes`` is the
    per-device wire volume (0 on unsharded problems).  ``serial_fraction``
    is the executor's unhidable share of the smaller of compute/collective
    time (1.0 = no overlap, the additive model).

    ``measured_s`` is a hardware-measured wall time for this exact
    contraction (from :mod:`repro.plan.autotune`'s ``TuningCache``), ``None``
    when never measured.  The analytic prediction is always kept alongside
    it: ``predicted_s`` stays model-only, ``expected_s`` prefers the
    measurement when one exists -- the planner's ``strategy='autotune'``
    argmins over ``expected_s`` (per comparison set; see
    :mod:`repro.plan.planner`).

    ``inter_bytes`` is the share of ``collective_bytes`` that crosses the
    *node* boundary of a two-level mesh and is therefore priced at the slow
    ``DCN_BW`` instead of ``ICI_BW`` (0 on single-level problems, where the
    whole collective rides the fast links and the model reduces to the old
    single-bandwidth form).
    """

    gemm_flops: float
    krp_flops: float
    second_step_flops: float
    bytes: float
    collective_bytes: float = 0.0
    serial_fraction: float = 1.0
    measured_s: float | None = None
    inter_bytes: float = 0.0

    @property
    def flops(self) -> float:
        """Total floating-point operations across all three terms."""
        return self.gemm_flops + self.krp_flops + self.second_step_flops

    @property
    def compute_s(self) -> float:
        """Local roofline time: GEMM/KRP flops + HBM traffic, no collectives."""
        return self.flops / PEAK_FLOPS + self.bytes / HBM_BW

    @property
    def intra_bytes(self) -> float:
        """Wire bytes on the fast (intra-node / ICI) level: the collective
        volume not crossing nodes."""
        return self.collective_bytes - self.inter_bytes

    @property
    def collective_s(self) -> float:
        """Wire time of the completing collective: intra-node bytes at
        nominal ICI bandwidth plus node-crossing bytes at DCN bandwidth."""
        return self.intra_bytes / ICI_BW + self.inter_bytes / DCN_BW

    @property
    def predicted_s(self) -> float:
        """Bounded-overlap roofline: ``max + serial_fraction * min`` of the
        compute and collective terms (``serial_fraction=1`` recovers the
        additive no-overlap model)."""
        c, q = self.compute_s, self.collective_s
        return max(c, q) + self.serial_fraction * min(c, q)

    @property
    def expected_s(self) -> float:
        """Best available time estimate: the hardware measurement when one
        exists (``measured_s``), the analytic ``predicted_s`` otherwise."""
        return self.predicted_s if self.measured_s is None else self.measured_s

    @property
    def predicted_overlap_efficiency(self) -> float:
        """Fraction of the hidable (smaller) term actually hidden:
        ``1 - serial_fraction`` when there is a collective to hide, else 0."""
        if self.collective_bytes <= 0.0:
            return 0.0
        return 1.0 - self.serial_fraction

    def as_dict(self) -> dict:
        """JSON-ready projection of all terms plus the derived predictions."""
        return {
            "gemm_flops": self.gemm_flops,
            "krp_flops": self.krp_flops,
            "second_step_flops": self.second_step_flops,
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_bytes": self.collective_bytes,
            "intra_bytes": self.intra_bytes,
            "inter_bytes": self.inter_bytes,
            "serial_fraction": self.serial_fraction,
            "compute_s": self.compute_s,
            "collective_s": self.collective_s,
            "predicted_overlap_efficiency": self.predicted_overlap_efficiency,
            "predicted_s": self.predicted_s,
            "measured_s": self.measured_s,
            "expected_s": self.expected_s,
        }


def compressed_allgather_bytes(
    block_bytes: float, participants: int, itemsize: float = 4.0
) -> float:
    """Per-device wire bytes of ``dist.collectives.compressed_psum``.

    The compressed collective is an all-gather of int8 payloads (scales are
    private per sender, so summation happens after dequantization on every
    receiver): each device receives ``participants - 1`` remote blocks at
    one byte per element -- ``block_bytes / itemsize`` -- plus one fp32
    scale each.  Versus the fp32 ring all-reduce (``2 B (p-1)/p``) the
    ratio is ``p/8`` -- a real win for few participants (4x at p=2) that
    *vanishes at p=8* and inverts beyond, which is exactly why executor
    selection is cost-driven rather than a flag.  Pass the problem's
    ``itemsize`` for non-fp32 dtypes (bf16 compresses only 2x per element).
    """
    if participants <= 1:
        return 0.0
    payload = block_bytes * _INT8_ITEMSIZE / itemsize
    return (participants - 1) * (payload + _SCALE_BYTES)


def _level_shards(problem: Problem, reduce_axes) -> tuple[int, int]:
    """Split one reduction's participants into (intra k, inter m) shards:
    ``k`` over the axes declared in ``Problem.intra_axes``, ``m`` over the
    node-crossing rest."""
    k = m = 1
    for axis in reduce_axes:
        if axis in problem.intra_axes:
            k *= problem.axis_sizes[axis]
        else:
            m *= problem.axis_sizes[axis]
    return k, m


def collective_level_bytes(
    problem: Problem,
    block_bytes: float,
    reduce_axes,
    collective: str = "flat",
) -> tuple[float, float]:
    """Per-device ``(collective_bytes, inter_bytes)`` of one node's psum.

    Splits the completing all-reduce of a ``block_bytes`` output block over
    ``reduce_axes`` into the two levels of a ``Problem.intra_axes`` mesh:

    * single-level problem (no ``intra_axes``) -- the classic ring volume,
      all of it on the fast links (``inter_bytes = 0``; predictions are
      bit-identical to the old single-bandwidth model);
    * reduction confined to one node (``m <= 1``) -- ring over the intra
      shards, nothing crosses nodes;
    * reduction only across nodes (``k <= 1``) -- the whole ring rides the
      slow level;
    * ``collective="flat"`` spanning both -- one ring over all ``k * m``
      devices; its slowest hops cross nodes, so the full volume is charged
      at DCN rate;
    * ``collective="hierarchical"`` -- reduce-scatter + all-gather within
      the node (``2 B (k-1)/k`` intra) and a ring over the ``1/k`` shard
      across nodes (``2 (B/k)(m-1)/m`` inter): the factor-``k`` cut of
      slow-level volume the two-level psum exists for.
    """
    k, m = _level_shards(problem, reduce_axes)
    if k * m <= 1:
        return 0.0, 0.0
    if not problem.intra_axes:
        return ring_allreduce_bytes(block_bytes, k * m), 0.0
    if m <= 1:
        return ring_allreduce_bytes(block_bytes, k), 0.0
    if k <= 1:
        t = ring_allreduce_bytes(block_bytes, m)
        return t, t
    if collective != "hierarchical":
        t = ring_allreduce_bytes(block_bytes, k * m)
        return t, t
    intra = ring_allreduce_bytes(block_bytes, k)
    inter = ring_allreduce_bytes(block_bytes / k, m)
    return intra + inter, inter


def hierarchical_applicable(problem: Problem, reduce_axes) -> bool:
    """True when a node's reduction spans both levels of a two-level mesh
    (``k > 1`` intra shards *and* ``m > 1`` nodes) -- i.e. the hierarchical
    psum would actually decompose instead of falling back to the flat ring,
    so the planner has a real flat-vs-hierarchical choice to argmin."""
    k, m = _level_shards(problem, reduce_axes)
    return k > 1 and m > 1


def _node_grids(n_modes: int, nodes: int):
    """All integer grids ``(m_1 .. m_N)`` with ``prod m_i == nodes``."""
    if n_modes == 1:
        yield (nodes,)
        return
    d = 1
    while d * d <= nodes:
        if nodes % d == 0:
            for q in (d, nodes // d):
                for rest in _node_grids(n_modes - 1, nodes // q):
                    yield (q,) + rest
                if d * d == nodes:
                    break
        d += 1


def mttkrp_comm_lower_bound(
    shape,
    rank: int,
    mesh_shape,
    *,
    itemsize: float = 4.0,
    per_mode: bool = False,
):
    """Communication lower bound for one full MTTKRP sweep over ``P`` nodes.

    Ballard/Knight/Rouse-style accounting (arXiv 1708.07401): any block
    placement of the dense tensor on ``P`` nodes is an integer grid
    ``(m_1 .. m_N)`` with ``prod m_n = P``, and mode ``n``'s MTTKRP must
    then reduce partial factor blocks across the ``P / m_n`` nodes sharing
    each mode-``n`` slab -- at best a ring all-reduce of the
    ``(I_n / m_n, R)`` block, i.e. ``2 (I_n / m_n) R s (1 - m_n / P)``
    bytes per node.  The bound is the minimum of the per-sweep sum over all
    grids (fractional blocks allowed: grids need not divide the dims, so
    this is a true lower bound for every realizable mapping).

    ``mesh_shape`` is the node count, or a tuple whose product is taken
    (e.g. the inter-node part of a mesh).  Returns bytes per node per
    sweep; with ``per_mode=True`` returns ``(bound, terms, grid)`` where
    ``terms[n]`` is mode ``n``'s contribution at the minimizing grid.
    """
    dims = tuple(int(d) for d in shape)
    if not dims:
        raise ValueError("shape must have at least one mode")
    nodes = mesh_shape
    if not isinstance(nodes, int):
        nodes = math.prod(int(x) for x in mesh_shape)
    nodes = int(nodes)
    if nodes < 1:
        raise ValueError(f"node count must be >= 1, got {nodes}")
    s = float(itemsize)
    best = None
    best_grid = None
    for grid in _node_grids(len(dims), nodes):
        total = 0.0
        for d, m in zip(dims, grid):
            total += 2.0 * (d / m) * rank * s * (1.0 - m / nodes)
        if best is None or total < best:
            best, best_grid = total, grid
    if not per_mode:
        return best
    terms = tuple(
        2.0 * (d / m) * rank * s * (1.0 - m / nodes)
        for d, m in zip(dims, best_grid)
    )
    return best, terms, best_grid


def _fused_krp_dims(local_shape, n: int) -> tuple[int, int]:
    """Row counts of the two partial KRPs the fused Pallas kernel streams
    (internal modes: the L/R sides; external modes: the log-balanced split
    used by ``repro.kernels.ops.fused_mttkrp``)."""
    L, _, R = dims_split(local_shape, n)
    if 0 < n < len(local_shape) - 1:
        return L, R
    from repro.kernels.ops import balanced_split  # lazy: kernels import pallas

    dims = [d for k, d in enumerate(local_shape) if k != n]
    if len(dims) < 2:
        return dims[0] if dims else 1, 1
    s = balanced_split(dims)
    return math.prod(dims[:s]), math.prod(dims[s:])


def mode_cost(
    problem: Problem, n: int, algorithm: str, *, collective: str = "flat"
) -> ModeCost:
    """Cost of one mode-``n`` MTTKRP under ``algorithm``.

    Computed on the per-device block dims; the psum volume for sharded
    problems is the ring all-reduce of the local partial result over the
    axes mapped to contracted modes (no collective when mode ``n`` itself is
    the only mapped mode -- its axis carries the output rows).  On two-level
    problems (``Problem.intra_axes`` set) ``collective`` picks how that
    volume splits across the levels -- see :func:`collective_level_bytes`.
    The ``"dimtree"`` algorithm prices the mode's share of the balanced
    binary schedule via :func:`dimtree_mode_cost` (which folds over
    :func:`node_cost`); general tree shapes are costed per node by
    :func:`node_cost` directly.

    Batched problems scale every flop/byte term by the per-device batch
    extent ``local_batch`` -- including the psum volume, which is why a
    mode-parallel placement of B small tensors pays B times the wire bytes
    while a batch-parallel placement (no mapped modes) pays zero.
    """
    if algorithm not in ALGORITHMS:
        raise ValueError(f"unknown algorithm {algorithm!r} (choose from {ALGORITHMS})")
    if algorithm == "dimtree":
        return dimtree_mode_cost(
            problem, n, (problem.ndim + 1) // 2, collective=collective
        )
    shape = problem.local_shape
    c = problem.rank
    s = problem.itemsize
    lb = problem.local_batch
    base = mttkrp_flops(shape, c, n, itemsize=s, batch=lb)
    L, In, R = dims_split(shape, n)
    out_bytes = In * c * s * lb
    coll, inter = collective_level_bytes(
        problem, out_bytes, problem.reduce_axes_for(n), collective
    )

    if algorithm == "2step" and not problem.external_mode(n):
        # forced 2-step resolves its order by cost, like the Alg. 4 line-4 rule
        left = mode_cost(problem, n, "2step-left", collective=collective)
        right = mode_cost(problem, n, "2step-right", collective=collective)
        return left if left.predicted_s < right.predicted_s else right

    if algorithm == "1step" or (
        problem.external_mode(n) and algorithm in ("2step", "2step-left", "2step-right")
    ):
        # explicit KRP: L*R*C materialized (written once, read once by the GEMM)
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=base["krp_flops"],
            second_step_flops=0.0,
            bytes=base["tensor_bytes"] + 2.0 * base["krp_bytes"] + out_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    if algorithm in ("2step-left", "2step-right"):
        # left-first contracts K_L in the GEMM, multi-TTVs over R (and vice
        # versa); intermediate is In * contracted-side * C.
        second_side = R if algorithm == "2step-left" else L
        intermediate = In * second_side * c * s * lb
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=float((L + R) * c * lb),  # two small KRPs instead of one huge
            second_step_flops=2.0 * In * second_side * c * lb,
            bytes=base["tensor_bytes"] + 2.0 * intermediate + (L + R) * c * s * lb + out_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    if algorithm == "fused":
        da, db = _fused_krp_dims(shape, n)
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=float((da + db) * c * lb),
            second_step_flops=0.0,
            # the full KRP never hits HBM -- only the two partials stream in
            bytes=base["tensor_bytes"] + (da + db) * c * s * lb + out_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    if algorithm == "matrix_free":
        # bytes-read-once model: the tensor streams through VMEM exactly one
        # time, the raw non-target factors ride along (sum of mode extents,
        # not KRP products), and nothing of KRP shape is ever written.  The
        # in-VMEM fold costs one full contraction (== gemm_flops) plus the
        # shrinking broadcast-MAC chain, priced as second_step_flops.
        others = [k for k in range(len(shape)) if k != n]
        spatial = float(math.prod(shape)) / shape[others[-1]]
        fold = 0.0
        for k in reversed(others[:-1]):
            fold += 2.0 * spatial * c * lb
            spatial /= shape[k]
        factor_bytes = float(sum(shape[k] for k in others)) * c * s * lb
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=0.0,
            second_step_flops=fold,
            bytes=base["tensor_bytes"] + factor_bytes + out_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    if algorithm == "einsum":
        return ModeCost(
            gemm_flops=base["gemm_flops"],
            krp_flops=0.0,
            second_step_flops=0.0,
            bytes=base["tensor_bytes"] + (L + In + R) * c * s * lb + out_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    assert algorithm == "baseline"
    # reorder (transpose copy: read + write) then one GEMM over the copy
    return ModeCost(
        gemm_flops=base["gemm_flops"],
        krp_flops=base["krp_flops"],
        second_step_flops=0.0,
        bytes=3.0 * base["tensor_bytes"] + 2.0 * base["krp_bytes"] + out_bytes,
        collective_bytes=coll,
        inter_bytes=inter,
    )


def _compress_terms(
    problem: Problem,
    base: ModeCost,
    block_bytes: float,
    participants: int,
    *,
    reduce_axes=(),
    collective: str = "flat",
) -> ModeCost:
    """Replace a node's ring all-reduce with the int8 error-feedback gather:
    wire bytes become :func:`compressed_allgather_bytes` of the local output
    block, and HBM traffic grows by the quantize (write + read the int8
    block) and dequantize (read the ``p-1`` gathered payloads) passes.

    On a two-level problem, ``collective="hierarchical"`` prices the split
    the executors actually run: an *exact* fp32 ring within the node (the
    intra level stays uncompressed -- it is cheap) plus the int8 gather
    across the ``m`` nodes only, so the compressed payload count drops from
    ``k * m - 1`` to ``m - 1`` senders.
    """
    s = problem.itemsize
    int8_block = block_bytes * _INT8_ITEMSIZE / s
    k, m = _level_shards(problem, reduce_axes)
    if collective == "hierarchical" and k > 1 and m > 1:
        intra = ring_allreduce_bytes(block_bytes, k)
        inter = compressed_allgather_bytes(block_bytes, m, s)
        return replace(
            base,
            collective_bytes=intra + inter,
            inter_bytes=inter,
            bytes=base.bytes + (m + 1) * int8_block,
        )
    coll = compressed_allgather_bytes(block_bytes, participants, s)
    inter = coll if (problem.intra_axes and m > 1) else 0.0
    return replace(
        base,
        collective_bytes=coll,
        inter_bytes=inter,
        bytes=base.bytes + (participants + 1) * int8_block,
    )


def _adjust(
    problem: Problem,
    base: ModeCost,
    executor: str,
    *,
    chunk_extent: int,
    n_chunks: int,
    block_bytes: float,
    participants: int,
    serial_fractions: Mapping[str, float] | None,
    reduce_axes=(),
    collective: str = "flat",
) -> ModeCost:
    """Full executor adjustment: compression terms, then schedule fraction."""
    if executor == "compressed" and base.collective_bytes > 0.0:
        base = _compress_terms(
            problem, base, block_bytes, participants,
            reduce_axes=reduce_axes, collective=collective,
        )
    fitted = (serial_fractions or {}).get(executor)
    if base.collective_bytes <= 0.0:
        return base
    if executor == "overlapping":
        chunks = max(1, min(int(n_chunks), int(chunk_extent)))
        f = float(fitted) if fitted is not None else 1.0 / chunks
        return replace(base, serial_fraction=f)
    if fitted is not None:
        return replace(base, serial_fraction=float(fitted))
    return base


def executor_mode_cost(
    problem: Problem,
    n: int,
    algorithm: str,
    executor: str = "sharded",
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    serial_fractions: Mapping[str, float] | None = None,
    collective: str = "flat",
) -> ModeCost:
    """Cost of one mode-``n`` MTTKRP under ``algorithm`` on ``executor``.

    Applies the executor's placement-specific adjustments on top of
    :func:`mode_cost`:

    * ``"local"`` / ``"sharded"`` -- the per-algorithm terms unchanged
      (``serial_fraction`` 1.0: the psum waits for the whole local GEMM).
    * ``"overlapping"`` -- same flops/bytes/wire volume, but the chunked
      double-buffered pipeline hides all but ``1/n_chunks`` of the smaller
      of compute/collective time (chunk count is capped by the local row
      count of mode ``n``).
    * ``"compressed"`` -- the fp32 ring all-reduce is replaced by the int8
      error-feedback all-gather: wire bytes become
      :func:`compressed_allgather_bytes`, and HBM traffic grows by the
      quantize/dequantize passes (write + read the int8 block, read the
      gathered payloads).

    ``serial_fractions`` (executor kind -> fitted unhidable fraction, from
    ``bench_mttkrp --calibrate``) overrides the analytic defaults.
    ``collective`` threads the two-level psum choice through (see
    :func:`collective_level_bytes`).
    """
    validate_executor(problem, executor)
    base = mode_cost(problem, n, algorithm, collective=collective)
    _, in_local, _ = dims_split(problem.local_shape, n)
    block = in_local * problem.rank * problem.itemsize * problem.local_batch
    axes = problem.reduce_axes_for(n)
    p = math.prod(problem.axis_sizes[a] for a in axes)
    return _adjust(
        problem,
        base,
        executor,
        chunk_extent=problem.local_shape[n],
        n_chunks=n_chunks,
        block_bytes=block,
        participants=p,
        serial_fractions=serial_fractions,
        reduce_axes=axes,
        collective=collective,
    )


def node_cost(
    problem: Problem,
    node: ContractionNode,
    executor: str | None = None,
    *,
    algorithm: str = "1step",
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    serial_fractions: Mapping[str, float] | None = None,
    collective: str = "flat",
) -> ModeCost:
    """Cost of one schedule node's contraction on ``executor``.

    ``executor=None`` resolves to the plain kind matching the problem's
    placement (``"sharded"`` when modes are mapped, ``"local"`` otherwise).

    The single coster behind every tree shape (the old per-mode and
    ``dimtree_mode_cost`` special cases fold into it):

    * **leaf off the root** -- a full mode MTTKRP: delegates to
      :func:`executor_mode_cost` with ``algorithm`` (the planner's per-mode
      pick applies only here).
    * **internal node off the root** -- one X-sized GEMM against the KRP of
      the contracted modes, writing the partial tensor, plus its psum over
      the contracted modes' axes.
    * **any node off a partial** -- a multi-TTV: one pass over the parent's
      (much smaller) partial per contracted mode, shrinking as it goes,
      plus this node's own psum.

    ``serial_fractions`` threads calibrated per-executor constants through,
    exactly as in :func:`executor_mode_cost`, and ``collective`` the
    two-level psum choice (the node's stamped flat ring volume is re-split
    per :func:`collective_level_bytes` on two-level problems).
    """
    if executor is None:
        executor = "sharded" if problem.sharded else "local"
    validate_executor(problem, executor)
    if node.is_root:
        raise ValueError("the schedule root is the raw tensor, not a contraction")
    c = problem.rank
    s = problem.itemsize
    lb = problem.local_batch
    if node.from_root and node.is_leaf:
        return executor_mode_cost(
            problem, node.lo, algorithm, executor,
            n_chunks=n_chunks, serial_fractions=serial_fractions,
            collective=collective,
        )
    t_elems = math.prod(node.local_shape) * lb  # kept local dims * rank (x batch)
    t_bytes = t_elems * s
    coll, inter = collective_level_bytes(
        problem, t_bytes, node.reduce_axes, collective
    )
    if node.from_root:
        total = math.prod(problem.local_shape) * lb
        krp_elems = (
            math.prod(problem.local_shape[m] for m in node.contracted) * c * lb
            if node.contracted
            else 0
        )
        base = ModeCost(
            gemm_flops=2.0 * total * c,
            krp_flops=float(krp_elems),
            second_step_flops=0.0,
            bytes=total * s + 2.0 * krp_elems * s + t_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    else:
        parent_elems = (
            math.prod(problem.local_shape[node.parent_lo : node.parent_hi]) * c * lb
        )
        ttv = 0.0
        elems = float(parent_elems)
        for m in node.contracted:
            ttv += 2.0 * elems
            elems /= problem.local_shape[m]
        base = ModeCost(
            gemm_flops=0.0,
            krp_flops=0.0,
            second_step_flops=ttv,
            bytes=parent_elems * s + t_bytes,
            collective_bytes=coll,
            inter_bytes=inter,
        )
    block = t_elems * s
    return _adjust(
        problem,
        base,
        executor,
        chunk_extent=problem.local_shape[node.lo],
        n_chunks=n_chunks,
        block_bytes=block,
        participants=node.psum_participants,
        serial_fractions=serial_fractions,
        reduce_axes=node.reduce_axes,
        collective=collective,
    )


def pp_build_cost(problem: Problem) -> ModeCost:
    """Cost of materializing the pairwise-perturbation cache once.

    One pass over the (local) tensor per pair intermediate ``M_{n,m}`` --
    the naive per-pair einsum the executors run, *not* an amortizing tree --
    each completed by its ring all-reduce over the axes mapped to the
    contracted modes (:func:`repro.plan.schedule.pp_pairs` stamps the
    volume), plus the N tiny base contractions ``M_{n,m} x V_m``.  Paid on
    every exact (re-materialization) sweep, so the planner adds it to the
    exact-sweep term of the amortized PP price.
    """
    c = problem.rank
    s = problem.itemsize
    lb = problem.local_batch
    total = math.prod(problem.local_shape) * lb
    gemm = krp = byts = coll = 0.0
    for pair in pp_pairs(problem):
        t_elems = math.prod(pair.local_shape) * lb
        gemm += 2.0 * total * c
        byts += total * s + t_elems * s
        coll += pair.psum_bytes
    # base terms: one correction-shaped GEMM per mode off its first pair
    for n in range(problem.ndim):
        m = 1 if n == 0 else 0
        ln = problem.local_shape[n]
        lm = problem.local_shape[m]
        gemm += 2.0 * ln * lm * c * lb
        byts += (ln * lm * c + lm * c + ln * c) * s * lb
    return ModeCost(
        gemm_flops=gemm, krp_flops=krp, second_step_flops=0.0,
        bytes=byts, collective_bytes=coll,
    )


def pp_correction_cost(problem: Problem) -> ModeCost:
    """Cost of ONE approximate (correction-only) PP sweep, all modes.

    Each mode's MTTKRP is its cached base plus ``N - 1`` small GEMMs --
    ``(C, I_n, I_m) x (I_m, C) -> (I_n, C)`` against each pairwise
    intermediate -- so an approximate sweep never touches the raw tensor:
    the per-sweep flops drop from ``O(N |X| C)`` to
    ``O(sum I_n I_m C)``, the whole point of pairwise perturbation.  On
    sharded problems the contraction over a mapped mode ``m`` ends in a
    ring all-reduce of the ``(I_n, C)`` block over that mode's axis.
    """
    c = problem.rank
    s = problem.itemsize
    lb = problem.local_batch
    gemm = byts = coll = 0.0
    for n in range(problem.ndim):
        ln = problem.local_shape[n]
        out_bytes = ln * c * s * lb
        for m in range(problem.ndim):
            if m == n:
                continue
            lm = problem.local_shape[m]
            gemm += 2.0 * ln * lm * c * lb
            byts += (ln * lm * c + lm * c) * s * lb + out_bytes
            coll += ring_allreduce_bytes(out_bytes, problem.mode_shards(m))
    return ModeCost(
        gemm_flops=gemm, krp_flops=0.0, second_step_flops=0.0,
        bytes=byts, collective_bytes=coll,
    )


def pp_amortized_cost(
    problem: Problem,
    exact_sweep_s: float,
    *,
    exact_fraction: float = PP_EXACT_FRACTION,
    build_s: float | None = None,
    correction_s: float | None = None,
) -> dict:
    """Amortized per-sweep price of the PP strategy, as a describe() row.

    ``f * (exact_sweep_s + build_s) + (1 - f) * correction_s`` with ``f``
    the assumed exact-sweep fraction: a re-materialization sweep pays the
    full exact sweep plus the cache build, every other sweep only the
    first-order corrections.  This slightly over-prices PP -- the engine
    only pays the build on exact sweeps whose step settled under the
    tolerance, not on every exact sweep -- so the argmin errs toward the
    exact strategy.  ``build_s`` / ``correction_s`` default to the
    analytic predictions; pass hardware measurements (from
    :func:`repro.plan.autotune.tune`) to price on the measured basis.
    """
    if build_s is None:
        build_s = pp_build_cost(problem).predicted_s
    if correction_s is None:
        correction_s = pp_correction_cost(problem).predicted_s
    f = float(exact_fraction)
    amortized = f * (exact_sweep_s + build_s) + (1.0 - f) * correction_s
    return {
        "tol": problem.pp_tol,
        "exact_fraction": f,
        "exact_sweep_s": exact_sweep_s,
        "build_s": build_s,
        "correction_sweep_s": correction_s,
        "amortized_sweep_s": amortized,
    }


def dimtree_mode_cost(
    problem: Problem, n: int, split: int, *, collective: str = "flat"
) -> ModeCost:
    """Dimension-tree cost of mode ``n`` given the half split at ``split``.

    Back-compat per-mode view of the binary schedule, folded over
    :func:`node_cost`: the first mode of each multi-mode half additionally
    carries its half's partial contraction (the X-sized GEMM + psum), every
    mode pays its leaf (a multi-TTV off the half's partial, or the full
    MTTKRP when the half has a single mode).  Summing over modes equals
    summing :func:`node_cost` over the binary schedule's nodes.
    """
    sched = binary_schedule(problem, split)
    leaf = sched.leaf_for_mode(n)
    total = node_cost(problem, leaf, algorithm="1step", collective=collective)
    if not leaf.from_root and n == leaf.parent_lo:
        parent = sched.nodes[leaf.parent]
        head = node_cost(problem, parent, collective=collective)
        total = ModeCost(
            gemm_flops=total.gemm_flops + head.gemm_flops,
            krp_flops=total.krp_flops + head.krp_flops,
            second_step_flops=total.second_step_flops + head.second_step_flops,
            bytes=total.bytes + head.bytes,
            collective_bytes=total.collective_bytes + head.collective_bytes,
            inter_bytes=total.inter_bytes + head.inter_bytes,
        )
    return total
