"""``plan_sweep``: the single front door for ALS algorithm choice.

The paper's Sec. 5.3.3 finding -- 1-step on external modes, 2-step on
internal modes -- used to be hard-coded inside ``mttkrp(method="auto")`` and
re-derived independently by four sweep implementations.  It now lives here,
driven by the analytic cost model of :mod:`repro.plan.cost`: ``auto`` picks
each root-level mode's algorithm by predicted seconds, breaking near-ties
(within 10%) toward the paper's empirical recommendation, which exactly
reproduces the Sec. 5.3.3 dispatch on the benchmark shapes while letting
genuinely lopsided shapes (e.g. one huge mode flanked by tiny ones) escape
the heuristic.

Beyond the per-mode algorithm, ``plan_sweep`` plans the *contraction
schedule* and the *executor* jointly: ``strategy='auto'`` cost-argmins over
the tree shapes of :func:`repro.plan.schedule.enumerate_schedules` (the
flat per-mode sweep, the binary split at every boundary, and the
multi-level chain for order >= 4) and, via ``executor='auto'``, over the
executor kinds of :data:`repro.plan.cost.EXECUTORS` under the
bounded-overlap model -- so dimension-tree reuse, communication hiding and
compression are all planner decisions, not call-site flags.  Any (schedule,
executor) pair is valid (:func:`repro.plan.cost.validate_executor` is the
one predicate): the overlapping and compressed executors chunk/compress the
partial contractions of tree schedules just like full MTTKRPs.  The chosen
kind lands on ``SweepPlan.executor``;
:func:`repro.plan.executor.make_executor` turns it into the matching
executor instance given the concrete mesh.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, replace
from typing import Mapping

from .cost import (
    DEFAULT_OVERLAP_CHUNKS,
    EXECUTORS,
    PP_EXACT_FRACTION,
    ModeCost,
    executor_mode_cost,
    hierarchical_applicable,
    mttkrp_comm_lower_bound,
    node_cost,
    pp_amortized_cost,
    validate_executor,
)
from .problem import Problem
from .schedule import (
    ContractionNode,
    Schedule,
    binary_schedule,
    chain_schedule,
    enumerate_schedules,
    flat_schedule,
)

STRATEGIES = (
    "auto",
    "autotune",
    "pp",
    "1step",
    "2step",
    "2step-left",
    "2step-right",
    "dimtree",
    "fused",
    "matrix_free",
    "einsum",
    "baseline",
)

# Named schedule shapes accepted by ``plan_sweep(schedule=...)``.
SCHEDULE_NAMES = ("flat", "binary", "chain")

# auto prefers 2-step on internal modes unless 1-step is predicted >10%
# cheaper: the flop/byte terms of the two algorithms cross within model noise
# on near-cubic shapes (where the paper measured 2-step ahead), so the model
# alone decides only clear wins.  The same margin breaks schedule near-ties
# toward the flat per-mode sweep (the shape the paper measured).
_NEAR_TIE = 0.9

# the compressed executor changes numerics (int8 + error feedback), so it
# must beat the best *exact* executor by >10% predicted time to be selected
# -- mirroring the _NEAR_TIE convention of the algorithm dispatch.
_COMPRESS_MARGIN = 0.9


@dataclass(frozen=True)
class ModePlan:
    """Algorithm choice + predicted cost for one mode's MTTKRP (leaf view)."""

    mode: int
    algorithm: str
    cost: ModeCost

    def as_dict(self) -> dict:
        """JSON-ready row: mode, algorithm, and every cost term."""
        return {"mode": self.mode, "algorithm": self.algorithm, **self.cost.as_dict()}


@dataclass(frozen=True)
class NodePlan:
    """One schedule node's planned contraction: algorithm + predicted cost.

    ``algorithm`` is a per-mode MTTKRP method for leaves off the root,
    ``"partial-krp"`` for root-level partial GEMMs, and ``"partial-ttv"``
    for contractions of an already-computed partial.  ``tiles`` carries the
    hardware-tuned Pallas tile config (``{"block_i": ..., "block_b": ...}``)
    when ``strategy='autotune'`` planned a kernel-backed algorithm; the
    executors thread it into :mod:`repro.kernels.ops`.

    ``collective`` is the planned completing-psum strategy (``"flat"`` or
    ``"hierarchical"``, argmin'd per node on two-level meshes) -- the
    executors thread it into :mod:`repro.dist.dist_mttkrp` exactly like
    ``algorithm``/``tiles``.  ``lower_bound_bytes`` is the leaf's share of
    the Ballard-Knight-Rouse communication lower bound (per node per
    sweep), stamped on certified-planning runs; ``None`` elsewhere.
    """

    node: ContractionNode
    algorithm: str
    cost: ModeCost
    tiles: Mapping[str, int] | None = None
    collective: str = "flat"
    lower_bound_bytes: float | None = None

    def as_dict(self) -> dict:
        """JSON-ready row: node topology/psum metadata + every cost term."""
        return {
            **self.node.as_dict(),
            "algorithm": self.algorithm,
            "tiles": dict(self.tiles) if self.tiles else None,
            "collective": self.collective,
            "lower_bound_bytes": self.lower_bound_bytes,
            **self.cost.as_dict(),
        }


@dataclass(frozen=True)
class SweepPlan:
    """Planned contraction schedule for one full ALS sweep.

    ``schedule`` is the contraction tree the engine walks and ``nodes`` its
    per-node plans in evaluation order; ``modes`` is the per-mode leaf view
    (kept stable for benchmarks and the pre-schedule callers).  ``split`` is
    the binary half boundary when the tree is the classic two-partial split;
    ``normalize`` is carried here because it is part of the sweep recipe the
    executors share; ``serial_fractions`` records calibrated per-executor
    overlap constants when the plan was built with them.  ``describe()`` is
    the JSON-ready prediction surface benchmarks report against
    measurements.

    For batched sharded problems the planner also argmins over *placements*
    (mode-parallel as given vs all-batch-parallel); ``placements`` records
    each candidate's predicted cost and ``problem`` is the winning
    placement -- build the executor from ``plan.problem``'s
    ``mode_axes``/``batch_axes``, not from the pre-planning problem.

    ``pp`` flags the pairwise-perturbation sweep mode: the engine still
    carries this plan's exact schedule (re-materialization sweeps run it
    verbatim), but while factor drift stays under ``problem.pp_tol`` each
    sweep approximates every MTTKRP from the cached pairwise intermediates
    plus first-order corrections.  ``pp_info`` is the pricing row behind
    the decision (see :func:`repro.plan.cost.pp_amortized_cost`), ``None``
    when the problem never opted in (``pp_tol == 0``).

    On two-level meshes (``Problem.intra_axes``) the planner additionally
    argmins over *mesh mappings* (mode -> axis assignments) with the
    flat-vs-hierarchical collective choice folded in per node:
    ``mappings`` records each evaluated candidate with its modeled
    per-node inter-node volume and the Ballard-Knight-Rouse lower bound,
    ``lower_bound_bytes`` is the winning problem's bound (bytes per node
    per sweep), and ``certified_bandwidth_optimal`` flags a winner whose
    modeled inter-node volume is within the planner's ``certify_eps`` of
    that bound -- enumeration stops early once a candidate certifies.
    """

    problem: Problem
    strategy: str
    modes: tuple[ModePlan, ...]
    split: int | None = None
    normalize: bool = True
    executor: str = "local"
    schedule: Schedule | None = None
    nodes: tuple[NodePlan, ...] = ()
    serial_fractions: Mapping[str, float] | None = None
    placements: tuple[Mapping, ...] = ()
    pp: bool = False
    pp_info: Mapping | None = None
    mappings: tuple[Mapping, ...] = ()
    lower_bound_bytes: float | None = None
    certified_bandwidth_optimal: bool = False

    @property
    def kind(self) -> str:
        """``"dimtree"`` for tree schedules, ``"permode"`` for the flat one."""
        if self.schedule is not None:
            return "permode" if self.schedule.is_flat else "dimtree"
        return "dimtree" if self.split is not None else "permode"

    @property
    def resolved_schedule(self) -> Schedule:
        """The plan's schedule, deriving the degenerate tree for plans built
        without one (flat, or the binary split when ``split`` is set)."""
        if self.schedule is not None:
            return self.schedule
        if self.split is not None:
            return binary_schedule(self.problem, self.split)
        return flat_schedule(self.problem)

    def node_plan(self, node_id: int) -> NodePlan:
        """The :class:`NodePlan` of one schedule node."""
        for np_ in self.nodes:
            if np_.node.id == node_id:
                return np_
        raise ValueError(f"no plan for node {node_id}")

    def total_cost(self) -> dict:
        """Sweep-level sums of the per-contraction cost terms/predictions
        (over every schedule node; for flat plans this equals the per-mode
        sum)."""
        rows = self.nodes if self.nodes else self.modes
        return {
            "flops": sum(r.cost.flops for r in rows),
            "bytes": sum(r.cost.bytes for r in rows),
            "collective_bytes": sum(r.cost.collective_bytes for r in rows),
            "intra_bytes": sum(r.cost.intra_bytes for r in rows),
            "inter_bytes": sum(r.cost.inter_bytes for r in rows),
            "predicted_s": sum(r.cost.predicted_s for r in rows),
        }

    def describe(self) -> dict:
        """Predicted flops / HBM bytes / collective bytes per mode and per
        schedule node, plus totals -- and, for batched sharded problems, the
        placement candidates compared (each with its predicted seconds and
        wire bytes, the selected one flagged).  The ``pp`` row prices the
        pairwise-perturbation strategy against the exact sweep (amortized
        per-sweep seconds; ``{"enabled": False}`` when the problem never
        opted in via ``pp_tol``)."""
        return {
            "shape": list(self.problem.shape),
            "rank": self.problem.rank,
            "dtype": self.problem.dtype_str,
            "strategy": self.strategy,
            "kind": self.kind,
            "executor": self.executor,
            "split": self.split,
            "sharded": self.problem.sharded,
            "mode_axes": {str(k): v for k, v in self.problem.mode_axes.items()},
            "batch": self.problem.batch,
            "batch_axes": list(self.problem.batch_axes),
            "local_batch": self.problem.local_batch,
            "placement": _placement_label(self.problem),
            "placements": [dict(p) for p in self.placements],
            "local_shape": list(self.problem.local_shape),
            "schedule": self.resolved_schedule.name,
            "modes": [m.as_dict() for m in self.modes],
            "nodes": [n.as_dict() for n in self.nodes],
            "serial_fractions": dict(self.serial_fractions or {}),
            "pp": {"enabled": self.pp, **dict(self.pp_info or {})},
            "mappings": [dict(m) for m in self.mappings],
            "lower_bound_bytes": self.lower_bound_bytes,
            "certified": self.certified_bandwidth_optimal,
            "totals": self.total_cost(),
        }


def _placement_label(problem: Problem) -> str:
    """Human name of a problem's mesh placement (for describe()/planning)."""
    if problem.mode_axes:
        return "mode-parallel"
    if problem.batch_axes:
        return "batch-parallel"
    return "unsharded"


def _placement_candidates(problem: Problem) -> list[Problem]:
    """Placement candidates the planner argmins over, as-given first.

    A batched mode-parallel problem additionally gets the all-batch-parallel
    remap (no mapped modes, the batch sharded over every mesh axis) whenever
    the batch divides the device count -- the placement with zero reduce
    traffic, which the Ballard-Knight-Rouse accounting predicts to win for
    fleets of small tensors.  Unbatched problems (and problems already
    batch-parallel, whose mode mapping we cannot invent) plan exactly as
    before: one candidate.
    """
    cands = [problem]
    if problem.batched and problem.mode_axes and problem.axis_sizes:
        devices = math.prod(problem.axis_sizes.values())
        if devices > 1 and problem.batch % devices == 0:
            cands.append(
                replace(
                    problem,
                    mode_axes={},
                    batch_axes=tuple(sorted(problem.axis_sizes)),
                )
            )
    return cands


def _mapping_candidates(problem: Problem) -> list[Problem]:
    """Alternative mode->axis assignments of a two-level problem's mesh.

    Every way to hand the axes the as-given mapping uses to distinct tensor
    modes (divisibility-checked), as-given excluded -- the search space of
    the certified mesh planning: same mesh, same tensor, different choice of
    which modes absorb the node / device axes, which is exactly what moves
    the inter-node reduce volume the BKR bound constrains.  Empty for flat
    problems (no ``intra_axes``) so single-level planning never changes.
    """
    if not (problem.intra_axes and problem.mode_axes):
        return []
    axes = sorted(set(problem.mode_axes.values()))
    given = dict(problem.mode_axes)
    out = []
    for modes in itertools.permutations(range(problem.ndim), len(axes)):
        mapping = dict(zip(modes, axes))
        if mapping == given:
            continue
        if any(
            problem.shape[m] % problem.axis_sizes[a] for m, a in mapping.items()
        ):
            continue
        out.append(replace(problem, mode_axes=mapping))
    return out


def _node_bound_bytes(problem: Problem) -> tuple[float, tuple[float, ...]] | None:
    """(BKR bound, per-mode terms) in bytes per node per sweep for a
    two-level mode-parallel problem; ``None`` when certification does not
    apply (flat mesh, single node, or no mapped modes)."""
    if not (problem.mode_axes and problem.intra_axes and problem.n_nodes > 1):
        return None
    bound, terms, _ = mttkrp_comm_lower_bound(
        problem.shape, problem.rank, problem.n_nodes,
        itemsize=problem.itemsize, per_mode=True,
    )
    lb = problem.local_batch
    return bound * lb, tuple(t * lb for t in terms)


def _pick_collective(
    problem: Problem,
    node: ContractionNode,
    alg: str,
    cost: ModeCost,
    executor: str,
    n_chunks: int,
    serial_fractions: Mapping[str, float] | None,
    measured=None,
) -> tuple[str, ModeCost]:
    """Flat-vs-hierarchical argmin for one node's completing collective.

    ``cost`` is the node's flat-collective cost (measurement already
    stamped when available).  When the node's reduction spans both mesh
    levels the hierarchical variant is costed head-to-head: measured
    seconds decide when *both* variants are measured (autotune), the
    analytic prediction otherwise -- measured and analytic never compete.
    """
    if not hierarchical_applicable(problem, node.reduce_axes):
        return "flat", cost
    if node.from_root and node.is_leaf:
        hier = executor_mode_cost(
            problem, node.mode, alg, executor, n_chunks=n_chunks,
            serial_fractions=serial_fractions, collective="hierarchical",
        )
    else:
        hier = node_cost(
            problem, node, executor, n_chunks=n_chunks,
            serial_fractions=serial_fractions, collective="hierarchical",
        )
    if measured is not None:
        m = measured.node_time(node, alg, executor, collective="hierarchical")
        if m is not None:
            hier = replace(hier, measured_s=m)
    if cost.measured_s is not None and hier.measured_s is not None:
        pick_hier = hier.measured_s < cost.measured_s
    else:
        pick_hier = hier.predicted_s < cost.predicted_s
    return ("hierarchical", hier) if pick_hier else ("flat", cost)


def _auto_mode(
    problem: Problem,
    n: int,
    executor: str,
    n_chunks: int,
    serial_fractions: Mapping[str, float] | None = None,
    node: ContractionNode | None = None,
    measured=None,
) -> ModePlan:
    """Cost-model dispatch for one mode (reproduces paper Sec. 5.3.3).

    With ``measured`` (a :class:`repro.plan.autotune.Measurements`, under
    ``strategy='autotune'``) every candidate's hardware measurement is
    stamped on its cost; when the *whole* candidate set is measured the
    choice is a strict argmin over measured seconds (the paper's own Sec. 5
    methodology) and the Pallas kernels (``fused`` and the streaming
    ``matrix_free``) join the candidates.
    Measured and analytic seconds never compete inside one comparison --
    a partially measured set falls back to the analytic near-tie rule.
    """

    def cost(alg: str) -> ModeCost:
        c = executor_mode_cost(
            problem, n, alg, executor, n_chunks=n_chunks,
            serial_fractions=serial_fractions,
        )
        if measured is not None and node is not None:
            m = measured.node_time(node, alg, executor)
            if m is not None:
                c = replace(c, measured_s=m)
        return c

    cands: dict[str, ModeCost] = {"1step": cost("1step")}
    if not problem.external_mode(n):
        cands["2step-left"] = cost("2step-left")
        cands["2step-right"] = cost("2step-right")
    for kernel_alg in ("fused", "matrix_free"):
        if (
            measured is not None
            and node is not None
            and measured.node_time(node, kernel_alg, executor) is not None
        ):
            cands[kernel_alg] = cost(kernel_alg)
    if len(cands) > 1 and all(c.measured_s is not None for c in cands.values()):
        alg = min(cands, key=lambda a: cands[a].measured_s)
        return ModePlan(n, alg, cands[alg])

    if problem.external_mode(n):
        # 2-step degenerates to 1-step here; only 1-step is a real candidate.
        return ModePlan(n, "1step", cands["1step"])
    left, right = cands["2step-left"], cands["2step-right"]
    # strict < keeps the Alg. 4 tie convention (L == R resolves right-first)
    two_alg, two = ("2step-left", left) if left.predicted_s < right.predicted_s else ("2step-right", right)
    one = cands["1step"]
    if one.predicted_s < _NEAR_TIE * two.predicted_s:
        return ModePlan(n, "1step", one)
    return ModePlan(n, two_alg, two)


def _plan_nodes(
    problem: Problem,
    sched: Schedule,
    strategy: str,
    executor: str,
    n_chunks: int,
    serial_fractions: Mapping[str, float] | None,
    measured=None,
) -> tuple[NodePlan, ...]:
    """NodePlans in evaluation order for one (schedule, executor) pair.

    Under ``strategy='autotune'`` (``measured`` set) every node's hardware
    measurement -- leaves and partial contractions alike -- is stamped on
    its cost, and leaves planned onto a kernel-backed algorithm carry the
    tuned Pallas tile config on ``NodePlan.tiles``.  On two-level meshes
    each node's completing collective is additionally argmin'd flat vs
    hierarchical (:func:`_pick_collective`) and stamped on
    ``NodePlan.collective``.
    """
    plans = []
    for node in sched.walk():
        if node.from_root and node.is_leaf:
            if strategy in ("auto", "autotune"):
                mp = _auto_mode(
                    problem, node.mode, executor, n_chunks, serial_fractions,
                    node=node, measured=measured,
                )
                alg, cost = mp.algorithm, mp.cost
            else:
                # forced strategies pin the leaf algorithm verbatim; tree
                # strategies route root leaves through the 1-step GEMM (the
                # arithmetic the binary tree's size-1 halves always used)
                alg = "1step" if strategy == "dimtree" else strategy
                cost = executor_mode_cost(
                    problem, node.mode, alg, executor, n_chunks=n_chunks,
                    serial_fractions=serial_fractions,
                )
            tiles = None
            if measured is not None:
                if alg == "fused":
                    tiles = measured.kernel_tiles("fused_mttkrp")
                elif alg == "matrix_free":
                    tiles = measured.kernel_tiles("matrix_free")
            coll, cost = _pick_collective(
                problem, node, alg, cost, executor, n_chunks,
                serial_fractions, measured,
            )
            plans.append(NodePlan(node, alg, cost, tiles=tiles, collective=coll))
        else:
            alg = "partial-krp" if node.from_root else "partial-ttv"
            cost = node_cost(
                problem, node, executor, n_chunks=n_chunks,
                serial_fractions=serial_fractions,
            )
            if measured is not None:
                m = measured.node_time(node, alg, executor)
                if m is not None:
                    cost = replace(cost, measured_s=m)
            coll, cost = _pick_collective(
                problem, node, alg, cost, executor, n_chunks,
                serial_fractions, measured,
            )
            plans.append(NodePlan(node, alg, cost, collective=coll))
    return tuple(plans)


def _best_executor(
    problem: Problem,
    sched: Schedule,
    strategy: str,
    candidates: tuple[str, ...],
    n_chunks: int,
    serial_fractions: Mapping[str, float] | None,
    measured=None,
) -> tuple[str, tuple[NodePlan, ...], float, float | None]:
    """Cost-argmin executor for one schedule among ``candidates``.

    Exact kinds compete head-to-head (ties resolve to the earlier, plainer
    kind); ``compressed`` changes numerics, so it must beat the best exact
    kind by >10% (``_COMPRESS_MARGIN``).  When every candidate's node plan
    is fully measured (autotune), the comparison runs over measured sweep
    seconds instead of the analytic predictions -- mixed sets stay on the
    analytic basis so measured CPU milliseconds never race nominal-constant
    nanoseconds.  Returns ``(kind, node plans, analytic total, measured
    total-or-None)``.
    """
    plans = {
        ex: _plan_nodes(
            problem, sched, strategy, ex, n_chunks, serial_fractions, measured
        )
        for ex in candidates
    }
    pred = {
        ex: sum(np_.cost.predicted_s for np_ in plans[ex]) for ex in candidates
    }
    fully_measured = all(
        np_.cost.measured_s is not None for ex in candidates for np_ in plans[ex]
    ) and measured is not None
    totals = (
        {ex: sum(np_.cost.measured_s for np_ in plans[ex]) for ex in candidates}
        if fully_measured
        else pred
    )

    def result(ex: str) -> tuple[str, tuple[NodePlan, ...], float, float | None]:
        meas = (
            sum(np_.cost.measured_s for np_ in plans[ex]) if fully_measured else None
        )
        return ex, plans[ex], pred[ex], meas

    exacts = [ex for ex in candidates if ex != "compressed"]
    if not exacts:  # compressed was forced explicitly
        return result(candidates[0])
    best = exacts[0]
    for ex in exacts[1:]:
        if totals[ex] < totals[best]:
            best = ex
    if "compressed" in candidates and totals["compressed"] < _COMPRESS_MARGIN * totals[best]:
        best = "compressed"
    return result(best)


def _resolve_schedules(
    problem: Problem, strategy: str, split: int | None, schedule
) -> list[Schedule]:
    """Candidate schedules for one plan_sweep call."""
    if isinstance(schedule, Schedule):
        if schedule.problem != problem:
            raise ValueError("schedule was built for a different Problem")
        return [schedule]
    if isinstance(schedule, str):
        if schedule not in SCHEDULE_NAMES:
            raise ValueError(
                f"unknown schedule {schedule!r} (choose from {SCHEDULE_NAMES})"
            )
        if schedule == "flat":
            return [flat_schedule(problem)]
        if schedule == "binary":
            return [binary_schedule(problem, split)]
        return [chain_schedule(problem)]
    assert schedule is None
    if strategy == "dimtree":
        return [binary_schedule(problem, split)]
    if strategy in ("auto", "autotune"):
        return enumerate_schedules(problem)
    return [flat_schedule(problem)]


def select_executor(
    problem: Problem,
    strategy: str = "auto",
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    schedule=None,
    serial_fractions: Mapping[str, float] | None = None,
    tuning_cache=None,
) -> str:
    """Cost-argmin executor kind for ``problem`` under ``strategy``.

    Unsharded problems run locally.  Sharded plans compare the plain
    ``sharded`` executor against ``overlapping`` (communication hidden
    behind chunked contractions) and ``compressed`` (int8 error-feedback
    all-gather) on total predicted sweep seconds -- jointly with the
    schedule shapes the strategy admits, exactly as
    :func:`plan_sweep` does; ``compressed`` changes numerics, so it must
    beat the best exact executor by >10% (``_COMPRESS_MARGIN``) -- ties
    resolve to the exact executor.  Dimension-tree schedules compete on the
    same footing: their partial contractions overlap and compress per node.
    """
    return plan_sweep(
        problem, strategy, executor="auto", n_chunks=n_chunks,
        schedule=schedule, serial_fractions=serial_fractions,
        tuning_cache=tuning_cache,
    ).executor


def plan_sweep(
    problem: Problem,
    strategy: str = "auto",
    *,
    split: int | None = None,
    normalize: bool = True,
    executor: str = "auto",
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    schedule: Schedule | str | None = None,
    serial_fractions: Mapping[str, float] | None = None,
    tuning_cache=None,
    certify_eps: float = 0.25,
) -> SweepPlan:
    """Plan one full ALS sweep for ``problem``.

    ``strategy='auto'`` cost-argmins jointly over contraction-tree shapes
    (flat, the binary split at every boundary, the multi-level chain for
    order >= 4) and -- within each tree -- the per-mode algorithm of every
    leaf off the root (1-step / 2-step-left / 2-step-right by predicted
    cost).  Near-ties (within 10%) break toward the flat per-mode sweep,
    the shape the paper measured.  ``'dimtree'`` forces the classic binary
    tree (``split`` defaults to the balanced half); any other value forces
    that algorithm on every mode of the flat schedule (the old ``method=``
    passthrough, kept for the back-compat wrappers).

    ``schedule`` pins the tree shape regardless of strategy: a
    :class:`repro.plan.schedule.Schedule` built for this problem, or one of
    ``"flat"`` / ``"binary"`` / ``"chain"``.

    ``executor='auto'`` additionally picks the executor kind by the same
    cost argmin (any (schedule, executor) pair is either costed or rejected
    by :func:`repro.plan.cost.validate_executor` -- tree schedules overlap
    and compress per node like everything else); pass an explicit kind from
    :data:`repro.plan.cost.EXECUTORS` to force one.  ``n_chunks`` sizes the
    overlapping executor's psum pipeline; ``serial_fractions`` threads
    calibrated per-executor overlap constants (from ``bench_mttkrp
    --calibrate``) through every cost.  The choice lands on
    ``SweepPlan.executor``; :func:`repro.plan.executor.make_executor`
    builds the matching instance.

    Batched sharded problems (``Problem(batch=B)`` with mapped modes) are
    additionally argmin'd over *placements*: the mode-parallel mapping as
    given vs the all-batch-parallel remap (batch sharded over every mesh
    axis, zero reduce traffic).  The winning placement becomes
    ``SweepPlan.problem`` and both candidates' costs are recorded on
    ``SweepPlan.placements`` (surfaced by ``describe()``) -- the cost model
    proves, rather than assumes, that batch-parallel wins for fleets of
    small tensors.

    Problems with ``pp_tol > 0`` additionally price the pairwise-
    perturbation sweep mode (Ma & Solomonik): ``'auto'``/``'autotune'``
    enable it (``SweepPlan.pp``) when the amortized per-sweep seconds --
    assumed exact-sweep fraction x (exact sweep + cache build) plus the
    correction-only sweeps -- beat the exact sweep, and ``strategy='pp'``
    forces it.  The plan's schedule/executor stay the exact winner's: PP
    re-materialization sweeps run them verbatim.

    Two-level problems (``Problem.intra_axes``) plan against the
    Ballard-Knight-Rouse communication lower bound: every node's psum is
    argmin'd flat vs hierarchical, alternative mode->axis *mappings* of the
    same mesh are enumerated (divisibility-checked permutations), each
    candidate is stamped with its modeled per-node inter-node volume and
    the bound, and enumeration stops early once a candidate's volume is
    within ``certify_eps`` (relative) of the bound -- the winner then
    carries ``certified_bandwidth_optimal`` and per-leaf
    ``lower_bound_bytes`` stamps.

    ``'autotune'`` closes the predict -> measure loop: hardware timings
    recorded by :func:`repro.plan.autotune.tune` (read from
    ``tuning_cache``, defaulting to the process cache -- planning itself
    never measures) are stamped on every node cost, fully measured
    comparison sets are argmin'd on measured seconds (the Pallas ``fused``
    kernel joins the leaf candidates, carrying its tuned tiles on
    ``NodePlan.tiles``), cached ``serial_fractions`` recalibrate the
    overlap constants, and anything unmeasured keeps the analytic
    ``node_cost`` -- an empty cache degrades to exactly ``'auto'``.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} (choose from {STRATEGIES})")
    if strategy == "pp" and problem.pp_tol <= 0.0:
        raise ValueError(
            "strategy='pp' needs Problem(pp_tol > 0): the drift threshold is "
            "part of the problem (and its signature), not a planner flag"
        )
    # "pp" forces the approximate sweep mode but still needs a full exact
    # plan (re-materialization sweeps run it verbatim); its schedule /
    # algorithm / executor choices follow the "auto" cost argmin.
    node_strategy = "auto" if strategy == "pp" else strategy
    if split is not None:
        if strategy != "dimtree" and schedule != "binary":
            raise ValueError(
                "split is only meaningful for strategy='dimtree' (or schedule='binary')"
            )
        if not 0 < split < problem.ndim:
            raise ValueError(
                f"split {split} out of range for order-{problem.ndim} tensor"
            )
    if serial_fractions is not None:
        for kind, f in dict(serial_fractions).items():
            if kind not in EXECUTORS:
                raise ValueError(
                    f"unknown executor {kind!r} in serial_fractions "
                    f"(choose from {EXECUTORS})"
                )
            if not 0.0 <= float(f) <= 1.0:
                raise ValueError(f"serial_fractions[{kind!r}] must be in [0, 1], got {f}")
    measured = None
    if strategy in ("autotune", "fused", "matrix_free"):
        # forced kernel strategies reuse the tuned tile stamps (and carry
        # any hardware timings on describe()); only autotune argmins on them
        from .autotune import lookup_measurements  # lazy: autotune plans via us

        measured = lookup_measurements(problem, cache=tuning_cache)
        if (
            measured is not None
            and serial_fractions is None
            and measured.serial_fractions
        ):
            serial_fractions = dict(measured.serial_fractions)

    # a pinned Schedule instance is bound to one Problem, so placement and
    # mapping exploration (which rebuild schedules per candidate) are off
    pinned = isinstance(schedule, Schedule)
    placements = [problem] if pinned else _placement_candidates(problem)

    def evaluate(prob):
        """One candidate problem's best (schedule, executor) row, or None
        when a forced executor kind is invalid on an alternate candidate."""
        if executor != "auto":
            try:
                validate_executor(prob, executor)
            except ValueError:
                if prob is problem:
                    raise
                return None  # forced kind invalid on the alternate candidate
            candidates = (executor,)
        elif prob.mode_axes:
            candidates = ("sharded", "overlapping", "compressed")
        elif prob.batch_axes:
            # batch-parallel placements have no psums: only the plain
            # sharded executor applies (see validate_executor)
            candidates = ("sharded",)
        else:
            candidates = ("local",)

        schedules = _resolve_schedules(prob, node_strategy, split, schedule)
        results = [
            (sched,)
            + _best_executor(
                prob, sched, node_strategy, candidates, n_chunks,
                serial_fractions, measured,
            )
            for sched in schedules
        ]  # rows: (sched, executor, node_plans, analytic_total, measured_total)
        if measured is not None and all(r[4] is not None for r in results):
            # every candidate schedule fully measured: strict argmin on
            # hardware seconds -- the measurement IS the tie-breaker, so the
            # analytic flat preference does not apply
            best = min(results, key=lambda r: r[4])
        else:
            best = results[0]
            flat_row = next((r for r in results if r[0].is_flat), None)
            for r in results[1:]:
                if r[3] < best[3]:
                    best = r
            # near-tie preference: a tree must beat the flat sweep by >10% to win
            if flat_row is not None and best[0] is not flat_row[0]:
                if best[3] >= _NEAR_TIE * flat_row[3]:
                    best = flat_row
        return (prob,) + best

    def certify(row):
        """(bound, per-node inter volume, certified) of one evaluated row;
        (None, None, False) when the BKR bound does not apply to it."""
        bt = _node_bound_bytes(row[0])
        if bt is None:
            return None, None, False
        bound, _ = bt
        # per-device inter volume x devices-per-node = bytes crossing the
        # node boundary per node per sweep -- the quantity the bound limits
        inter = sum(np_.cost.inter_bytes for np_ in row[3]) * row[0].intra_shards
        return bound, inter, inter <= (1.0 + certify_eps) * bound

    picked = []  # rows: (prob, sched, executor, node_plans, analytic, measured)
    cert_rows = []  # (row, bound, inter, certified) for bound-eligible rows
    certified_found = False
    for prob in placements:
        row = evaluate(prob)
        if row is None:
            continue
        picked.append(row)
        bound, inter, ok = certify(row)
        if bound is not None:
            cert_rows.append((row, bound, inter, ok))
            certified_found = certified_found or ok
    n_placements = len(picked)  # mapping rows appended below are not placements

    # mesh-mapping enumeration (two-level problems only): evaluate
    # alternative mode->axis assignments until one certifies against the
    # communication lower bound -- skipped entirely when the as-given
    # mapping already certifies
    if not pinned and not certified_found:
        for prob in _mapping_candidates(problem):
            row = evaluate(prob)
            if row is None:
                continue
            picked.append(row)
            bound, inter, ok = certify(row)
            cert_rows.append((row, bound, inter, ok))
            if ok:
                break  # within eps of the lower bound: provably near-optimal

    # placement/mapping argmin: strict < keeps the as-given problem on ties
    winner = picked[0]
    for row in picked[1:]:
        if row[4] < winner[4]:
            winner = row
    prob, sched, chosen, node_plans = winner[0], winner[1], winner[2], winner[3]

    # certification + per-leaf lower-bound stamps for the winning problem
    lower_bound = None
    certified = False
    for row, bound, inter, ok in cert_rows:
        if row is winner:
            lower_bound, certified = bound, ok
            break
    if lower_bound is not None:
        _, terms = _node_bound_bytes(prob)
        node_plans = tuple(
            replace(np_, lower_bound_bytes=terms[np_.node.mode])
            if np_.node.is_leaf
            else np_
            for np_ in node_plans
        )
    mapping_rows = tuple(
        {
            "mode_axes": {str(k): v for k, v in row[0].mode_axes.items()},
            "executor": row[2],
            "schedule": row[1].name,
            "predicted_s": row[4],
            "inter_bytes_per_node": inter,
            "lower_bound_bytes": bound,
            "certified": ok,
            "collectives": [np_.collective for np_ in row[3]],
            "selected": row is winner,
        }
        for row, bound, inter, ok in cert_rows
    )

    # pairwise perturbation: price the approximate sweep against the chosen
    # exact plan whenever the problem opted in (pp_tol > 0); strategy="pp"
    # forces it, "auto"/"autotune" argmin the amortized per-sweep seconds.
    # The comparison runs on the measured basis only when BOTH sides are
    # measured (the winner's sweep total and the tuned PP rows) -- measured
    # and analytic seconds never compete inside one comparison.
    pp_enabled = False
    pp_info = None
    if prob.pp_tol > 0.0:
        m_build = measured.pp_second("build_s") if measured is not None else None
        m_corr = (
            measured.pp_second("correct_sweep_s") if measured is not None else None
        )
        if winner[5] is not None and m_build is not None and m_corr is not None:
            pp_info = pp_amortized_cost(
                prob, winner[5], build_s=m_build, correction_s=m_corr
            )
            pp_info["basis"] = "measured"
        else:
            pp_info = pp_amortized_cost(prob, winner[4])
            pp_info["basis"] = "analytic"
        if strategy == "pp":
            pp_enabled = True
        elif strategy in ("auto", "autotune"):
            pp_enabled = pp_info["amortized_sweep_s"] < pp_info["exact_sweep_s"]

    placement_rows = tuple(
        {
            "placement": _placement_label(r[0]),
            "mode_axes": {str(k): v for k, v in r[0].mode_axes.items()},
            "batch_axes": list(r[0].batch_axes),
            "executor": r[2],
            "schedule": r[1].name,
            "predicted_s": r[4],
            "collective_bytes": sum(np_.cost.collective_bytes for np_ in r[3]),
            "selected": r is winner,
        }
        for r in picked[:n_placements]
    ) if n_placements > 1 else ()

    modes = tuple(
        sorted(
            (
                ModePlan(np_.node.mode, np_.algorithm, np_.cost)
                for np_ in node_plans
                if np_.node.is_leaf
            ),
            key=lambda mp: mp.mode,
        )
    )
    return SweepPlan(
        prob,
        strategy,
        modes,
        split=sched.split,
        normalize=normalize,
        executor=chosen,
        schedule=sched,
        nodes=node_plans,
        serial_fractions=dict(serial_fractions) if serial_fractions else None,
        placements=placement_rows,
        pp=pp_enabled,
        pp_info=pp_info,
        mappings=mapping_rows,
        lower_bound_bytes=lower_bound,
        certified_bandwidth_optimal=certified,
    )
