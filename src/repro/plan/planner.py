"""``plan_sweep``: the single front door for ALS algorithm choice.

The paper's Sec. 5.3.3 finding -- 1-step on external modes, 2-step on
internal modes -- used to be hard-coded inside ``mttkrp(method="auto")`` and
re-derived independently by four sweep implementations.  It now lives here,
driven by the analytic cost model of :mod:`repro.plan.cost`: ``auto`` picks
each mode's algorithm by predicted seconds, breaking near-ties (within 10%)
toward the paper's empirical recommendation, which exactly reproduces the
Sec. 5.3.3 dispatch on the benchmark shapes while letting genuinely lopsided
shapes (e.g. one huge mode flanked by tiny ones) escape the heuristic.

Beyond the per-mode algorithm, ``plan_sweep`` also picks WHERE the sweep
runs: ``executor='auto'`` cost-argmins over the executor kinds of
:data:`repro.plan.cost.EXECUTORS` (``local`` for unsharded problems;
``sharded`` / ``overlapping`` / ``compressed`` for sharded ones) under the
bounded-overlap model, so communication hiding and compression are planner
decisions, not call-site flags.  The chosen kind lands on
``SweepPlan.executor``; :func:`repro.plan.executor.make_executor` turns it
into the matching executor instance given the concrete mesh.
"""

from __future__ import annotations

from dataclasses import dataclass

from .cost import (
    ALGORITHMS,
    DEFAULT_OVERLAP_CHUNKS,
    EXECUTORS,
    ModeCost,
    dimtree_mode_cost,
    executor_mode_cost,
)
from .problem import Problem

STRATEGIES = (
    "auto",
    "1step",
    "2step",
    "2step-left",
    "2step-right",
    "dimtree",
    "fused",
    "einsum",
    "baseline",
)

# auto prefers 2-step on internal modes unless 1-step is predicted >10%
# cheaper: the flop/byte terms of the two algorithms cross within model noise
# on near-cubic shapes (where the paper measured 2-step ahead), so the model
# alone decides only clear wins.
_NEAR_TIE = 0.9

# the compressed executor changes numerics (int8 + error feedback), so it
# must beat the best *exact* executor by >10% predicted time to be selected
# -- mirroring the _NEAR_TIE convention of the algorithm dispatch.
_COMPRESS_MARGIN = 0.9


@dataclass(frozen=True)
class ModePlan:
    """Algorithm choice + predicted cost for one mode's MTTKRP."""

    mode: int
    algorithm: str
    cost: ModeCost

    def as_dict(self) -> dict:
        """JSON-ready row: mode, algorithm, and every cost term."""
        return {"mode": self.mode, "algorithm": self.algorithm, **self.cost.as_dict()}


@dataclass(frozen=True)
class SweepPlan:
    """Per-mode algorithm schedule for one full ALS sweep.

    ``split`` is set only for dimension-tree plans (the half boundary);
    ``normalize`` is carried here because it is part of the sweep recipe the
    executors share.  ``describe()`` is the JSON-ready prediction surface
    benchmarks report against measurements.
    """

    problem: Problem
    strategy: str
    modes: tuple[ModePlan, ...]
    split: int | None = None
    normalize: bool = True
    executor: str = "local"

    @property
    def kind(self) -> str:
        """``"dimtree"`` for two-partial plans, ``"permode"`` otherwise."""
        return "dimtree" if self.split is not None else "permode"

    def total_cost(self) -> dict:
        """Sweep-level sums of the per-mode cost terms and predictions."""
        return {
            "flops": sum(m.cost.flops for m in self.modes),
            "bytes": sum(m.cost.bytes for m in self.modes),
            "collective_bytes": sum(m.cost.collective_bytes for m in self.modes),
            "predicted_s": sum(m.cost.predicted_s for m in self.modes),
        }

    def describe(self) -> dict:
        """Predicted flops / HBM bytes / collective bytes per mode + totals."""
        return {
            "shape": list(self.problem.shape),
            "rank": self.problem.rank,
            "dtype": self.problem.dtype_str,
            "strategy": self.strategy,
            "kind": self.kind,
            "executor": self.executor,
            "split": self.split,
            "sharded": self.problem.sharded,
            "mode_axes": {str(k): v for k, v in self.problem.mode_axes.items()},
            "local_shape": list(self.problem.local_shape),
            "modes": [m.as_dict() for m in self.modes],
            "totals": self.total_cost(),
        }


def _auto_mode(
    problem: Problem, n: int, executor: str, n_chunks: int
) -> ModePlan:
    """Cost-model dispatch for one mode (reproduces paper Sec. 5.3.3)."""

    def cost(alg: str) -> ModeCost:
        return executor_mode_cost(problem, n, alg, executor, n_chunks=n_chunks)

    if problem.external_mode(n):
        # 2-step degenerates to 1-step here; only 1-step is a real candidate.
        return ModePlan(n, "1step", cost("1step"))
    right = cost("2step-right")
    left = cost("2step-left")
    # strict < keeps the Alg. 4 tie convention (L == R resolves right-first)
    two_alg, two = ("2step-left", left) if left.predicted_s < right.predicted_s else ("2step-right", right)
    one = cost("1step")
    if one.predicted_s < _NEAR_TIE * two.predicted_s:
        return ModePlan(n, "1step", one)
    return ModePlan(n, two_alg, two)


def _plan_modes(
    problem: Problem, strategy: str, executor: str, n_chunks: int
) -> tuple[ModePlan, ...]:
    """Per-mode ModePlans for a non-dimtree strategy on one executor kind."""
    if strategy == "auto":
        return tuple(
            _auto_mode(problem, n, executor, n_chunks) for n in range(problem.ndim)
        )
    assert strategy in ALGORITHMS
    return tuple(
        ModePlan(
            n, strategy, executor_mode_cost(problem, n, strategy, executor, n_chunks=n_chunks)
        )
        for n in range(problem.ndim)
    )


def select_executor(
    problem: Problem,
    strategy: str = "auto",
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
) -> str:
    """Cost-argmin executor kind for ``problem`` under ``strategy``.

    Unsharded problems run locally.  Sharded per-mode plans compare the
    plain ``sharded`` executor against ``overlapping`` (communication
    hidden behind chunked GEMMs) and ``compressed`` (int8 error-feedback
    all-gather) on total predicted sweep seconds; ``compressed`` changes
    numerics, so it must beat the best exact executor by >10%
    (``_COMPRESS_MARGIN``) -- ties resolve to the exact executor.  Dimtree
    plans stay on ``sharded``: overlap/compression of the two half-partial
    contractions is not implemented (ROADMAP).
    """
    if not problem.sharded:
        return "local"
    if strategy == "dimtree":
        return "sharded"

    def total(executor: str) -> float:
        modes = _plan_modes(problem, strategy, executor, n_chunks)
        return sum(m.cost.predicted_s for m in modes)

    t_sharded, t_overlap = total("sharded"), total("overlapping")
    best_exact = "overlapping" if t_overlap < t_sharded else "sharded"
    if total("compressed") < _COMPRESS_MARGIN * min(t_sharded, t_overlap):
        return "compressed"
    return best_exact


def plan_sweep(
    problem: Problem,
    strategy: str = "auto",
    *,
    split: int | None = None,
    normalize: bool = True,
    executor: str = "auto",
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
) -> SweepPlan:
    """Plan one full ALS sweep for ``problem``.

    ``strategy='auto'`` selects per-mode among 1-step / 2-step-left /
    2-step-right by predicted cost; ``'dimtree'`` plans the two-partial
    dimension-tree schedule (``split`` defaults to the balanced half);
    any other value forces that algorithm on every mode (the old
    ``method=`` passthrough, kept for the back-compat wrappers).

    ``executor='auto'`` additionally picks the executor kind via
    :func:`select_executor` (cost-argmin under the bounded-overlap model);
    pass an explicit kind from :data:`repro.plan.cost.EXECUTORS` to force
    one.  ``n_chunks`` sizes the overlapping executor's psum pipeline.
    The choice lands on ``SweepPlan.executor``;
    :func:`repro.plan.executor.make_executor` builds the matching instance.
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} (choose from {STRATEGIES})")
    if split is not None and strategy != "dimtree":
        raise ValueError("split is only meaningful for strategy='dimtree'")
    if executor != "auto" and executor not in EXECUTORS:
        raise ValueError(
            f"unknown executor {executor!r} (choose from {('auto',) + EXECUTORS})"
        )
    if strategy == "dimtree" and executor in ("overlapping", "compressed"):
        raise ValueError(
            f"executor {executor!r} does not support dimtree plans: the half-"
            "partial contractions are neither chunked nor compressed (ROADMAP)"
        )
    if executor == "auto":
        executor = select_executor(problem, strategy, n_chunks=n_chunks)
    elif executor == "local" and problem.sharded:
        raise ValueError("executor 'local' cannot run a sharded problem")
    elif executor in ("overlapping", "compressed") and not problem.sharded:
        raise ValueError(f"executor {executor!r} needs a sharded problem")

    n_modes = problem.ndim
    if strategy == "dimtree":
        m = split if split is not None else (n_modes + 1) // 2
        if not 0 < m < n_modes:
            raise ValueError(f"split {m} out of range for order-{n_modes} tensor")
        modes = tuple(
            ModePlan(n, "dimtree", dimtree_mode_cost(problem, n, m))
            for n in range(n_modes)
        )
        return SweepPlan(
            problem, strategy, modes, split=m, normalize=normalize, executor=executor
        )

    modes = _plan_modes(problem, strategy, executor, n_chunks)
    return SweepPlan(problem, strategy, modes, normalize=normalize, executor=executor)
