"""``plan_sweep``: the single front door for ALS algorithm choice.

The paper's Sec. 5.3.3 finding -- 1-step on external modes, 2-step on
internal modes -- used to be hard-coded inside ``mttkrp(method="auto")`` and
re-derived independently by four sweep implementations.  It now lives here,
driven by the analytic cost model of :mod:`repro.plan.cost`: ``auto`` picks
each mode's algorithm by predicted seconds, breaking near-ties (within 10%)
toward the paper's empirical recommendation, which exactly reproduces the
Sec. 5.3.3 dispatch on the benchmark shapes while letting genuinely lopsided
shapes (e.g. one huge mode flanked by tiny ones) escape the heuristic.

Future ROADMAP items (async psum overlap, compressed factor all-reduce, new
backends) hook in here: they change a cost term or add an algorithm, and
every driver -- local, dimension-tree, distributed -- picks it up for free.
"""

from __future__ import annotations

from dataclasses import dataclass

from .cost import ALGORITHMS, ModeCost, dimtree_mode_cost, mode_cost
from .problem import Problem

STRATEGIES = (
    "auto",
    "1step",
    "2step",
    "2step-left",
    "2step-right",
    "dimtree",
    "fused",
    "einsum",
    "baseline",
)

# auto prefers 2-step on internal modes unless 1-step is predicted >10%
# cheaper: the flop/byte terms of the two algorithms cross within model noise
# on near-cubic shapes (where the paper measured 2-step ahead), so the model
# alone decides only clear wins.
_NEAR_TIE = 0.9


@dataclass(frozen=True)
class ModePlan:
    """Algorithm choice + predicted cost for one mode's MTTKRP."""

    mode: int
    algorithm: str
    cost: ModeCost

    def as_dict(self) -> dict:
        return {"mode": self.mode, "algorithm": self.algorithm, **self.cost.as_dict()}


@dataclass(frozen=True)
class SweepPlan:
    """Per-mode algorithm schedule for one full ALS sweep.

    ``split`` is set only for dimension-tree plans (the half boundary);
    ``normalize`` is carried here because it is part of the sweep recipe the
    executors share.  ``describe()`` is the JSON-ready prediction surface
    benchmarks report against measurements.
    """

    problem: Problem
    strategy: str
    modes: tuple[ModePlan, ...]
    split: int | None = None
    normalize: bool = True

    @property
    def kind(self) -> str:
        return "dimtree" if self.split is not None else "permode"

    def total_cost(self) -> dict:
        return {
            "flops": sum(m.cost.flops for m in self.modes),
            "bytes": sum(m.cost.bytes for m in self.modes),
            "collective_bytes": sum(m.cost.collective_bytes for m in self.modes),
            "predicted_s": sum(m.cost.predicted_s for m in self.modes),
        }

    def describe(self) -> dict:
        """Predicted flops / HBM bytes / collective bytes per mode + totals."""
        return {
            "shape": list(self.problem.shape),
            "rank": self.problem.rank,
            "dtype": self.problem.dtype_str,
            "strategy": self.strategy,
            "kind": self.kind,
            "split": self.split,
            "sharded": self.problem.sharded,
            "mode_axes": {str(k): v for k, v in self.problem.mode_axes.items()},
            "local_shape": list(self.problem.local_shape),
            "modes": [m.as_dict() for m in self.modes],
            "totals": self.total_cost(),
        }


def _auto_mode(problem: Problem, n: int) -> ModePlan:
    """Cost-model dispatch for one mode (reproduces paper Sec. 5.3.3)."""
    if problem.external_mode(n):
        # 2-step degenerates to 1-step here; only 1-step is a real candidate.
        return ModePlan(n, "1step", mode_cost(problem, n, "1step"))
    right = mode_cost(problem, n, "2step-right")
    left = mode_cost(problem, n, "2step-left")
    # strict < keeps the Alg. 4 tie convention (L == R resolves right-first)
    two_alg, two = ("2step-left", left) if left.predicted_s < right.predicted_s else ("2step-right", right)
    one = mode_cost(problem, n, "1step")
    if one.predicted_s < _NEAR_TIE * two.predicted_s:
        return ModePlan(n, "1step", one)
    return ModePlan(n, two_alg, two)


def plan_sweep(
    problem: Problem,
    strategy: str = "auto",
    *,
    split: int | None = None,
    normalize: bool = True,
) -> SweepPlan:
    """Plan one full ALS sweep for ``problem``.

    ``strategy='auto'`` selects per-mode among 1-step / 2-step-left /
    2-step-right by predicted cost; ``'dimtree'`` plans the two-partial
    dimension-tree schedule (``split`` defaults to the balanced half);
    any other value forces that algorithm on every mode (the old
    ``method=`` passthrough, kept for the back-compat wrappers).
    """
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r} (choose from {STRATEGIES})")
    if split is not None and strategy != "dimtree":
        raise ValueError("split is only meaningful for strategy='dimtree'")

    n_modes = problem.ndim
    if strategy == "dimtree":
        m = split if split is not None else (n_modes + 1) // 2
        if not 0 < m < n_modes:
            raise ValueError(f"split {m} out of range for order-{n_modes} tensor")
        modes = tuple(
            ModePlan(n, "dimtree", dimtree_mode_cost(problem, n, m))
            for n in range(n_modes)
        )
        return SweepPlan(problem, strategy, modes, split=m, normalize=normalize)

    if strategy == "auto":
        modes = tuple(_auto_mode(problem, n) for n in range(n_modes))
    else:
        assert strategy in ALGORITHMS
        modes = tuple(
            ModePlan(n, strategy, mode_cost(problem, n, strategy))
            for n in range(n_modes)
        )
    return SweepPlan(problem, strategy, modes, normalize=normalize)
