"""Executors: where a planned contraction actually runs.

The sweep engine (:mod:`repro.plan.sweep`) is executor-agnostic: it asks for
"the mode-n MTTKRP of this ModePlan" or "the half-partial of these factors"
and never touches placement.  ``LocalExecutor`` runs the paper's
shared-memory kernels directly; ``ShardedExecutor`` wraps the
``shard_map`` + minimal-``psum`` placement of :mod:`repro.dist.dist_mttkrp`
(local kernel per device block, one psum over the axes mapped to contracted
modes).  New backends -- async-collective variants, other accelerators --
implement the same four methods and every driver picks them up unchanged.
"""

from __future__ import annotations

from typing import Protocol, Sequence, runtime_checkable

import jax

from repro.core.dimtree import partial_mttkrp_left, partial_mttkrp_right
from repro.core.mttkrp import mttkrp
from repro.dist.dist_mttkrp import (
    _dist_partial_left,
    _dist_partial_right,
    dist_mttkrp,
    shard_problem,
)

from .planner import ModePlan
from .problem import Problem

Array = jax.Array


@runtime_checkable
class Executor(Protocol):
    """The four contractions an ALS sweep needs, placement included."""

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        """Place tensor + factors for this executor (identity when local)."""
        ...

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        """Full mode-``mp.mode`` MTTKRP with ``mp.algorithm``."""
        ...

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        """Dimension-tree ``T_L``: contract the trailing modes away."""
        ...

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        """Dimension-tree ``T_R``: contract the leading modes away."""
        ...


class LocalExecutor:
    """Single-device execution of the paper's shared-memory kernels."""

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        return x, list(factors)

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        return mttkrp(x, list(factors), mp.mode, method=mp.algorithm)

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        return partial_mttkrp_right(x, list(right_factors))

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        return partial_mttkrp_left(x, list(left_factors))


class ShardedExecutor:
    """Block-distributed execution over a device mesh.

    Holds the concrete ``Mesh`` + ``mode_axes`` mapping (the Problem only
    carries their sizes).  Every contraction is the local shared-memory
    kernel inside ``shard_map`` plus the minimal psum the mapping requires;
    the small Gram/pinv algebra stays at the global-array level in the
    engine, exactly as the previous hand-written distributed sweeps did.
    """

    def __init__(self, mesh, mode_axes):
        self.mesh = mesh
        self.mode_axes = dict(mode_axes)

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        return shard_problem(x, factors, self.mode_axes, self.mesh)

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        return dist_mttkrp(
            x, list(factors), mp.mode, self.mode_axes, self.mesh, method=mp.algorithm
        )

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        return _dist_partial_right(x, list(right_factors), self.mode_axes, self.mesh)

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        return _dist_partial_left(x, list(left_factors), self.mode_axes, self.mesh)
