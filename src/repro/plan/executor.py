"""Executors: where a planned contraction actually runs.

The sweep engine (:mod:`repro.plan.sweep`) is executor-agnostic: it walks
the plan's contraction schedule asking for "this node's contraction" and
never touches placement.  Four executors implement the protocol:

* :class:`LocalExecutor` -- the paper's shared-memory kernels, one device.
* :class:`ShardedExecutor` -- the ``shard_map`` + minimal-``psum`` placement
  of :mod:`repro.dist.dist_mttkrp` (local kernel per device block, one psum
  per node over the axes mapped to the modes contracted there).
* :class:`OverlappingExecutor` -- same numerics, but every node's local
  contraction -- full MTTKRPs *and* the partial contractions of a
  dimension-tree schedule -- is chunked so chunk ``k``'s psum overlaps
  chunk ``k+1``'s GEMM (communication hiding; exact).
* :class:`CompressedShardedExecutor` -- every node psum runs through the
  int8 error-feedback collective, with per-node residuals threaded through
  the sweep as carry state (communication compression; approximate but
  convergent).

``plan_sweep(executor="auto")`` picks among them by predicted cost; use
:func:`make_executor` to turn the chosen ``SweepPlan.executor`` kind into
an instance bound to a concrete mesh.
"""

from __future__ import annotations

from typing import Any, Mapping, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.dimtree import contract_from_partial, partial_mttkrp_range
from repro.core.mttkrp import mttkrp, mttkrp_batched
from repro.core.tensor_ops import mode_letters
from repro.dist.dist_mttkrp import (
    dist_contract_partial,
    dist_contract_partial_compressed,
    dist_contract_range,
    dist_contract_range_compressed,
    dist_mttkrp,
    dist_mttkrp_compressed,
    dist_mttkrp_overlapped,
    dist_pp_pairs,
    shard_problem,
)

from .cost import DEFAULT_OVERLAP_CHUNKS, EXECUTORS
from .schedule import ContractionNode

Array = jax.Array


def _node_is_batched(node: ContractionNode, src: Array) -> bool:
    """True when ``src`` carries a leading batch axis over the node's shape.

    The unbatched source of a node has a known rank from the topology alone:
    the raw tensor's order for root contractions, the parent's kept modes
    plus the rank axis for partial-to-partial ones.  One extra axis = batch.
    """
    expected = (node.parent_hi - node.parent_lo) + (0 if node.from_root else 1)
    return src.ndim == expected + 1


@runtime_checkable
class Executor(Protocol):
    """The contractions an ALS sweep needs, placement included.

    The schedule walker drives everything through :meth:`contract` -- one
    entry point per :class:`repro.plan.schedule.ContractionNode`, whether
    the node is a full mode MTTKRP, a root-level partial GEMM, or a
    partial-to-partial multi-TTV.  Executors that carry state across
    contractions (e.g. per-node error-feedback residuals) additionally
    implement the optional carry extension -- ``init_carry(plan, x,
    factors)`` and ``contract_carry(node, src, factors, algorithm, carry)
    -> (out, carry)`` -- which the engine threads through
    ``SweepState.carry`` when present (``hasattr`` duck typing; stateless
    executors skip both).
    """

    def prepare(self, problem, x: Array, factors: Sequence[Array]):
        """Place tensor + factors for this executor (identity when local)."""
        ...

    def contract(
        self, node: ContractionNode, src: Array, factors: Sequence[Array],
        algorithm: str = "auto", tiles: Mapping[str, int] | None = None,
        collective: str = "flat",
    ) -> Array:
        """Run one schedule node's contraction of ``src`` (the parent's
        output; the raw tensor for children of the root).  ``tiles`` is the
        plan's tuned Pallas tile config for kernel-backed algorithms
        (``NodePlan.tiles``); ``None`` keeps the kernel defaults.
        ``collective`` picks the psum decomposition for this node's
        reduction (``NodePlan.collective``): ``"flat"`` is one ring over
        all participating devices, ``"hierarchical"`` reduce-scatters
        within the node axis first so only shards cross the slow level
        (ignored by executors without collectives)."""
        ...


class LocalExecutor:
    """Single-device execution of the paper's shared-memory kernels."""

    def prepare(self, problem, x: Array, factors: Sequence[Array]):
        """No placement needed on one device: returns inputs unchanged."""
        return x, list(factors)

    def contract(
        self, node: ContractionNode, src: Array, factors: Sequence[Array],
        algorithm: str = "auto", tiles: Mapping[str, int] | None = None,
        collective: str = "flat",
    ) -> Array:
        """One schedule node locally: planned MTTKRP for leaves off the
        root (tuned Pallas tiles threaded through for the fused kernel),
        range GEMM for internal nodes off the root, multi-TTV einsum
        for anything contracted from a partial.  A leading batch axis on
        ``src`` (and every factor) dispatches the batched kernel for
        leaves and a vmap of the same contraction otherwise.
        ``collective`` is accepted for protocol compatibility and
        ignored: one device runs no psum to decompose."""
        batched = _node_is_batched(node, src)
        if node.from_root:
            if node.is_leaf:
                if batched:
                    return mttkrp_batched(
                        src, list(factors), node.mode, method=algorithm, tiles=tiles
                    )
                return mttkrp(src, list(factors), node.mode, method=algorithm, tiles=tiles)
            if batched:
                return jax.vmap(
                    lambda t, *fs: partial_mttkrp_range(t, list(fs), node.lo, node.hi)
                )(src, *factors)
            return partial_mttkrp_range(src, list(factors), node.lo, node.hi)
        if batched:
            return jax.vmap(
                lambda t, *fs: contract_from_partial(
                    t, dict(zip(node.contracted, fs)), node.lo, node.hi, node.parent_lo
                )
            )(src, *[factors[m] for m in node.contracted])
        sibs = {m: factors[m] for m in node.contracted}
        return contract_from_partial(src, sibs, node.lo, node.hi, node.parent_lo)

    def pp_pairs(
        self, problem, x: Array, factors: Sequence[Array]
    ) -> dict[tuple[int, int], Array]:
        """All pairwise-perturbation intermediates at the current factors:
        ``{(n, m): M_nm}`` for every ``n < m`` with
        ``M_nm[c, i_n, i_m] = sum X * prod_{k not in {n,m}} U_k[i_k, c]``
        in the rank-major layout of :class:`repro.plan.schedule.PPPair`
        -- one einsum per pair; a leading batch axis on ``x`` and the
        factors broadcasts through the ``...`` prefix unchanged."""
        order = problem.ndim
        letters = mode_letters(order)
        out: dict[tuple[int, int], Array] = {}
        for n in range(order):
            for m in range(n + 1, order):
                others = [k for k in range(order) if k not in (n, m)]
                spec = (
                    ",".join(
                        ["..." + letters] + ["..." + letters[k] + "c" for k in others]
                    )
                    + "->..." + letters[n] + letters[m] + "c"
                )
                # contract rank-last (the GEMM-friendly orientation), then
                # move rank to the front for the PPPair storage layout --
                # asking einsum for the rank-major output directly makes
                # XLA:CPU emit a far slower fused transpose-GEMM
                p = jnp.einsum(spec, x, *[factors[k] for k in others])
                out[(n, m)] = jnp.moveaxis(p, -1, -3)
        return out


class ShardedExecutor:
    """Block-distributed execution over a device mesh.

    Holds the concrete ``Mesh`` + ``mode_axes`` mapping (the Problem only
    carries their sizes).  Every node contraction is the local
    shared-memory kernel inside ``shard_map`` plus the minimal psum the
    node requires (over the axes mapped to the modes contracted *at that
    node*); the small Gram/pinv algebra stays at the global-array level in
    the engine, exactly as the previous hand-written distributed sweeps did.

    ``batch_axes`` names the mesh axes the leading batch dimension of a
    batched problem is sharded over (empty = batch replicated, or no
    batch).  Batch-parallel placements (``mode_axes`` empty, ``batch_axes``
    set) run every contraction collective-free: each device owns whole
    problems.

    ``node_axis`` names the *intra-node* mesh axis (the fast level of a
    two-level ``make_node_mesh``); it is only consulted when the engine
    passes ``collective="hierarchical"`` for a node, in which case the
    node's psum runs as reduce-scatter over ``node_axis`` + cross-node
    psum of the shard + all-gather back.
    """

    def __init__(self, mesh, mode_axes, batch_axes=(), node_axis=None):
        self.mesh = mesh
        self.mode_axes = dict(mode_axes)
        self.batch_axes = tuple(batch_axes)
        self.node_axis = node_axis

    # chunk count for the node pipeline: 1 = no chunking (plain psum)
    _n_chunks = 1

    def prepare(self, problem, x: Array, factors: Sequence[Array]):
        """Block-distribute tensor + factors per ``mode_axes`` (no reorder);
        a leading batch axis is sharded over ``batch_axes``."""
        return shard_problem(
            x, factors, self.mode_axes, self.mesh, batch_axes=self.batch_axes
        )

    def contract(
        self, node: ContractionNode, src: Array, factors: Sequence[Array],
        algorithm: str = "auto", tiles: Mapping[str, int] | None = None,
        collective: str = "flat",
    ) -> Array:
        """One schedule node on the mesh: local kernel per block + this
        node's psum over the axes mapped to its contracted modes, flat or
        hierarchical per ``collective``."""
        if node.from_root and node.is_leaf:
            return dist_mttkrp(
                src, list(factors), node.mode, self.mode_axes, self.mesh,
                method=algorithm, tiles=tiles, batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        if node.from_root:
            return dist_contract_range(
                src, list(factors), node.lo, node.hi, self.mode_axes, self.mesh,
                n_chunks=self._n_chunks, batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        return dist_contract_partial(
            src, list(factors), node.lo, node.hi, node.parent_lo, node.parent_hi,
            self.mode_axes, self.mesh, n_chunks=self._n_chunks,
            batch_axes=self.batch_axes,
            collective=collective, node_axis=self.node_axis,
        )

    def pp_pairs(
        self, problem, x: Array, factors: Sequence[Array]
    ) -> dict[tuple[int, int], Array]:
        """Pairwise-perturbation intermediates on the mesh: per pair one
        local einsum inside ``shard_map`` + the minimal psum over the axes
        mapped to the contracted modes (both kept modes ride their own
        axes, exactly like the factor rows they later update).  The PP
        cache build stays *exact* on every sharded executor -- overlapping
        changes only psum scheduling and compression only applies to the
        per-sweep factor reductions, so both inherit this verbatim."""
        return dist_pp_pairs(
            x, list(factors), self.mode_axes, self.mesh,
            batch_axes=self.batch_axes,
        )


class OverlappingExecutor(ShardedExecutor):
    """Communication-hiding sharded executor (exact).

    Identical placement and results to :class:`ShardedExecutor`, but every
    node's communication is pipelined in ``n_chunks`` slabs along its
    leading kept mode: full MTTKRPs run through
    :func:`repro.dist.dist_mttkrp.dist_mttkrp_overlapped` (slab GEMMs with
    per-slab psums -- exact: disjoint output rows of the same reduction),
    and the partial contractions of dimension-tree schedules through the
    chunked ``dist_contract_range`` / ``dist_contract_partial`` pipelines
    (one local contraction, per-slab psums -- *bitwise* identical to the
    plain executor by construction).  Only the schedule changes.
    """

    def __init__(
        self, mesh, mode_axes, n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
        batch_axes=(), node_axis=None,
    ):
        super().__init__(mesh, mode_axes, batch_axes, node_axis)
        self.n_chunks = int(n_chunks)

    @property
    def _n_chunks(self) -> int:
        """Pipeline depth used by the inherited node ``contract``."""
        return self.n_chunks

    def contract(
        self, node: ContractionNode, src: Array, factors: Sequence[Array],
        algorithm: str = "auto", tiles: Mapping[str, int] | None = None,
        collective: str = "flat",
    ) -> Array:
        """One schedule node with its psum hidden behind chunked GEMMs."""
        if node.from_root and node.is_leaf:
            return dist_mttkrp_overlapped(
                src, list(factors), node.mode, self.mode_axes, self.mesh,
                method=algorithm, n_chunks=self.n_chunks, tiles=tiles,
                batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        return super().contract(
            node, src, factors, algorithm, tiles=tiles, collective=collective
        )


class CompressedShardedExecutor(ShardedExecutor):
    """Communication-compressing sharded executor (approximate, convergent).

    Runs every node psum -- the per-mode factor all-reduces *and* the
    partial contractions of dimension-tree schedules -- through the int8
    error-feedback collective: each device quantizes its partial result
    plus its carried residual, all-gathers the int8 payloads, and
    dequant-sums locally.  The per-node residuals are persistent sweep
    state -- created by :meth:`init_carry`, threaded through
    :meth:`contract_carry` by the engine -- so the accumulated quantization
    error at every node stays bounded by one int8 step and compressed
    CP-ALS converges to the exact fit.  Nodes whose mapping needs no psum
    run the exact path.
    """

    def init_carry(self, plan, x: Array, factors: Sequence[Array]) -> dict[int, Array]:
        """Zero per-node error-feedback residuals for every schedule node
        whose contraction completes with a psum, placed on the mesh (one
        leading axis per reduced mesh axis, then -- for a batched problem --
        the batch dim sharded over ``batch_axes``, then the node's global
        output dims sharded like the output itself)."""
        prob = plan.problem
        batched = bool(getattr(prob, "batched", False))
        batch_entry = tuple(self.batch_axes) or None
        errs: dict[int, Array] = {}
        for node in plan.resolved_schedule.walk():
            if not node.reduce_axes:
                continue
            lead = tuple(self.mesh.shape[a] for a in node.reduce_axes)
            mid = (prob.batch,) if batched else ()
            e = jnp.zeros(lead + mid + node.shape, jnp.float32)
            spec = P(
                *node.reduce_axes,
                *((batch_entry,) if batched else ()),
                *[self.mode_axes.get(m) for m in node.modes],
                None,
            )
            errs[node.id] = jax.device_put(e, NamedSharding(self.mesh, spec))
        return errs

    def contract_carry(
        self,
        node: ContractionNode,
        src: Array,
        factors: Sequence[Array],
        algorithm: str,
        carry: Any,
        tiles: Mapping[str, int] | None = None,
        collective: str = "flat",
    ) -> tuple[Array, Any]:
        """Compressed node contraction; returns ``(result, new_carry)``.

        Dispatches to the compressed variant matching the node's topology
        when a residual exists for it, the exact path otherwise; ``tiles``
        threads the plan's tuned kernel tiling into the local contraction.
        With ``collective="hierarchical"`` the intra-node slice of the psum
        runs exact first and only the cross-node stage is compressed --
        same residual layout and carry semantics, less wire traffic.
        """
        if carry is None or node.id not in carry:
            return (
                self.contract(
                    node, src, factors, algorithm, tiles=tiles, collective=collective
                ),
                carry,
            )
        err = carry[node.id]
        if node.from_root and node.is_leaf:
            out, new_err = dist_mttkrp_compressed(
                src, list(factors), node.mode, self.mode_axes, self.mesh, err,
                method=algorithm, tiles=tiles, batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        elif node.from_root:
            out, new_err = dist_contract_range_compressed(
                src, list(factors), node.lo, node.hi, self.mode_axes, self.mesh,
                err, batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        else:
            out, new_err = dist_contract_partial_compressed(
                src, list(factors), node.lo, node.hi, node.parent_lo,
                node.parent_hi, self.mode_axes, self.mesh, err,
                batch_axes=self.batch_axes,
                collective=collective, node_axis=self.node_axis,
            )
        return out, {**carry, node.id: new_err}


def make_executor(
    kind: str,
    mesh=None,
    mode_axes=None,
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    batch_axes=(),
    node_axis=None,
) -> Executor:
    """Instantiate the executor for a planner-chosen kind.

    ``kind`` is a ``SweepPlan.executor`` value (one of
    :data:`repro.plan.cost.EXECUTORS`); the sharded kinds need the concrete
    ``mesh`` + ``mode_axes``, which the Problem deliberately does not carry
    (plans are pure metadata).  ``n_chunks`` sizes the overlapping
    executor's psum pipeline; ``batch_axes`` names the mesh axes a batched
    problem's leading batch dimension is sharded over (batch-parallel
    placements pass ``mode_axes={}`` plus the batch axes); ``node_axis``
    names the intra-node mesh axis hierarchical collectives decompose over
    (``Problem.node_axis`` for problems built with ``intra_axes``).
    """
    if kind not in EXECUTORS:
        raise ValueError(f"unknown executor kind {kind!r} (choose from {EXECUTORS})")
    if kind == "local":
        return LocalExecutor()
    if mesh is None or mode_axes is None:
        raise ValueError(f"executor {kind!r} needs mesh and mode_axes")
    if kind == "sharded":
        return ShardedExecutor(mesh, mode_axes, batch_axes, node_axis)
    if kind == "overlapping":
        return OverlappingExecutor(
            mesh, mode_axes, n_chunks=n_chunks, batch_axes=batch_axes,
            node_axis=node_axis,
        )
    return CompressedShardedExecutor(mesh, mode_axes, batch_axes, node_axis)
