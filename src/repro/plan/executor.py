"""Executors: where a planned contraction actually runs.

The sweep engine (:mod:`repro.plan.sweep`) is executor-agnostic: it asks for
"the mode-n MTTKRP of this ModePlan" or "the half-partial of these factors"
and never touches placement.  Four executors implement the protocol:

* :class:`LocalExecutor` -- the paper's shared-memory kernels, one device.
* :class:`ShardedExecutor` -- the ``shard_map`` + minimal-``psum`` placement
  of :mod:`repro.dist.dist_mttkrp` (local kernel per device block, one psum
  over the axes mapped to contracted modes).
* :class:`OverlappingExecutor` -- same numerics, but each mode's local
  MTTKRP is chunked so chunk ``k``'s psum overlaps chunk ``k+1``'s GEMM
  (communication hiding; exact).
* :class:`CompressedShardedExecutor` -- the completing psum runs through
  the int8 error-feedback collective, with per-mode residuals threaded
  through the sweep as carry state (communication compression;
  approximate but convergent).

``plan_sweep(executor="auto")`` picks among them by predicted cost; use
:func:`make_executor` to turn the chosen ``SweepPlan.executor`` kind into
an instance bound to a concrete mesh.
"""

from __future__ import annotations

from typing import Any, Protocol, Sequence, runtime_checkable

import jax

from repro.core.dimtree import partial_mttkrp_left, partial_mttkrp_right
from repro.core.mttkrp import mttkrp
from repro.dist.dist_mttkrp import (
    _dist_partial_left,
    _dist_partial_right,
    dist_mttkrp,
    dist_mttkrp_compressed,
    dist_mttkrp_overlapped,
    init_mttkrp_error_state,
    shard_problem,
)

from .cost import DEFAULT_OVERLAP_CHUNKS, EXECUTORS
from .planner import ModePlan
from .problem import Problem

Array = jax.Array


@runtime_checkable
class Executor(Protocol):
    """The four contractions an ALS sweep needs, placement included.

    Executors that carry state across MTTKRP calls (e.g. error-feedback
    residuals) additionally implement the optional carry extension --
    ``init_carry(problem, x, factors)`` and ``mttkrp_carry(x, factors, mp,
    carry) -> (m, carry)`` -- which the sweep engine threads through
    ``SweepState.carry`` when present (``hasattr`` duck typing; stateless
    executors skip both).
    """

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        """Place tensor + factors for this executor (identity when local)."""
        ...

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        """Full mode-``mp.mode`` MTTKRP with ``mp.algorithm``."""
        ...

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        """Dimension-tree ``T_L``: contract the trailing modes away."""
        ...

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        """Dimension-tree ``T_R``: contract the leading modes away."""
        ...


class LocalExecutor:
    """Single-device execution of the paper's shared-memory kernels."""

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        """No placement needed on one device: returns inputs unchanged."""
        return x, list(factors)

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        """Mode-``mp.mode`` MTTKRP via the planned local algorithm."""
        return mttkrp(x, list(factors), mp.mode, method=mp.algorithm)

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        """Local dimension-tree ``T_L`` (contract trailing modes)."""
        return partial_mttkrp_right(x, list(right_factors))

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        """Local dimension-tree ``T_R`` (contract leading modes)."""
        return partial_mttkrp_left(x, list(left_factors))


class ShardedExecutor:
    """Block-distributed execution over a device mesh.

    Holds the concrete ``Mesh`` + ``mode_axes`` mapping (the Problem only
    carries their sizes).  Every contraction is the local shared-memory
    kernel inside ``shard_map`` plus the minimal psum the mapping requires;
    the small Gram/pinv algebra stays at the global-array level in the
    engine, exactly as the previous hand-written distributed sweeps did.
    """

    def __init__(self, mesh, mode_axes):
        self.mesh = mesh
        self.mode_axes = dict(mode_axes)

    def prepare(self, problem: Problem, x: Array, factors: Sequence[Array]):
        """Block-distribute tensor + factors per ``mode_axes`` (no reorder)."""
        return shard_problem(x, factors, self.mode_axes, self.mesh)

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        """Local planned kernel per block + one psum over contracted axes."""
        return dist_mttkrp(
            x, list(factors), mp.mode, self.mode_axes, self.mesh, method=mp.algorithm
        )

    def partial_right(self, x: Array, right_factors: Sequence[Array]) -> Array:
        """Distributed dimension-tree ``T_L`` (psum over trailing-mode axes)."""
        return _dist_partial_right(x, list(right_factors), self.mode_axes, self.mesh)

    def partial_left(self, x: Array, left_factors: Sequence[Array]) -> Array:
        """Distributed dimension-tree ``T_R`` (psum over leading-mode axes)."""
        return _dist_partial_left(x, list(left_factors), self.mode_axes, self.mesh)


class OverlappingExecutor(ShardedExecutor):
    """Communication-hiding sharded executor (exact).

    Identical placement and results to :class:`ShardedExecutor`, but each
    mode's local MTTKRP is split into ``n_chunks`` row slabs so the psum of
    chunk ``k`` is issued while the GEMM of chunk ``k+1`` runs
    (:func:`repro.dist.dist_mttkrp.dist_mttkrp_overlapped`).  Chunk psums
    cover disjoint output rows, so the iterates match the plain sharded
    executor exactly; only the schedule changes.  The dimension-tree
    partials are inherited unchunked (ROADMAP).
    """

    def __init__(self, mesh, mode_axes, n_chunks: int = DEFAULT_OVERLAP_CHUNKS):
        super().__init__(mesh, mode_axes)
        self.n_chunks = int(n_chunks)

    def mttkrp(self, x: Array, factors: Sequence[Array], mp: ModePlan) -> Array:
        """Chunked local kernel with per-chunk psums (double-buffered)."""
        return dist_mttkrp_overlapped(
            x,
            list(factors),
            mp.mode,
            self.mode_axes,
            self.mesh,
            method=mp.algorithm,
            n_chunks=self.n_chunks,
        )


class CompressedShardedExecutor(ShardedExecutor):
    """Communication-compressing sharded executor (approximate, convergent).

    Runs the factor all-reduce of every mode through the int8
    error-feedback collective
    (:func:`repro.dist.dist_mttkrp.dist_mttkrp_compressed`): each device
    quantizes its partial MTTKRP plus its carried residual, all-gathers the
    int8 payloads, and dequant-sums locally.  The per-mode residuals are
    persistent sweep state -- created by :meth:`init_carry`, threaded
    through :meth:`mttkrp_carry` by the engine -- so the accumulated
    quantization error stays bounded by one int8 step and compressed CP-ALS
    converges to the exact fit.  Modes whose mapping needs no psum run the
    exact path.
    """

    def init_carry(
        self, problem: Problem, x: Array, factors: Sequence[Array]
    ) -> dict[int, Array]:
        """Zero per-mode error-feedback residuals, placed on the mesh."""
        return init_mttkrp_error_state(
            problem.shape, problem.rank, self.mode_axes, self.mesh
        )

    def mttkrp_carry(
        self, x: Array, factors: Sequence[Array], mp: ModePlan, carry: Any
    ) -> tuple[Array, Any]:
        """Compressed mode-``mp.mode`` MTTKRP; returns result + new carry."""
        n = mp.mode
        if carry is None or n not in carry:
            return self.mttkrp(x, factors, mp), carry
        m, new_err = dist_mttkrp_compressed(
            x, list(factors), n, self.mode_axes, self.mesh, carry[n],
            method=mp.algorithm,
        )
        return m, {**carry, n: new_err}


def make_executor(
    kind: str,
    mesh=None,
    mode_axes=None,
    *,
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
) -> Executor:
    """Instantiate the executor for a planner-chosen kind.

    ``kind`` is a ``SweepPlan.executor`` value (one of
    :data:`repro.plan.cost.EXECUTORS`); the sharded kinds need the concrete
    ``mesh`` + ``mode_axes``, which the Problem deliberately does not carry
    (plans are pure metadata).  ``n_chunks`` sizes the overlapping
    executor's psum pipeline.
    """
    if kind not in EXECUTORS:
        raise ValueError(f"unknown executor kind {kind!r} (choose from {EXECUTORS})")
    if kind == "local":
        return LocalExecutor()
    if mesh is None or mode_axes is None:
        raise ValueError(f"executor {kind!r} needs mesh and mode_axes")
    if kind == "sharded":
        return ShardedExecutor(mesh, mode_axes)
    if kind == "overlapping":
        return OverlappingExecutor(mesh, mode_axes, n_chunks=n_chunks)
    return CompressedShardedExecutor(mesh, mode_axes)
