"""Subpackage."""
