"""Sharded checkpointing: atomic, keep-k, async, elastic (mesh-resharding) restore.

Format: ``<dir>/step_<n>/arrays.npz`` (leaf path -> ndarray) +
``manifest.json`` (step, leaf paths, shapes, dtypes, save wall-time).  Writes
go to ``step_<n>.tmp`` and are ``os.replace``d on completion, so a crash
mid-save can never corrupt the latest checkpoint (restart-safety).

Elastic restore: arrays are saved as full logical tensors and re-placed with
``jax.device_put(x, NamedSharding(new_mesh, spec))`` on load, so a run may
resume on a different mesh shape (data-parallel width change, pod loss) --
the loader reshards transparently.  On a real multi-host fleet the same
manifest+leaf-path format extends to per-host shard files; the single-process
container writes one file.

Async: ``save_async`` snapshots to host memory (device_get) synchronously --
cheap -- and runs the file I/O on a daemon thread, overlapping with the next
training step.  ``wait()`` drains pending writes (called before exit and
before deleting old checkpoints).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = SEP.join(_path_str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---------- save ----------
    def save(self, step: int, tree: Any, extra: dict | None = None) -> str:
        arrays = _flatten(tree)
        return self._write(step, arrays, extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None) -> None:
        self.wait()
        arrays = _flatten(tree)  # snapshot now; IO later
        self._thread = threading.Thread(
            target=self._write, args=(step, arrays, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, arrays: dict[str, np.ndarray], extra: dict) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(v.shape) for k, v in arrays.items()},
            "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
            "saved_at": time.time(),
            **extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------- restore ----------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        step: int | None = None,
        *,
        mesh=None,
        specs: Any = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``mesh``+``specs`` (a PartitionSpec tree matching template) enable
        elastic restore: every leaf is placed with the *new* mesh's sharding
        regardless of the mesh shape at save time.
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        paths_and_leaves = jax.tree_util.tree_flatten_with_path(template)
        flat, treedef = paths_and_leaves
        spec_leaves = (
            treedef.flatten_up_to(specs) if specs is not None else [None] * len(flat)
        )
        leaves = []
        for (path_t, leaf), spec in zip(flat, spec_leaves):
            key = SEP.join(_path_str(p) for p in path_t)
            arr = data[key]
            if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
            if mesh is not None and spec is not None:
                from jax.sharding import NamedSharding

                arr = jax.device_put(arr, NamedSharding(mesh, spec))
            else:
                arr = jax.device_put(arr)
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            leaves.append(arr)
        return treedef.unflatten(leaves), manifest
