"""Bandwidth-reducing collectives: int8 all-reduce with error feedback.

``compressed_psum`` implements the standard EF-SGD compressor (Seide et
al. 1-bit SGD generalized to int8; Karimireddy et al. error feedback):
each participant quantizes ``value + residual`` to int8 with a private
per-tensor scale, the quantized tensors are summed across the axis, and
the local quantization error is carried into the next round.  The carried
residual keeps the *accumulated* compression error bounded by one
quantization step instead of growing with the step count, which is what
lets a compressed data-parallel trainer track the exact run.

Two call sites consume the compressor: the data-parallel gradient
exchange below, and the compressed factor all-reduce of
``dist_mttkrp.dist_mttkrp_compressed`` (the
``repro.plan.CompressedShardedExecutor`` path, which threads the
residuals through the ALS sweep as carry state).

``make_compressed_dp_step`` builds the data-parallel train step on top:
per-device grads inside ``shard_map``, compressed (or exact) mean over
the data axes, then the usual AdamW update on the synchronized grads.
Error-feedback state is explicitly per-device: leaves carry a leading
device axis sharded over the whole mesh, so each device round-trips its
own residual through the step like any other bit of training state (and
it checkpoints/restores with the same machinery).
"""

from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.launch import mesh as meshlib
from repro.train.optimizer import OptConfig, OptState, adamw_update

Array = jax.Array

_EPS = 1e-12  # guards the all-zero-tensor scale


def reduce_scatter(x: Array, axis_name, *, scatter_axis: int = 0) -> Array:
    """Tiled reduce-scatter of ``x`` over ``axis_name`` (inside shard_map).

    Each of the ``k`` participants ends up with the fully reduced
    ``1/k``-slice of ``x`` along ``scatter_axis`` -- the first half of a ring
    all-reduce, moving ``B (k-1)/k`` bytes per device.  This is the
    intra-node leg of :func:`hierarchical_psum`; ``x.shape[scatter_axis]``
    must be divisible by the axis size.
    """
    return jax.lax.psum_scatter(
        x, axis_name, scatter_dimension=scatter_axis, tiled=True
    )


def all_gather(x: Array, axis_name, *, gather_axis: int = 0) -> Array:
    """Tiled all-gather of ``x`` over ``axis_name`` (inside shard_map).

    Concatenates the participants' blocks along ``gather_axis`` on every
    device -- the second half of a ring all-reduce (``B (k-1)/k`` bytes per
    device), undoing :func:`reduce_scatter`'s split.
    """
    return jax.lax.all_gather(x, axis_name, axis=gather_axis, tiled=True)


def hierarchical_psum(
    x: Array, axes, mesh: Mesh, node_axis: str | None = None,
    *, scatter_axis: int = 0,
) -> Array:
    """Two-level ``psum`` over ``axes``: intra-node traffic on the fast links,
    only a ``1/k`` shard crossing the slow node boundary.

    Must be called inside ``shard_map``.  ``node_axis`` names the mesh axis
    spanning the devices *within* one node (the fast-ICI level); the
    remaining ``axes`` are taken to cross nodes (the slow-DCN level).  The
    decomposition is :func:`reduce_scatter` within ``node_axis`` along
    ``scatter_axis``, a plain ``psum`` of the scattered shard across the
    node-crossing axes, then :func:`all_gather` back within ``node_axis`` --
    so each device moves ``2 B (k-1)/k`` intra-node bytes but only
    ``2 (B/k)(m-1)/m`` inter-node bytes, a factor-``k`` cut of the volume on
    the slow level versus the flat ring (which pays the full ``2 B`` there).

    The result equals ``jax.lax.psum(x, axes)`` up to floating-point
    reduction order.  Falls back to the flat psum whenever the decomposition
    cannot apply: ``node_axis`` is ``None`` or not among ``axes``, it is the
    *only* reduced axis (no slow level to protect), its size is 1, or
    ``x.shape[scatter_axis]`` is not divisible by it.
    """
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    if node_axis is None or node_axis not in axes:
        return jax.lax.psum(x, axes)
    inter = tuple(a for a in axes if a != node_axis)
    k = int(mesh.shape[node_axis])
    if not inter or k <= 1 or int(x.shape[scatter_axis]) % k:
        return jax.lax.psum(x, axes)
    shard = reduce_scatter(x, node_axis, scatter_axis=scatter_axis)
    shard = jax.lax.psum(shard, inter)
    return all_gather(shard, node_axis, gather_axis=scatter_axis)


def compressed_psum(
    x: Array, axis_name, err: Array
) -> tuple[Array, Array]:
    """int8-quantized ``psum`` of ``x`` over ``axis_name`` with error feedback.

    Must be called inside ``shard_map``.  ``axis_name`` may be a single mesh
    axis or a tuple of axes (the gather then spans their product of devices,
    like ``psum`` over multiple axes).  ``err`` is this device's carried
    residual from the previous round (zeros initially, same shape as ``x``).
    Returns ``(sum, new_err)``: the all-reduced dequantized sum (every
    participant gets the same value) and the new local residual, bounded by
    half a quantization step (``max|x + err| / 254``).

    The collective itself is an all-gather of the int8 payloads plus one
    fp32 scale per sender (scales are private, so summation happens on the
    receiver after dequantization) -- the wire moves 1/4 the bytes of an
    fp32 all-reduce, at the cost of an ``(n_participants, *x.shape)`` int8
    gather buffer per tensor on each device.
    """
    val = x.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(val)) / 127.0, _EPS)
    q = jnp.round(val / scale).astype(jnp.int8)  # |val|/scale <= 127 by scale
    new_err = val - q.astype(jnp.float32) * scale
    qs = jax.lax.all_gather(q, axis_name)  # (n, ...) int8 on the wire
    scales = jax.lax.all_gather(scale, axis_name)  # (n,) fp32
    total = jnp.einsum("n...,n->...", qs.astype(jnp.float32), scales)
    return total.astype(x.dtype), new_err


def init_error_state(
    params: Any, mesh: Mesh | None = None, *, n_shards: int | None = None
) -> Any:
    """Zero error-feedback residuals: one fp32 copy of ``params`` per device.

    Leaves have shape ``(n, *param.shape)`` with the leading axis sharded
    over the full mesh inside the compressed step.  ``n`` is the mesh size
    when ``mesh`` is given, else ``n_shards``, else ``jax.device_count()``
    (correct when the step's mesh spans every device; pass the mesh for
    sub-meshes -- the step validates the match either way).
    """
    if n_shards is not None:
        n = int(n_shards)
    elif mesh is not None:
        n = math.prod(mesh.shape.values())
    else:
        n = jax.device_count()
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + tuple(p.shape), jnp.float32), params
    )


def make_compressed_dp_step(
    model, opt_cfg: OptConfig, mesh: Mesh, *, compress: bool = True
) -> Callable:
    """Data-parallel train step with int8+error-feedback gradient exchange.

    Returns ``step(params, opt_state, err, batch) -> (params, opt_state,
    new_err, metrics)``.  ``compress=False`` swaps the quantized all-reduce
    for an exact ``pmean`` (same code path otherwise), which is the
    baseline the compressed run is validated against in tests.
    """
    axes = tuple(mesh.axis_names)
    dp = meshlib.dp_axes(mesh)
    dspec = meshlib.dp_spec_entry(mesh)
    dp_size = math.prod(mesh.shape[a] for a in dp)

    mesh_size = math.prod(mesh.shape.values())

    def step(params: Any, opt_state: OptState, err: Any, batch: dict):
        for e in jax.tree.leaves(err):
            if e.shape[0] != mesh_size:
                raise ValueError(
                    f"error-state leading dim {e.shape[0]} != mesh size "
                    f"{mesh_size}; build it with init_error_state(params, mesh)"
                )

        def local_fn(params, err_blk, batch_blk):
            err_loc = jax.tree.map(lambda e: e[0], err_blk)
            with meshlib.manual_mode():
                (loss, metrics), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True
                )(params, batch_blk)
            if compress:
                flat_g, tdef = jax.tree.flatten(grads)
                flat_e = tdef.flatten_up_to(err_loc)
                summed = [compressed_psum(g, dp, e) for g, e in zip(flat_g, flat_e)]
                grads = tdef.unflatten([s / dp_size for s, _ in summed])
                err_loc = tdef.unflatten([e for _, e in summed])
            else:
                grads = jax.lax.pmean(grads, dp)
            loss = jax.lax.pmean(loss, dp)
            metrics = jax.lax.pmean(metrics, dp)
            new_err = jax.tree.map(lambda e: e[None], err_loc)
            return grads, new_err, loss, metrics

        grads, new_err, loss, metrics = compat.shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(), P(axes), P(dspec)),
            out_specs=(P(), P(axes), P(), P()),
            check_vma=False,
        )(params, err, batch)
        params, opt_state, opt_stats = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_stats)
        metrics["loss"] = loss
        return params, opt_state, new_err, metrics

    return step
