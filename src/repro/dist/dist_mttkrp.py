"""Block-distributed MTTKRP and CP-ALS over a device mesh.

The paper's shared-memory parallelization assigns contiguous row blocks of
the (never-materialized) matricization to threads; the distributed-memory
port assigns contiguous *index blocks of the tensor modes* to devices.  A
``mode_axes`` mapping ``{mode: mesh_axis}`` places the dense tensor on an
N-D grid without reordering a single entry -- the defining constraint of
the paper, kept under sharding: every device holds a natural row-major
subtensor (a block of each mapped mode, all of each unmapped mode), and
each factor ``U_k`` is row-distributed over the axis of its mode (or
replicated when mode ``k`` is unmapped).

Per-mode-n MTTKRP then factors exactly as in Ballard/Knight/Rouse's
communication lower-bound analysis:

  * each device runs the *local* shared-memory kernel
    (:func:`repro.core.mttkrp.mttkrp`, 1-step or 2-step) on its block with
    its local factor rows -- a partial sum over the mapped modes != n;
  * one ``psum`` over the mesh axes mapped to modes != n completes the
    contraction (the minimal all-reduce the mode->axis mapping requires);
  * no collective touches the axis mapped to mode ``n`` itself: the output
    rows stay distributed over it, exactly like the factor they update.

``dist_cp_als`` / ``dist_dimtree_sweep`` wrap this into sharded ALS
drivers that match the single-device ``cp_als`` / ``als_sweep`` iterates
numerically (same update algebra; only the reduction order differs).
All sweeps route through the single engine in :mod:`repro.plan.sweep`
(``ShardedExecutor`` wraps the shard_map + psum placement below); this
module keeps the placement primitives and the back-compat entry points.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.dimtree import contract_from_partial, partial_mttkrp_range
from repro.core.mttkrp import Method, mttkrp, mttkrp_batched
from repro.core.tensor_ops import mode_letters

from .collectives import compressed_psum, hierarchical_psum

Array = jax.Array
ModeAxes = Mapping[int, str]

# Collective strategies the node psum can complete with: "flat" is the plain
# single-level psum; "hierarchical" is the two-level decomposition of
# repro.dist.collectives.hierarchical_psum (reduce-scatter within the node
# axis, cross-node psum of the shard, all-gather back).
COLLECTIVES = ("flat", "hierarchical")


def _validate_collective(collective: str) -> None:
    if collective not in COLLECTIVES:
        raise ValueError(
            f"unknown collective {collective!r} (choose from {COLLECTIVES})"
        )


def _node_psum(
    m: Array,
    reduce_axes: tuple[str, ...],
    mesh: Mesh,
    collective: str,
    node_axis: str | None,
    *,
    scatter_axis: int = 0,
) -> Array:
    """Complete one node contraction's reduction over ``reduce_axes``.

    ``collective="hierarchical"`` routes through
    :func:`repro.dist.collectives.hierarchical_psum` with ``node_axis`` as
    the intra-node level (falling back to the flat psum whenever the
    decomposition cannot apply); ``"flat"`` is the classic single psum.
    """
    _validate_collective(collective)
    if collective == "hierarchical":
        return hierarchical_psum(
            m, reduce_axes, mesh, node_axis=node_axis, scatter_axis=scatter_axis
        )
    return jax.lax.psum(m, reduce_axes)

# default chunk count of the overlapped psum pipeline; the canonical knob
# the planner uses is repro.plan.cost.DEFAULT_OVERLAP_CHUNKS (same value --
# kept as a plain literal here so repro.dist never imports repro.plan at
# module level).
DEFAULT_OVERLAP_CHUNKS = 4


def _validate(shape: Sequence[int], mode_axes: ModeAxes, mesh: Mesh) -> None:
    seen: dict[str, int] = {}
    for mode, axis in mode_axes.items():
        if not 0 <= mode < len(shape):
            raise ValueError(f"mode {mode} out of range for order-{len(shape)} tensor")
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {axis!r} (axes: {mesh.axis_names})")
        if axis in seen:
            raise ValueError(f"mesh axis {axis!r} mapped to modes {seen[axis]} and {mode}")
        seen[axis] = mode
        if shape[mode] % mesh.shape[axis]:
            raise ValueError(
                f"mode {mode} dim {shape[mode]} not divisible by "
                f"axis {axis!r} size {mesh.shape[axis]}"
            )


def _batch_entry(batch_axes: Sequence[str]):
    """PartitionSpec entry of a leading batch axis (``None`` = replicated)."""
    axes = tuple(batch_axes)
    return axes if axes else None


def _validate_batch(
    batch: int, batch_axes: Sequence[str], mode_axes: ModeAxes, mesh: Mesh
) -> None:
    used = set(mode_axes.values())
    seen: set[str] = set()
    shards = 1
    for axis in batch_axes:
        if axis not in mesh.shape:
            raise ValueError(f"mesh has no axis {axis!r} (axes: {mesh.axis_names})")
        if axis in used:
            raise ValueError(
                f"mesh axis {axis!r} cannot shard both a mode and the batch"
            )
        if axis in seen:
            raise ValueError(f"duplicate batch axis {axis!r}")
        seen.add(axis)
        shards *= mesh.shape[axis]
    if batch % shards:
        raise ValueError(
            f"batch {batch} not divisible by batch-axis product {shards}"
        )


def _x_spec(
    ndim: int,
    mode_axes: ModeAxes,
    *,
    batched: bool = False,
    batch_axes: Sequence[str] = (),
) -> P:
    dims = [mode_axes.get(k) for k in range(ndim)]
    if batched:
        return P(_batch_entry(batch_axes), *dims)
    return P(*dims)


def _factor_specs(
    ndim: int,
    mode_axes: ModeAxes,
    *,
    batched: bool = False,
    batch_axes: Sequence[str] = (),
) -> list[P]:
    if batched:
        entry = _batch_entry(batch_axes)
        return [P(entry, mode_axes.get(k), None) for k in range(ndim)]
    return [P(mode_axes.get(k), None) for k in range(ndim)]


def _reduce_axes(mode_axes: ModeAxes, keep_modes: Sequence[int]) -> tuple[str, ...]:
    """Mesh axes whose modes are contracted away (i.e. not in ``keep_modes``)."""
    keep = set(keep_modes)
    return tuple(mode_axes[m] for m in sorted(mode_axes) if m not in keep)


def shard_problem(
    x: Array,
    factors: Sequence[Array],
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    batch_axes: Sequence[str] = (),
) -> tuple[Array, list[Array]]:
    """Place tensor + factors on ``mesh`` per ``mode_axes``; no reordering.

    The tensor is block-distributed: device ``(i, j, ...)`` holds the
    row-major subtensor of its index block along each mapped mode (a plain
    ``device_put`` with a NamedSharding -- entries within each block keep
    their natural layout, so the local kernels see exactly the layout the
    paper's algorithms assume).  Factor ``U_k`` is row-sharded over
    ``mode_axes[k]`` when mapped, replicated otherwise.

    A *batched* problem (``x.ndim == len(factors) + 1``: one leading batch
    axis on the tensor and on every factor) is block-distributed along the
    batch over ``batch_axes`` -- each device holds whole problems, so no
    contraction ever needs a collective across the batch.
    """
    batched = x.ndim == len(factors) + 1
    shape = x.shape[1:] if batched else x.shape
    _validate(shape, mode_axes, mesh)
    if batched:
        _validate_batch(x.shape[0], batch_axes, mode_axes, mesh)
    order = len(shape)
    xs = jax.device_put(
        x,
        NamedSharding(
            mesh, _x_spec(order, mode_axes, batched=batched, batch_axes=batch_axes)
        ),
    )
    fs = [
        jax.device_put(u, NamedSharding(mesh, spec))
        for u, spec in zip(
            factors,
            _factor_specs(order, mode_axes, batched=batched, batch_axes=batch_axes),
        )
    ]
    return xs, fs


def dist_mttkrp(
    x: Array,
    factors: Sequence[Array],
    n: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    method: Method = "auto",
    tiles: Mapping[str, int] | None = None,
    *,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> Array:
    """Mode-``n`` MTTKRP of a block-distributed tensor.

    Local shared-memory kernel inside ``shard_map`` + the minimal ``psum``:
    only over axes mapped to contracted modes (``tiles`` threads the tuned
    Pallas tiling into the local kernel for kernel-backed methods).  The
    result is distributed
    over ``mode_axes[n]`` (replicated if mode ``n`` is unmapped) -- the
    sharding of the factor it updates in ALS.

    ``collective="hierarchical"`` completes the reduction with
    :func:`repro.dist.collectives.hierarchical_psum` instead of the flat
    psum: reduce-scatter within ``node_axis`` (the intra-node mesh axis),
    cross-node psum of the ``1/k`` shard, all-gather back -- same value up
    to summation order, a factor-``k`` less volume on the slow level.

    When ``x`` carries a leading batch axis (``x.ndim == len(factors) + 1``),
    the batch is sharded over ``batch_axes`` and each device runs the
    batched local kernel on its slab of whole problems; the psum pattern is
    untouched -- batch axes are never reduced (problems are independent),
    which is exactly why batch-parallel placement costs zero reduce traffic.
    """
    _validate_collective(collective)
    batched = x.ndim == len(factors) + 1
    shape = x.shape[1:] if batched else x.shape
    _validate(shape, mode_axes, mesh)
    if batched:
        _validate_batch(x.shape[0], batch_axes, mode_axes, mesh)
    reduce_axes = _reduce_axes(mode_axes, keep_modes=(n,))
    order = len(shape)
    lead = 1 if batched else 0
    entry = _batch_entry(batch_axes)

    def local_fn(x_blk, *f_blks):
        if batched:
            m = mttkrp_batched(x_blk, list(f_blks), n, method=method, tiles=tiles)
        else:
            m = mttkrp(x_blk, list(f_blks), n, method=method, tiles=tiles)
        if reduce_axes:
            m = _node_psum(
                m, reduce_axes, mesh, collective, node_axis, scatter_axis=lead
            )
        return m

    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            _x_spec(order, mode_axes, batched=batched, batch_axes=batch_axes),
            *_factor_specs(order, mode_axes, batched=batched, batch_axes=batch_axes),
        ),
        out_specs=(
            P(entry, mode_axes.get(n), None) if batched else P(mode_axes.get(n), None)
        ),
        check_vma=False,
    )
    return fn(x, *factors)


def dist_pp_pairs(
    x: Array,
    factors: Sequence[Array],
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    batch_axes: Sequence[str] = (),
) -> dict[tuple[int, int], Array]:
    """All pairwise-perturbation intermediates of a block-distributed tensor.

    For every mode pair ``n < m`` the intermediate
    ``M_nm[c, i_n, i_m] = sum X * prod_{k not in {n,m}} U_k[i_k, c]``
    gets exactly the full MTTKRP's treatment with two kept modes instead of
    one: local einsum per device block inside ``shard_map``, then one psum
    over the axes mapped to the contracted modes only -- the kept modes'
    axes carry the output rows/columns, so no collective ever touches them
    (the sharding the PP corrections later consume matches the factors they
    perturb).  A leading batch axis (``x.ndim == len(factors) + 1``) is
    sharded over ``batch_axes`` and never reduced.  Returns ``{(n, m):
    M_nm}`` in the rank-major layout of :class:`repro.plan.schedule.PPPair`
    -- global shapes ``(C, I_n, I_m)`` (batch-led when batched).
    """
    batched = x.ndim == len(factors) + 1
    shape = x.shape[1:] if batched else x.shape
    _validate(shape, mode_axes, mesh)
    if batched:
        _validate_batch(x.shape[0], batch_axes, mode_axes, mesh)
    order = len(shape)
    entry = _batch_entry(batch_axes)
    letters = mode_letters(order)
    out: dict[tuple[int, int], Array] = {}
    for n in range(order):
        for m in range(n + 1, order):
            others = [k for k in range(order) if k not in (n, m)]
            spec = (
                ",".join(
                    ["..." + letters] + ["..." + letters[k] + "c" for k in others]
                )
                + "->..." + letters[n] + letters[m] + "c"
            )
            reduce_axes = _reduce_axes(mode_axes, keep_modes=(n, m))

            def local_fn(x_blk, *f_blks, spec=spec, reduce_axes=reduce_axes):
                # rank-last einsum (the GEMM-friendly orientation), then
                # rank to the front for the PPPair storage layout
                p = jnp.moveaxis(jnp.einsum(spec, x_blk, *f_blks), -1, -3)
                if reduce_axes:
                    p = jax.lax.psum(p, reduce_axes)
                return p

            if batched:
                f_specs = [P(entry, mode_axes.get(k), None) for k in others]
                out_spec = P(entry, None, mode_axes.get(n), mode_axes.get(m))
            else:
                f_specs = [P(mode_axes.get(k), None) for k in others]
                out_spec = P(None, mode_axes.get(n), mode_axes.get(m))
            fn = compat.shard_map(
                local_fn,
                mesh=mesh,
                in_specs=(
                    _x_spec(order, mode_axes, batched=batched, batch_axes=batch_axes),
                    *f_specs,
                ),
                out_specs=out_spec,
                check_vma=False,
            )
            out[(n, m)] = fn(x, *[factors[k] for k in others])
    return out


def _chunk_bounds(extent: int, n_chunks: int) -> list[int]:
    """Split ``[0, extent)`` into ``<= n_chunks`` near-equal static slices."""
    k = max(1, min(int(n_chunks), int(extent)))
    sizes = [extent // k + (1 if i < extent % k else 0) for i in range(k)]
    bounds = [0]
    for s in sizes:
        bounds.append(bounds[-1] + s)
    return bounds


def dist_mttkrp_overlapped(
    x: Array,
    factors: Sequence[Array],
    n: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    method: Method = "auto",
    n_chunks: int = DEFAULT_OVERLAP_CHUNKS,
    tiles: Mapping[str, int] | None = None,
    *,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> Array:
    """Mode-``n`` MTTKRP with the completing psum hidden behind the GEMMs.

    Identical placement and *bitwise-identical per-element reduction* to
    :func:`dist_mttkrp`, but the local block is split into ``n_chunks``
    row slabs along mode ``n`` and each slab's psum is issued as soon as
    its local MTTKRP finishes -- a double-buffered pipeline: the collective
    of chunk ``k`` has no data dependency on the GEMM of chunk ``k+1``, so
    XLA's latency-hiding scheduler runs them concurrently and only the
    first GEMM and the last psum stay exposed (the ``1/n_chunks``
    serialization fraction of the cost model).  Chunk psums touch disjoint
    output rows, so concatenating them equals the unchunked psum exactly.

    Falls back to :func:`dist_mttkrp` when the mapping requires no
    collective (nothing to hide) or ``n_chunks <= 1``.  Batched tensors
    (leading batch axis, sharded over ``batch_axes``) chunk along mode
    ``n`` of every problem in the local slab -- the slab axis shifts by one
    but the pipeline structure is identical.

    ``collective="hierarchical"`` completes each slab's reduction with the
    two-level psum (slabs whose row count the ``node_axis`` size does not
    divide fall back to the flat psum individually -- still exact).
    """
    _validate_collective(collective)
    batched = x.ndim == len(factors) + 1
    shape = x.shape[1:] if batched else x.shape
    _validate(shape, mode_axes, mesh)
    reduce_axes = _reduce_axes(mode_axes, keep_modes=(n,))
    local_in = shape[n] // (mesh.shape[mode_axes[n]] if n in mode_axes else 1)
    if not reduce_axes or n_chunks <= 1 or local_in <= 1:
        return dist_mttkrp(
            x, factors, n, mode_axes, mesh,
            method=method, tiles=tiles, batch_axes=batch_axes,
            collective=collective, node_axis=node_axis,
        )
    if batched:
        _validate_batch(x.shape[0], batch_axes, mode_axes, mesh)
    bounds = _chunk_bounds(local_in, n_chunks)
    order = len(shape)
    lead = 1 if batched else 0
    entry = _batch_entry(batch_axes)

    def local_one(x_slab, f_blks):
        if batched:
            return mttkrp_batched(x_slab, list(f_blks), n, method=method, tiles=tiles)
        return mttkrp(x_slab, list(f_blks), n, method=method, tiles=tiles)

    def local_fn(x_blk, *f_blks):
        # issue order GEMM_0, (GEMM_1, psum_0), (GEMM_2, psum_1), ...: each
        # psum depends only on its own slab's GEMM, never on the next one.
        partials = [
            local_one(jax.lax.slice_in_dim(x_blk, i0, i1, axis=n + lead), f_blks)
            for i0, i1 in zip(bounds[:-1], bounds[1:])
        ]
        reduced = [
            _node_psum(
                p, reduce_axes, mesh, collective, node_axis, scatter_axis=lead
            )
            for p in partials
        ]
        return jnp.concatenate(reduced, axis=lead)

    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            _x_spec(order, mode_axes, batched=batched, batch_axes=batch_axes),
            *_factor_specs(order, mode_axes, batched=batched, batch_axes=batch_axes),
        ),
        out_specs=(
            P(entry, mode_axes.get(n), None) if batched else P(mode_axes.get(n), None)
        ),
        check_vma=False,
    )
    return fn(x, *factors)


def init_mttkrp_error_state(
    shape: Sequence[int], rank: int, mode_axes: ModeAxes, mesh: Mesh
) -> dict[int, Array]:
    """Zero error-feedback residuals for the compressed factor all-reduce.

    One fp32 array per mode whose MTTKRP needs a psum (mapped modes other
    than the mode itself exist).  Every participating device carries its own
    residual: the global array for mode ``n`` has one leading axis per
    reduced mesh axis (sharded over that axis) followed by the ``(I_n, C)``
    output dims sharded like the factor the MTTKRP updates.  Thread the dict
    through :func:`dist_mttkrp_compressed` calls; it is ordinary sweep state
    (checkpointable, donate-able) exactly like the residuals of
    ``make_compressed_dp_step``.
    """
    _validate(shape, mode_axes, mesh)
    errs: dict[int, Array] = {}
    for n in range(len(shape)):
        reduce_axes = _reduce_axes(mode_axes, keep_modes=(n,))
        if not reduce_axes:
            continue
        lead = tuple(mesh.shape[a] for a in reduce_axes)
        e = jnp.zeros(lead + (shape[n], rank), jnp.float32)
        spec = P(*reduce_axes, mode_axes.get(n), None)
        errs[n] = jax.device_put(e, NamedSharding(mesh, spec))
    return errs


def dist_mttkrp_compressed(
    x: Array,
    factors: Sequence[Array],
    n: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    err: Array,
    method: Method = "auto",
    tiles: Mapping[str, int] | None = None,
    *,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> tuple[Array, Array]:
    """Mode-``n`` MTTKRP completed by the int8 error-feedback collective.

    Same local kernel and placement as :func:`dist_mttkrp`, but the
    completing fp32 psum is replaced by
    :func:`repro.dist.collectives.compressed_psum` over the same mesh axes:
    each device quantizes ``partial + err`` to int8 with a private scale,
    all-gathers the payloads, and dequant-sums locally.  ``err`` is this
    mode's entry of :func:`init_mttkrp_error_state`; returns ``(result,
    new_err)``.  The carried residual keeps the accumulated quantization
    error bounded by one int8 step, which is what lets compressed CP-ALS
    track the exact fit across sweeps.

    Batched tensors thread a batched residual (global layout: reduce-axis
    leads, then the batch axis, then the output dims); the quantize /
    all-gather / dequant path is shape-agnostic, so nothing else changes.

    ``collective="hierarchical"`` splits the levels around the compressor:
    the ``node_axis`` (intra-node) reduction runs as an *exact* psum on the
    fast links first, then only the cross-node exchange is quantized --
    every device in a node compresses the identical node-sum, so the
    residual layout and carry semantics are unchanged while the int8 wire
    traffic spans ``m`` nodes instead of ``k * m`` devices.
    """
    _validate_collective(collective)
    batched = x.ndim == len(factors) + 1
    shape = x.shape[1:] if batched else x.shape
    _validate(shape, mode_axes, mesh)
    reduce_axes = _reduce_axes(mode_axes, keep_modes=(n,))
    if not reduce_axes:
        out = dist_mttkrp(
            x, factors, n, mode_axes, mesh,
            method=method, tiles=tiles, batch_axes=batch_axes,
        )
        return out, err
    if batched:
        _validate_batch(x.shape[0], batch_axes, mode_axes, mesh)
    order = len(shape)
    entry = _batch_entry(batch_axes)
    intra_first = (
        collective == "hierarchical"
        and node_axis in reduce_axes
        and len(reduce_axes) > 1
    )
    gather_axes = (
        tuple(a for a in reduce_axes if a != node_axis)
        if intra_first
        else reduce_axes
    )
    if batched:
        err_spec = P(*reduce_axes, entry, mode_axes.get(n), None)
        out_spec = P(entry, mode_axes.get(n), None)
    else:
        err_spec = P(*reduce_axes, mode_axes.get(n), None)
        out_spec = P(mode_axes.get(n), None)

    def local_fn(x_blk, err_blk, *f_blks):
        if batched:
            m = mttkrp_batched(x_blk, list(f_blks), n, method=method, tiles=tiles)
        else:
            m = mttkrp(x_blk, list(f_blks), n, method=method, tiles=tiles)
        if intra_first:
            m = jax.lax.psum(m, (node_axis,))
        total, new_e = compressed_psum(m, gather_axes, err_blk.reshape(m.shape))
        return total, new_e.reshape(err_blk.shape)

    fn = compat.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            _x_spec(order, mode_axes, batched=batched, batch_axes=batch_axes),
            err_spec,
            *_factor_specs(order, mode_axes, batched=batched, batch_axes=batch_axes),
        ),
        out_specs=(out_spec, err_spec),
        check_vma=False,
    )
    return fn(x, err, *factors)


# --------------------------------------------------------------------------
# Per-node contractions of a general dimension-tree schedule.  Every node of
# repro.plan.schedule is one of two shapes -- a range contraction of the raw
# tensor, or a further contraction of an already-complete partial tensor --
# and each gets the same treatment as the full MTTKRP: local kernel inside
# shard_map + the minimal psum over the axes mapped to the modes contracted
# AT THAT NODE (parents were already reduced when they were built).  The
# overlapped variants chunk along the node's leading kept mode so each
# slab's psum hides behind the next slab's contraction; the compressed
# variants run the node psum through the int8 error-feedback collective.
# --------------------------------------------------------------------------
def _node_reduce_axes(mode_axes: ModeAxes, contracted: Sequence[int]) -> tuple[str, ...]:
    """Mesh axes of the mapped modes contracted at one node, in mode order."""
    want = set(contracted)
    return tuple(mode_axes[m] for m in sorted(mode_axes) if m in want)


def _dist_contract(
    src: Array,
    factors: Sequence[Array],
    lo: int,
    hi: int,
    parent_lo: int,
    parent_hi: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    from_root: bool,
    n_chunks: int = 1,
    err: Array | None = None,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
):
    """Shared core of the four per-node contraction entry points.

    Derives the node's (contracted modes, reduce axes, specs) once, runs
    the matching local contraction -- :func:`partial_mttkrp_range` off the
    raw tensor, :func:`contract_from_partial` off a partial -- and
    completes it with this node's collective: per-slab psums along mode
    ``lo`` when exact (``err is None``), the int8 error-feedback
    ``compressed_psum`` otherwise.  ``collective="hierarchical"`` swaps
    each exact psum for the two-level decomposition around ``node_axis``
    (reduce-scatter / cross-node psum / all-gather); the compressed path
    runs the intra-node level as an exact psum first and quantizes only
    the cross-node exchange.

    Batchedness is inferred from ``src.ndim`` (one extra leading axis over
    the unbatched shape for the node's topology); the local contraction is
    then vmapped over the device's batch slab and every spec -- source,
    factors, residual, output -- gains a leading ``batch_axes`` entry.
    Batch axes never appear in ``reduce_axes``: problems are independent.
    """
    _validate_collective(collective)
    order = parent_hi - parent_lo
    expected = order if from_root else order + 1
    batched = src.ndim == expected + 1
    lead = 1 if batched else 0
    if batched:
        _validate_batch(src.shape[0], batch_axes, mode_axes, mesh)
    entry = _batch_entry(batch_axes)
    contracted = [m for m in range(parent_lo, parent_hi) if not lo <= m < hi]
    reduce_axes = _node_reduce_axes(mode_axes, contracted)
    keep_axes = [mode_axes.get(k) for k in range(lo, hi)]
    if batched:
        f_specs = [P(entry, mode_axes.get(m), None) for m in contracted]
        src_spec = (
            _x_spec(order, mode_axes, batched=True, batch_axes=batch_axes)
            if from_root
            else P(entry, *[mode_axes.get(k) for k in range(parent_lo, parent_hi)], None)
        )
        out_spec = P(entry, *keep_axes, None)
        err_spec = P(*reduce_axes, entry, *keep_axes, None)
    else:
        f_specs = [P(mode_axes.get(m), None) for m in contracted]
        src_spec = (
            _x_spec(order, mode_axes)
            if from_root
            else P(*[mode_axes.get(k) for k in range(parent_lo, parent_hi)], None)
        )
        out_spec = P(*keep_axes, None)
        err_spec = P(*reduce_axes, *keep_axes, None)
    lo_local = src.shape[lead + lo - parent_lo] // (
        mesh.shape[mode_axes[lo]] if lo in mode_axes else 1
    )
    chunks = max(1, min(int(n_chunks), lo_local)) if reduce_axes else 1
    bounds = _chunk_bounds(lo_local, chunks)

    def contract_local(src_blk, cf):
        if from_root:
            def one(t, *fs):
                fl = list(fs[:lo]) + [None] * (hi - lo) + list(fs[lo:])
                return partial_mttkrp_range(t, fl, lo, hi)
        else:
            def one(t, *fs):
                return contract_from_partial(
                    t, dict(zip(contracted, fs)), lo, hi, parent_lo
                )
        if batched:
            return jax.vmap(one)(src_blk, *cf)
        return one(src_blk, *cf)

    intra_first = (
        collective == "hierarchical"
        and node_axis in reduce_axes
        and len(reduce_axes) > 1
    )
    gather_axes = (
        tuple(a for a in reduce_axes if a != node_axis)
        if intra_first
        else reduce_axes
    )

    def local_exact(src_blk, *cf):
        out = contract_local(src_blk, cf)
        if not reduce_axes:
            return out
        # slab axis = mode lo of the node output (shifted past the batch)
        slabs = [
            _node_psum(
                jax.lax.slice_in_dim(out, i0, i1, axis=lead),
                reduce_axes, mesh, collective, node_axis, scatter_axis=lead,
            )
            for i0, i1 in zip(bounds[:-1], bounds[1:])
        ]
        return slabs[0] if len(slabs) == 1 else jnp.concatenate(slabs, axis=lead)

    def local_compressed(src_blk, err_blk, *cf):
        out = contract_local(src_blk, cf)
        if intra_first:
            out = jax.lax.psum(out, (node_axis,))
        total, new_e = compressed_psum(out, gather_axes, err_blk.reshape(out.shape))
        return total, new_e.reshape(err_blk.shape)

    contracted_factors = [factors[m] for m in contracted]
    if err is None:
        fn = compat.shard_map(
            local_exact,
            mesh=mesh,
            in_specs=(src_spec, *f_specs),
            out_specs=out_spec,
            check_vma=False,
        )
        return fn(src, *contracted_factors)
    fn = compat.shard_map(
        local_compressed,
        mesh=mesh,
        in_specs=(src_spec, err_spec, *f_specs),
        out_specs=(out_spec, err_spec),
        check_vma=False,
    )
    return fn(src, err, *contracted_factors)


def dist_contract_range(
    x: Array,
    factors: Sequence[Array],
    lo: int,
    hi: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    n_chunks: int = 1,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> Array:
    """Distributed range contraction: every mode outside ``[lo, hi)`` of the
    block-distributed tensor is contracted with its (row-sharded) factor.

    Local :func:`repro.core.dimtree.partial_mttkrp_range` per block + one
    psum over the axes mapped to the contracted modes; the partial tensor
    stays distributed over the axes of its surviving modes.  ``n_chunks > 1``
    splits the node's collective into per-slab psums along mode ``lo`` (the
    leading kept mode): slab ``k``'s wire time has no data dependency on
    anything but its own rows, so XLA's latency-hiding scheduler runs the
    later slabs under whatever compute follows.  Slab psums are elementwise
    reductions over disjoint rows of the same local result, so the output is
    *bitwise identical* to the unchunked path by construction.
    """
    order = len(factors)
    _validate(x.shape[1:] if x.ndim == order + 1 else x.shape, mode_axes, mesh)
    return _dist_contract(
        x, factors, lo, hi, 0, order, mode_axes, mesh,
        from_root=True, n_chunks=n_chunks, batch_axes=batch_axes,
        collective=collective, node_axis=node_axis,
    )


def dist_contract_partial(
    t: Array,
    factors: Sequence[Array],
    lo: int,
    hi: int,
    parent_lo: int,
    parent_hi: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    n_chunks: int = 1,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> Array:
    """Distributed partial-to-partial contraction of one schedule node.

    ``t`` is an already-complete partial tensor carrying modes
    ``[parent_lo, parent_hi)`` plus the rank axis, distributed over those
    modes' axes; the modes outside ``[lo, hi)`` are contracted with their
    row-sharded factors (a multi-TTV, the rank axis shared Hadamard-style).
    The local contraction sums only each device's index block of the
    contracted modes, so one psum over those modes' axes completes it --
    the per-node analogue of the full MTTKRP's minimal collective.  With a
    single kept mode this IS the leaf update off a partial.  ``n_chunks``
    splits the psum into per-slab collectives along mode ``lo`` exactly as
    in :func:`dist_contract_range` -- bitwise identical by construction.
    """
    return _dist_contract(
        t, factors, lo, hi, parent_lo, parent_hi, mode_axes, mesh,
        from_root=False, n_chunks=n_chunks, batch_axes=batch_axes,
        collective=collective, node_axis=node_axis,
    )


def dist_contract_range_compressed(
    x: Array,
    factors: Sequence[Array],
    lo: int,
    hi: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    err: Array,
    *,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> tuple[Array, Array]:
    """:func:`dist_contract_range` with the node psum compressed.

    The completing fp32 psum of the partial tensor runs through
    :func:`repro.dist.collectives.compressed_psum` over the same axes, with
    ``err`` the node's persistent error-feedback residual (see
    :func:`init_mttkrp_error_state` for the layout convention); returns
    ``(partial, new_err)``.  Falls back to the exact path when the node
    needs no collective.
    """
    order = len(factors)
    _validate(x.shape[1:] if x.ndim == order + 1 else x.shape, mode_axes, mesh)
    contracted = [m for m in range(order) if not lo <= m < hi]
    if not _node_reduce_axes(mode_axes, contracted):
        return (
            dist_contract_range(
                x, factors, lo, hi, mode_axes, mesh, batch_axes=batch_axes
            ),
            err,
        )
    return _dist_contract(
        x, factors, lo, hi, 0, order, mode_axes, mesh,
        from_root=True, err=err, batch_axes=batch_axes,
        collective=collective, node_axis=node_axis,
    )


def dist_contract_partial_compressed(
    t: Array,
    factors: Sequence[Array],
    lo: int,
    hi: int,
    parent_lo: int,
    parent_hi: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    err: Array,
    *,
    batch_axes: Sequence[str] = (),
    collective: str = "flat",
    node_axis: str | None = None,
) -> tuple[Array, Array]:
    """:func:`dist_contract_partial` with the node psum compressed.

    Same placement, but the node's completing collective is the int8
    error-feedback gather with ``err`` as this node's persistent residual;
    returns ``(result, new_err)``.  Exact path when no collective is needed.
    """
    contracted = [m for m in range(parent_lo, parent_hi) if not lo <= m < hi]
    if not _node_reduce_axes(mode_axes, contracted):
        return (
            dist_contract_partial(
                t, factors, lo, hi, parent_lo, parent_hi, mode_axes, mesh,
                batch_axes=batch_axes,
            ),
            err,
        )
    return _dist_contract(
        t, factors, lo, hi, parent_lo, parent_hi, mode_axes, mesh,
        from_root=False, err=err, batch_axes=batch_axes,
        collective=collective, node_axis=node_axis,
    )


# --------------------------------------------------------------------------
# Sharded ALS sweeps.  Only the X-sized contractions run inside shard_map;
# the C x C Gram/Hadamard/pinv algebra and the (I_k, C) factor updates run
# at the global-array level (GSPMD inserts the small factor collectives),
# which is what keeps the distributed iterates numerically aligned with
# cp_als/als_sweep.  The algebra itself lives ONCE in repro.plan.sweep;
# these wrappers build the sharded plan + executor for the old signatures.
# --------------------------------------------------------------------------
def dist_als_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: Array,
    mode_axes: ModeAxes,
    mesh: Mesh,
    method: Method = "auto",
    normalize: bool = True,
) -> tuple[list[Array], Array, Array]:
    """One distributed ALS sweep; mirrors :func:`repro.core.cpals.als_sweep`."""
    from repro import plan as planlib

    return planlib.legacy_sweep(
        x, factors, weights, norm_x, it,
        strategy=method, normalize=normalize, mode_axes=mode_axes, mesh=mesh,
    )


def dist_dimtree_sweep(
    x: Array,
    factors: list[Array],
    weights: Array,
    norm_x: Array,
    it: Array,
    mode_axes: ModeAxes,
    mesh: Mesh,
    *,
    normalize: bool = True,
    split: int | None = None,
) -> tuple[list[Array], Array, Array]:
    """Distributed dimension-tree sweep; same iterates as the standard sweep.

    Two distributed X-sized partial contractions per sweep (instead of N
    full MTTKRPs): ``T_L`` from the old right factors, the per-mode updates
    of the left half from ``T_L``, then ``T_R`` from the *fresh* left
    factors and the right-half updates -- the schedule of the shared engine's
    dimtree path, so it reproduces standard-ALS iterates while reading the
    distributed tensor twice per sweep.
    """
    from repro import plan as planlib

    return planlib.legacy_sweep(
        x, factors, weights, norm_x, it,
        strategy="dimtree", normalize=normalize, split=split,
        mode_axes=mode_axes, mesh=mesh,
    )


def dist_cp_als(
    x: Array,
    rank: int,
    mode_axes: ModeAxes,
    mesh: Mesh,
    n_iters: int = 50,
    tol: float = 1.0e-5,
    *,
    seed: int = 0,
    method: Method = "auto",
    normalize: bool = True,
    dimtree: bool = False,
    init_factors: list[Array] | None = None,
    executor: str = "sharded",
) -> tuple[list[Array], Array, Array]:
    """Sharded CP-ALS driver; same init/stop logic as core ``cp_als``.

    Returns ``(factors, weights, fit)`` with factors row-distributed per
    ``mode_axes``.  ``dimtree=True`` swaps in the distributed
    dimension-tree sweep (identical iterates, 2 tensor reads per sweep).

    ``executor`` picks the communication strategy of the factor all-reduce:
    ``"sharded"`` (the frozen default -- plain psum), ``"overlapping"``
    (chunked psum hidden behind the local GEMMs; exact),
    ``"compressed"`` (int8 error-feedback all-gather; approximate, with the
    per-mode residuals threaded through the sweep), or ``"auto"`` to let
    :func:`repro.plan.select_executor` cost-argmin among them.

    Back-compat wrapper over the single :func:`repro.plan.cp_als` driver.
    """
    from repro import plan as planlib

    problem = planlib.Problem.from_tensor(x, rank, mode_axes=mode_axes, mesh=mesh)
    # the executor kind propagates verbatim (any executor now pairs with any
    # schedule: overlapping chunks and compressed compresses the dimtree
    # partials per node); the tree shape stays pinned to the wrapper's
    # historical behavior -- flat per-mode, or the binary split for dimtree
    sweep_plan = planlib.plan_sweep(
        problem,
        strategy="dimtree" if dimtree else method,
        normalize=normalize,
        executor=executor,
        schedule=None if dimtree else "flat",
    )
    st = planlib.cp_als(
        x,
        sweep_plan,
        executor=planlib.make_executor(sweep_plan.executor, mesh, mode_axes),
        n_iters=n_iters,
        tol=tol,
        seed=seed,
        init_factors=init_factors,
    )
    return st.factors, st.weights, st.fit
