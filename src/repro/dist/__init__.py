"""Distributed-memory extension of the paper's shared-memory MTTKRP.

``dist_mttkrp``: block-distributed MTTKRP/CP-ALS over a device mesh --
the device-for-thread port of the paper's parallelization, with the
communication structure of Ballard/Knight/Rouse (comm lower bounds for
MTTKRP) and Ballard/Hayashi/Kannan (parallel dense CP).

``collectives``: bandwidth-reducing collectives (int8 quantized
all-reduce with error feedback) and the data-parallel train step built
on them.
"""

from .collectives import compressed_psum, init_error_state, make_compressed_dp_step
from .dist_mttkrp import (
    dist_als_sweep,
    dist_cp_als,
    dist_dimtree_sweep,
    dist_mttkrp,
    shard_problem,
)

__all__ = [
    "compressed_psum",
    "init_error_state",
    "make_compressed_dp_step",
    "dist_als_sweep",
    "dist_cp_als",
    "dist_dimtree_sweep",
    "dist_mttkrp",
    "shard_problem",
]
