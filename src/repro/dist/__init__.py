"""Distributed-memory extension of the paper's shared-memory MTTKRP.

``dist_mttkrp``: block-distributed MTTKRP/CP-ALS over a device mesh --
the device-for-thread port of the paper's parallelization, with the
communication structure of Ballard/Knight/Rouse (comm lower bounds for
MTTKRP) and Ballard/Hayashi/Kannan (parallel dense CP).  The lower bounds
say the per-mode reduction volume cannot shrink, so the communication-
hiding variants attack latency instead: ``dist_mttkrp_overlapped`` chunks
the local kernel so each slab's psum runs under the next slab's GEMM
(exact), and ``dist_mttkrp_compressed`` + ``init_mttkrp_error_state``
swap the fp32 psum for the int8 error-feedback collective (approximate,
convergent).  The per-node contractions of a general dimension-tree
schedule (``repro.plan.schedule``) get the same treatment:
``dist_contract_range`` / ``dist_contract_partial`` place one minimal psum
per schedule node (chunked for overlap via ``n_chunks``), and the
``*_compressed`` variants run that node psum through the error-feedback
collective.

``collectives``: bandwidth-reducing collectives (int8 quantized
all-reduce with error feedback), hierarchical two-level collectives
(``hierarchical_psum`` = ``reduce_scatter`` within the node +
cross-node psum of the shard + ``all_gather`` back, so only ``1/k`` of
each block crosses the slow inter-node level), and the data-parallel
train step built on them.
"""

from .collectives import (
    all_gather,
    compressed_psum,
    hierarchical_psum,
    init_error_state,
    make_compressed_dp_step,
    reduce_scatter,
)
from .dist_mttkrp import (
    dist_als_sweep,
    dist_contract_partial,
    dist_contract_partial_compressed,
    dist_contract_range,
    dist_contract_range_compressed,
    dist_cp_als,
    dist_dimtree_sweep,
    dist_mttkrp,
    dist_mttkrp_compressed,
    dist_mttkrp_overlapped,
    init_mttkrp_error_state,
    shard_problem,
)

__all__ = [
    "all_gather",
    "compressed_psum",
    "hierarchical_psum",
    "init_error_state",
    "make_compressed_dp_step",
    "reduce_scatter",
    "dist_als_sweep",
    "dist_contract_partial",
    "dist_contract_partial_compressed",
    "dist_contract_range",
    "dist_contract_range_compressed",
    "dist_cp_als",
    "dist_dimtree_sweep",
    "dist_mttkrp",
    "dist_mttkrp_compressed",
    "dist_mttkrp_overlapped",
    "init_mttkrp_error_state",
    "shard_problem",
]
