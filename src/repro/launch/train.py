"""Production training driver: mesh + sharded state + fault-tolerant loop.

On a real fleet each host runs this same entry point;
``jax.distributed.initialize()`` wires the pods together and the data
pipeline shards per host.  In this container it runs on the host mesh
(--dp/--tp select the local mesh shape; more devices come from
XLA_FLAGS=--xla_force_host_platform_device_count).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b \
        --steps 50 --batch 8 --seq 128 --reduced
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import os

import jax

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
log = logging.getLogger("repro.launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--corpus", default=None, help="memmap token .bin (else synthetic)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host fleet)")
    args = ap.parse_args()

    if args.distributed:  # pragma: no cover -- real fleet only
        jax.distributed.initialize()

    from repro.configs import get_config
    from repro.data.pipeline import DataConfig, MemmapCorpus, SyntheticLM
    from repro.launch import mesh as meshlib
    from repro.models import build_model
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.optimizer import OptConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, vocab=min(cfg.vocab, 2048))
    model = build_model(cfg)

    host_id = jax.process_index()
    host_count = jax.process_count()
    dc = DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        host_id=host_id, host_count=host_count,
    )
    data = MemmapCorpus(args.corpus, dc) if args.corpus else SyntheticLM(dc)

    mesh = meshlib.make_host_mesh(args.dp, args.tp)
    log.info("mesh %s, arch %s, %d steps", dict(mesh.shape), cfg.name, args.steps)
    with meshlib.use_mesh(mesh):
        result = train_loop(
            model,
            data,
            OptConfig(lr=args.lr, total_steps=max(args.steps, 100)),
            LoopConfig(
                total_steps=args.steps,
                ckpt_every=args.ckpt_every,
                ckpt_dir=args.ckpt_dir,
                accum_steps=args.accum,
            ),
        )
    log.info(
        "done: step=%d final_loss=%.4f failures=%d stragglers=%s",
        result.step,
        result.metrics_history[-1]["loss"],
        result.failures,
        result.straggler_steps,
    )


if __name__ == "__main__":
    main()
