"""input_specs(): ShapeDtypeStruct stand-ins + shardings per (arch x shape).

Everything here is abstract (no device allocation): parameters and optimizer
states come from ``jax.eval_shape`` over the real init functions, inputs and
caches are constructed ShapeDtypeStructs, and shardings are attached directly
on the structs so ``jax.jit(fn).lower(*structs)`` picks them up.

Sharding decisions (see DESIGN.md S5):
  batch        -> dp = ('pod','data')/('data',); replicated when batch == 1
  params       -> 2-D FSDP x TP from the ParamDef logical specs
  KV cache     -> sequence/window axis over 'model' (split-K decode: every
                  chip reads 1/tp of the cache -- also sidesteps kv-head
                  counts not divisible by 16)
  SSM/LRU state-> inner width over 'model'
"""

from __future__ import annotations

import dataclasses
from dataclasses import replace
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import mesh as meshlib
from repro.models import Model, build_model
from repro.models.transformer import layer_types
from repro.train.optimizer import OptState

Array = jax.Array


def _dp(mesh: Mesh, batch: int):
    axes = meshlib.dp_axes(mesh)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if batch % size != 0:
        return None  # replicate (batch==1 long_500k)
    return axes if len(axes) > 1 else axes[0]


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def with_shardings(struct_tree: Any, spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)
        ),
        struct_tree,
        spec_tree,
    )


# --------------------------------------------------------------------------
# Params / optimizer structs
# --------------------------------------------------------------------------
def param_structs(model: Model, mesh: Mesh, *, serve: bool = False) -> Any:
    structs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = model.partition_specs(mesh, drop_fsdp=serve)
    return with_shardings(structs, specs, mesh)


def opt_structs(model: Model, mesh: Mesh) -> Any:
    p = param_structs(model, mesh)
    m = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=s.sharding), p)
    step = _sds((), jnp.int32, mesh, P())
    return OptState(step, m, jax.tree.map(lambda s: s, m))


# --------------------------------------------------------------------------
# Batch structs
# --------------------------------------------------------------------------
def train_batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    dp = _dp(mesh, b)
    batch = {"tokens": _sds((b, s + 1), jnp.int32, mesh, P(dp, None))}
    if cfg.mrope_sections:
        batch["positions"] = _sds((b, s + 1, 3), jnp.int32, mesh, P(dp, None, None))
    if cfg.is_encdec:
        batch["frames"] = _sds((b, s, cfg.d_model), jnp.float32, mesh, P(dp, None, None))
    return batch


def prefill_batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    b, s = shape.global_batch, shape.seq_len
    dp = _dp(mesh, b)
    batch = {"tokens": _sds((b, s), jnp.int32, mesh, P(dp, None))}
    if cfg.mrope_sections:
        batch["positions"] = _sds((b, s, 3), jnp.int32, mesh, P(dp, None, None))
    if cfg.is_encdec:
        batch["frames"] = _sds((b, s, cfg.d_model), jnp.float32, mesh, P(dp, None, None))
    return batch


# --------------------------------------------------------------------------
# Decode cache structs (sharding by family; see module docstring)
# --------------------------------------------------------------------------
def cache_structs(model: Model, shape: ShapeConfig, mesh: Mesh) -> Any:
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    dp = _dp(mesh, b)
    if cfg.is_encdec:
        return _encdec_cache_structs(model, shape, mesh, dp)
    struct = jax.eval_shape(lambda: model.init_cache(b, s))
    specs = _cache_spec_tree(cfg, struct, dp)
    return with_shardings(struct, specs, mesh)


def _cache_spec_tree(cfg: ModelConfig, struct: Any, dp) -> Any:
    from repro.models.attention import KVCache
    from repro.models.rglru import LRUState
    from repro.models.ssm import SSMState

    def kv_spec(x):  # (L, B, W, Hk, hd) or (B, W, Hk, hd)
        if x.ndim == 5:
            return P(None, dp, "model", None, None)
        return P(dp, "model", None, None)

    def entry_specs(e):
        if isinstance(e, SSMState):  # h (L?,B,di,N); conv (L?,B,K-1,di)
            if e.h.ndim == 4:
                return SSMState(P(None, dp, "model", None), P(None, dp, None, "model"))
            return SSMState(P(dp, "model", None), P(dp, None, "model"))
        if isinstance(e, LRUState):  # h (B,w); conv (B,K-1,w)
            return LRUState(P(dp, "model"), P(dp, None, "model"))
        if isinstance(e, KVCache):
            return KVCache(kv_spec(e.k), kv_spec(e.v))
        raise TypeError(type(e))

    from repro.models.transformer import DecodeCache

    entries = struct.entries
    if isinstance(entries, list):
        entry_sp = [entry_specs(e) for e in entries]
    else:
        entry_sp = entry_specs(entries)
    return DecodeCache(entry_sp, P())


def _encdec_cache_structs(model: Model, shape: ShapeConfig, mesh: Mesh, dp) -> Any:
    from repro.models.attention import KVCache
    from repro.models.encdec import EncDecCache

    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.compute_dtype)
    kv = KVCache(
        _sds((b, s, cfg.n_kv_heads, cfg.hd), dt, mesh, P(dp, "model", None, None)),
        _sds((b, s, cfg.n_kv_heads, cfg.hd), dt, mesh, P(dp, "model", None, None)),
    )
    self_kv = [kv for _ in range(cfg.dec_layers)]
    cross = (
        _sds((b, s, cfg.n_kv_heads, cfg.hd), dt, mesh, P(dp, "model", None, None)),
        _sds((b, s, cfg.n_kv_heads, cfg.hd), dt, mesh, P(dp, "model", None, None)),
    )
    cross_kv = [cross for _ in range(cfg.dec_layers)]
    return EncDecCache(self_kv, cross_kv, _sds((), jnp.int32, mesh, P()))


def decode_token_structs(shape: ShapeConfig, mesh: Mesh) -> Array:
    dp = _dp(mesh, shape.global_batch)
    return _sds((shape.global_batch, 1), jnp.int32, mesh, P(dp, None))


# --------------------------------------------------------------------------
# Cell assembly: (callable, example_args) for lower()
# --------------------------------------------------------------------------
def serve_config(cfg: ModelConfig) -> ModelConfig:
    """bf16 weights for inference cells."""
    return replace(cfg, param_dtype="bfloat16", remat=False)


def train_config(cfg: ModelConfig, seq_len: int) -> ModelConfig:
    # chunk long sequences (memory discipline; see models/attention.py);
    # respect an explicit seq_chunk already set on the config.  512 keeps the
    # per-chunk fp32 score tensor under ~0.5 GB even for 56-head archs.
    chunk = cfg.seq_chunk or (512 if seq_len > 8192 else 0)
    return replace(cfg, seq_chunk=chunk)


# Gradient-accumulation factors for train_4k, sized so the per-microbatch
# activation peak fits 16 GB HBM alongside fp32 masters + Adam states
# (measured via compiled.memory_analysis(); see EXPERIMENTS.md SDry-run).
TRAIN_ACCUM: dict[str, int] = {
    "dbrx-132b": 8,
    "deepseek-coder-33b": 4,
    "qwen2-vl-7b": 2,
    "qwen3-8b": 4,
    "h2o-danube-3-4b": 2,
    "qwen2-moe-a2.7b": 2,
    "recurrentgemma-2b": 16,
    "whisper-base": 4,
    "falcon-mamba-7b": 4,
}


def train_accum(cfg: ModelConfig) -> int:
    return TRAIN_ACCUM.get(cfg.name, 1)


def build_cell(arch_cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Returns (fn, args) ready for jax.jit(fn).lower(*args) under use_mesh."""
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import make_train_step

    if shape.kind == "train":
        cfg = train_config(arch_cfg, shape.seq_len)
        model = build_model(cfg)
        step = make_train_step(
            model, OptConfig(total_steps=1000), accum_steps=train_accum(cfg)
        )
        args = (
            param_structs(model, mesh),
            opt_structs(model, mesh),
            train_batch_structs(cfg, shape, mesh),
        )
        return step, args

    if shape.kind == "prefill":
        cfg = train_config(serve_config(arch_cfg), shape.seq_len)
        model = build_model(cfg)

        def prefill_step(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len + 1)

        args = (
            param_structs(model, mesh, serve=True),
            prefill_batch_structs(cfg, shape, mesh),
        )
        return prefill_step, args

    # decode
    cfg = serve_config(arch_cfg)
    model = build_model(cfg)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    args = (
        param_structs(model, mesh, serve=True),
        decode_token_structs(shape, mesh),
        cache_structs(model, shape, mesh),
    )
    return serve_step, args
