"""Production mesh + logical-axis sharding resolution.

Meshes (TPU v5e target):
  * single pod:  (data=16, model=16)            -- 256 chips
  * multi pod:   (pod=2, data=16, model=16)     -- 512 chips

Model code never names physical axes; it uses *logical* axes resolved here:

  "fsdp"   -> ('pod','data') | ('data',)   weight/optimizer sharding (ZeRO-3)
  "dp"     -> ('pod','data') | ('data',)   batch dimension
  "tp"     -> 'model'                      heads / d_ff / vocab (Megatron TP)
  "expert" -> 'model'                      MoE expert parallelism (EP co-located
                                           with TP; see models/moe.py)
  None     -> replicated

`make_production_mesh` is a function (not a module constant) so importing this
module never touches jax device state.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "repro_mesh", default=None
)
_MANUAL: contextvars.ContextVar[bool] = contextvars.ContextVar(
    "repro_manual", default=False
)


@contextlib.contextmanager
def manual_mode():
    """Mark a region as running *inside* shard_map (per-device code): sharding
    constraints become no-ops and nested collectives layers use local paths."""
    token = _MANUAL.set(True)
    try:
        yield
    finally:
        _MANUAL.reset(token)


def in_manual_mode() -> bool:
    return _MANUAL.get()


def _auto(n: int):
    return compat.auto_axis_types(n)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) == n:
        return compat.make_mesh(shape, axes, axis_types=_auto(len(axes)))
    if len(devices) > n:  # e.g. dry-run process exposes 512; single pod uses 256
        import numpy as np

        return compat.mesh_from_devices(
            np.asarray(devices[:n]).reshape(shape), axes, axis_types=_auto(len(axes))
        )
    raise RuntimeError(
        f"need {n} devices for mesh {shape}, have {len(devices)} -- set "
        "XLA_FLAGS=--xla_force_host_platform_device_count before importing jax"
    )


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over the locally available devices (tests / examples)."""
    return compat.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


def make_node_mesh(
    nodes: int, devices_per_node: int, *, axis_names: tuple[str, str] = ("node", "device")
) -> Mesh:
    """Two-level ``(nodes, devices_per_node)`` mesh for hierarchical collectives.

    Axis ``axis_names[0]`` (default ``"node"``) spans the *nodes* -- the
    slow-DCN level a flat ring would drag full blocks across -- and
    ``axis_names[1]`` (default ``"device"``) spans the devices within one
    node (the fast-ICI level ``repro.dist.collectives.hierarchical_psum``
    reduce-scatters over).  On real multi-host hardware the device order of
    ``jax.devices()`` already groups by process, so consecutive blocks of
    ``devices_per_node`` land on one host; on the CI fake-device backend the
    grouping is synthetic but exercises the identical collective structure.
    Declare the intra level to the planner via
    ``Problem(intra_axes=(axis_names[1],))``.
    """
    return compat.make_mesh(
        (int(nodes), int(devices_per_node)), tuple(axis_names), axis_types=_auto(2)
    )


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    token = _MESH.set(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Mesh | None:
    return _MESH.get()


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def dp_spec_entry(mesh: Mesh):
    """The data-parallel axes as one PartitionSpec entry: a tuple when the
    batch dim is sharded over several mesh axes, the bare name otherwise."""
    dp = dp_axes(mesh)
    return dp if len(dp) > 1 else dp[0]


def resolve_logical(logical: Sequence[Any] | None, mesh: Mesh) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``."""
    if logical is None:
        return P()
    out: list[Any] = []
    for ax in logical:
        if ax is None:
            out.append(None)
        elif ax in ("fsdp", "dp"):
            dp = dp_axes(mesh)
            out.append(dp if len(dp) > 1 else dp[0])
        elif ax in ("tp", "expert"):
            out.append("model")
        else:
            raise ValueError(f"unknown logical axis {ax!r}")
    return P(*out)


def named_sharding(logical: Sequence[Any] | None, mesh: Mesh | None = None):
    mesh = mesh or current_mesh()
    assert mesh is not None, "no mesh in context"
    return NamedSharding(mesh, resolve_logical(logical, mesh))


def constraint(x: jax.Array, *logical: Any) -> jax.Array:
    """``with_sharding_constraint`` against the ambient mesh; no-op without one.

    Model code calls this at layer boundaries; GSPMD propagates the rest.
    """
    mesh = current_mesh()
    if mesh is None or in_manual_mode():
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve_logical(logical, mesh))
    )


def tp_size(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    if mesh is None:
        return 1
    return mesh.shape.get("model", 1)
