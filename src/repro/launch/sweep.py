"""Dry-run sweep driver: one subprocess per (arch x shape x mesh) cell.

Each cell runs in a fresh process (jax locks the fake-device count at init;
isolation also bounds compile-memory growth).  Resumable: cells whose JSON
already records ok=true are skipped.  Run:

    PYTHONPATH=src python -m repro.launch.sweep --out results/dryrun
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cell_done(out_dir: str, arch: str, shape: str, mesh: str) -> bool:
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return False
    try:
        with open(path) as f:
            return bool(json.load(f).get("ok"))
    except Exception:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--timeout", type=int, default=1500)
    ap.add_argument("--archs", nargs="*", default=None)
    args = ap.parse_args()

    # ordered smallest-first so failures surface early
    archs = args.archs or [
        "olmo-1b", "whisper-base", "h2o-danube-3-4b", "qwen2-moe-a2.7b",
        "recurrentgemma-2b", "qwen3-8b", "qwen2-vl-7b", "falcon-mamba-7b",
        "deepseek-coder-33b", "dbrx-132b",
    ]
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    total = fail = skip = 0
    t0 = time.time()
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                total += 1
                if cell_done(args.out, arch, shape, mesh):
                    skip += 1
                    continue
                cmd = [
                    sys.executable, "-m", "repro.launch.dryrun",
                    "--arch", arch, "--shape", shape, "--mesh", mesh,
                    "--out", args.out,
                ]
                env = dict(os.environ)
                env.setdefault("PYTHONPATH", "src")
                try:
                    proc = subprocess.run(
                        cmd, env=env, timeout=args.timeout,
                        capture_output=True, text=True,
                    )
                    sys.stdout.write(proc.stdout[-400:] if proc.stdout else "")
                    if proc.returncode != 0:
                        fail += 1
                        sys.stdout.write(f"[rc={proc.returncode}] {arch} {shape} {mesh}\n")
                        sys.stdout.write((proc.stderr or "")[-600:] + "\n")
                except subprocess.TimeoutExpired:
                    fail += 1
                    sys.stdout.write(f"[TIMEOUT] {arch} {shape} {mesh}\n")
                sys.stdout.flush()
    print(f"sweep done: {total} cells, {skip} skipped, {fail} failed, "
          f"{time.time()-t0:.0f}s")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
