"""Subpackage."""
