"""Serving driver: load a checkpoint (or init), run the batched engine.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --requests 8 --new-tokens 16 [--ckpt-dir /tmp/repro_launch_train]
"""

from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import numpy as np

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
log = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None, help="restore params from here")
    args = ap.parse_args()

    from repro.checkpoint.manager import CheckpointManager
    from repro.configs import get_config
    from repro.launch import mesh as meshlib
    from repro.models import build_model
    from repro.serve.engine import GenerationConfig, ServeEngine
    from repro.train.optimizer import init_opt_state

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), vocab=min(cfg.reduced().vocab, 2048))
    model = build_model(cfg)

    mesh = meshlib.make_host_mesh(args.dp, args.tp)
    with meshlib.use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            (params, _), manifest = mgr.restore((params, init_opt_state(params)))
            log.info("restored step %s from %s", manifest["step"], args.ckpt_dir)
        eng = ServeEngine(
            model,
            params,
            GenerationConfig(
                max_new_tokens=args.new_tokens, temperature=args.temperature
            ),
            batch_size=args.batch_size,
        )
        rng = np.random.default_rng(0)
        for _ in range(args.requests):
            eng.submit(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 16))))
        t0 = time.perf_counter()
        results = eng.flush()
        dt = time.perf_counter() - t0
    total_tokens = sum(len(v) for v in results.values())
    log.info(
        "served %d requests / %d tokens in %.2fs (%.1f tok/s)",
        len(results), total_tokens, dt, total_tokens / dt,
    )


if __name__ == "__main__":
    main()
