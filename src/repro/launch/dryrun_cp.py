import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's own workload at pod scale: distributed CP-ALS.

Lowers one full distributed ALS sweep (dist/dist_mttkrp.dist_als_sweep) on the
production mesh for a pod-scale dense tensor (default: a 2048 time x 1024
subject x 400 x 400 region functional-connectivity tensor, 1.34 TB fp32 --
the paper's fMRI application grown to the scale its Sec. 3 calls for), and
records the same cost/memory/collective stats as the LM dry-run.

The MTTKRP method is selectable -- this is the SPerf hillclimb axis:
  1step : paper Alg. 3 with the explicit KRP (materializes K_L (.) K_R)
  2step : paper Alg. 4 (partial MTTKRP + multi-TTV)
  auto  : paper's recommended mix (Sec. 5.3.3)

    PYTHONPATH=src python -m repro.launch.dryrun_cp --method auto --mesh pod
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def run(shape, rank, method, mesh_kind, mode_axes, out_dir):
    from functools import partial

    from repro.analysis.roofline import parse_collectives
    from repro.dist.dist_mttkrp import (
        _factor_specs,
        _x_spec,
        dist_als_sweep,
        dist_dimtree_sweep,
    )
    from repro.launch.mesh import make_production_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    ndim = len(shape)

    x_struct = jax.ShapeDtypeStruct(
        tuple(shape), jnp.float32,
        sharding=NamedSharding(mesh, _x_spec(ndim, mode_axes)),
    )
    f_structs = [
        jax.ShapeDtypeStruct(
            (dim, rank), jnp.float32, sharding=NamedSharding(mesh, spec)
        )
        for dim, spec in zip(shape, _factor_specs(ndim, mode_axes))
    ]
    scalars = [
        jax.ShapeDtypeStruct((rank,), jnp.float32, sharding=NamedSharding(mesh, P())),
        jax.ShapeDtypeStruct((), jnp.float32, sharding=NamedSharding(mesh, P())),
        jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
    ]

    if method == "dimtree":
        fn = partial(dist_dimtree_sweep, mode_axes=mode_axes, mesh=mesh)
    else:
        fn = partial(dist_als_sweep, mode_axes=mode_axes, mesh=mesh, method=method)
    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(x_struct, f_structs, *scalars)
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0

    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    coll = parse_collectives(compiled.as_text())
    entries = 1
    for d in shape:
        entries *= d
    # MODEL_FLOPS for one ALS sweep: N modes x (2|X|C MTTKRP + small solves)
    model_flops = 2.0 * entries * rank * ndim

    record = {
        "kind": "cp_als_sweep",
        "shape": list(shape),
        "rank": rank,
        "method": method,
        "mesh": mesh_kind,
        "chips": mesh.size,
        "mode_axes": {str(k): v for k, v in mode_axes.items()},
        "model_flops": model_flops,
        "compile_s": round(compile_s, 2),
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": coll["total_bytes"],
        "coll_by_kind": coll["bytes_by_kind"],
        "coll_counts": coll["count_by_kind"],
        "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "ok": True,
    }
    os.makedirs(out_dir, exist_ok=True)
    axes_tag = "-".join(f"{k}{v[0]}" for k, v in sorted(mode_axes.items()))
    fname = os.path.join(out_dir, f"cpals__{method}__{mesh_kind}__{axes_tag}.json")
    with open(fname, "w") as f:
        json.dump(record, f, indent=1)
    print(
        f"[OK] cpals method={method} mesh={mesh_kind} axes={mode_axes}: "
        f"compile={compile_s:.1f}s flops={record['flops']:.3e} "
        f"bytes={record['bytes']:.3e} coll={record['coll_bytes']:.3e} "
        f"temp={record['temp_bytes']/1e9:.2f}GB -> {fname}",
        flush=True,
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", type=int, nargs="*", default=[2048, 1024, 400, 400])
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--method", default="auto",
                    choices=["auto", "1step", "2step", "einsum", "dimtree"])
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--axes", default="0:data,1:model",
                    help="mode:axis pairs, e.g. '0:data,1:model' or '0:pod,1:data,2:model'")
    ap.add_argument("--out", default="results/dryrun_cp")
    args = ap.parse_args()

    mode_axes = {}
    for pair in args.axes.split(","):
        k, v = pair.split(":")
        mode_axes[int(k)] = v
    run(tuple(args.shape), args.rank, args.method, args.mesh, mode_axes, args.out)


if __name__ == "__main__":
    main()
