"""CP serving driver: submit a mixed-signature tensor fleet, stream results.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve_cp --requests 16 --batch-size 8 \
        --rank 4 [--mesh] [--tuning-cache /path/cache.json]

Generates a fleet of small random tensors over two shapes (two signatures:
the scheduler must bucket them into separate compiled dispatches), submits
them all, drains the service, and logs problems/sec plus the serving
counters.  ``--mesh`` shards every dispatch's batch axis over all attached
devices (batch-parallel: zero collective traffic); ``--tuning-cache`` names
a persistent :class:`repro.plan.autotune.TuningCache` file to use as the
warm-plan store.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax

logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
log = logging.getLogger("repro.launch.serve_cp")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--rank", type=int, default=4)
    ap.add_argument("--n-iters", type=int, default=5)
    ap.add_argument("--dim", type=int, default=12, help="edge of the cubic shape")
    ap.add_argument("--mesh", action="store_true",
                    help="shard the batch axis over all attached devices")
    ap.add_argument("--tuning-cache", default=None,
                    help="persistent TuningCache file (the warm-plan store)")
    args = ap.parse_args()

    from repro.core.tensor_ops import random_tensor
    from repro.plan.autotune import TuningCache
    from repro.serve import CPService

    mesh = None
    if args.mesh:
        import math

        # batch-parallel sharding needs the device count to divide the batch
        n_dev = math.gcd(jax.device_count(), args.batch_size)
        mesh = jax.make_mesh((n_dev,), ("b",))
        log.info("batch-parallel over %d of %d devices", n_dev, jax.device_count())
    cache = TuningCache(args.tuning_cache) if args.tuning_cache else None
    svc = CPService(
        batch_size=args.batch_size, n_iters=args.n_iters, mesh=mesh,
        tuning_cache=cache,
    )
    # two shapes -> two signatures: the scheduler buckets them separately
    shapes = [(args.dim,) * 3, (args.dim, args.dim // 2, args.dim)]
    futures = [
        svc.submit(random_tensor(jax.random.PRNGKey(i), shapes[i % 2]), args.rank)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = svc.flush()
    dt = time.perf_counter() - t0
    assert all(f.done() for f in futures)
    stats = svc.stats()
    fits = [f.result().fit for f in done]
    log.info(
        "served %d problems in %.2fs (%.1f problems/s end-to-end, "
        "%.1f in-dispatch) mean fit %.4f",
        len(done), dt, len(done) / dt, stats["problems_per_s"],
        sum(fits) / len(fits),
    )
    log.info(
        "signatures=%d compiles=%d warm_plan_hits=%d batches=%d "
        "occupancy=%.2f padded=%d",
        stats["signatures"], stats["compiles"], stats["warm_plan_hits"],
        stats["batches"], stats["batch_occupancy"], stats["padded_slots"],
    )


if __name__ == "__main__":
    main()
