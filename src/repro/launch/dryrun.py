import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax import: jax locks the device count
at first init, and the production meshes need 512 placeholder host devices
(single-pod 16x16 uses the first 256).

Per cell this script:
  1. builds abstract inputs (ShapeDtypeStructs with shardings; no allocation),
  2. ``jax.jit(step).lower(*inputs).compile()`` -- proving the sharding config
     is coherent (no mismatched collectives, no impossible layouts),
  3. records ``compiled.memory_analysis()`` (fits-in-HBM proof) and
     ``compiled.cost_analysis()`` (FLOPs/bytes) plus parsed collective bytes,
  4. compiles L=1/L=2 unrolled probe variants for the scan-depth correction
     (analysis/roofline.py) on scanned architectures.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh pod \
      --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402


def _mesh(kind: str):
    from repro.launch.mesh import make_production_mesh

    return make_production_mesh(multi_pod=(kind == "multipod"))


def _compile_once(cfg, shape, mesh):
    """Lower+compile one variant; returns stats dict."""
    from repro.analysis.roofline import parse_collectives
    from repro.launch import mesh as meshlib
    from repro.launch.specs import build_cell

    with meshlib.use_mesh(mesh):
        fn, args = build_cell(cfg, shape, mesh)
        # donation mirrors deployment: train donates (params, opt_state);
        # decode donates the cache -- without it the "temp" report counts a
        # full extra copy of the donated state (4+ GB on 33B decode).
        donate = {"train": (0, 1), "decode": (2,)}.get(shape.kind, ())
        t0 = time.perf_counter()
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    stats = {"lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    try:
        cost = compiled.cost_analysis()
        stats["flops"] = float(cost.get("flops", 0.0))
        stats["bytes"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        stats["cost_error"] = str(e)
        stats["flops"] = stats["bytes"] = 0.0
    try:
        mem = compiled.memory_analysis()
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "generated_code_size_in_bytes",
            "alias_size_in_bytes",
        ):
            if hasattr(mem, attr):
                stats[attr] = int(getattr(mem, attr))
    except Exception as e:  # pragma: no cover
        stats["memory_error"] = str(e)
    coll = parse_collectives(compiled.as_text())
    stats["coll_bytes"] = coll["total_bytes"]
    stats["coll_by_kind"] = coll["bytes_by_kind"]
    stats["coll_counts"] = coll["count_by_kind"]
    return stats


def _probe_cfg(cfg, n_layers: int):
    pattern = cfg.block_pattern
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        scan_layers=False,
        seq_chunk=0,
        block_pattern=pattern[:1] if pattern else pattern,
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str, probes: bool = True):
    from repro.analysis.flops import model_flops, param_count
    from repro.configs import cell_is_applicable, get_config, get_shape
    from repro.models.transformer import is_scanned

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = _mesh(mesh_kind)
    chips = mesh.size

    from repro.launch.specs import train_accum

    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "n_layers": cfg.n_layers,
        "params": param_count(cfg),
        "model_flops": model_flops(cfg, shape),
        "accum_steps": train_accum(cfg) if shape.kind == "train" else 1,
        "ok": False,
    }
    applicable, why = cell_is_applicable(cfg, shape)
    if not applicable:
        record["skipped"] = why
        record["ok"] = True
    else:
        try:
            record["full"] = _compile_once(cfg, shape, mesh)
            if probes and is_scanned(cfg):
                record["probe1"] = _compile_once(_probe_cfg(cfg, 1), shape, mesh)
                record["probe2"] = _compile_once(_probe_cfg(cfg, 2), shape, mesh)
            record["ok"] = True
        except Exception as e:  # noqa: BLE001 -- recorded, nonzero exit below
            record["error"] = f"{type(e).__name__}: {e}"
            record["traceback"] = traceback.format_exc()[-4000:]

    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(fname, "w") as f:
        json.dump(record, f, indent=1)
    status = "SKIP" if record.get("skipped") else ("OK" if record["ok"] else "FAIL")
    full = record.get("full", {})
    print(
        f"[{status}] {arch} x {shape_name} x {mesh_kind}: "
        f"compile={full.get('compile_s', '-')}s flops={full.get('flops', 0):.3e} "
        f"coll={full.get('coll_bytes', 0):.3e}B -> {fname}",
        flush=True,
    )
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    from repro.configs import LM_SHAPES, list_archs

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in list_archs():
            for shape in LM_SHAPES:
                for mk in meshes:
                    cells.append((arch, shape, mk))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mk in meshes:
            cells.append((args.arch, args.shape, mk))

    failures = 0
    for arch, shape, mk in cells:
        rec = run_cell(arch, shape, mk, args.out, probes=not args.no_probes)
        failures += 0 if rec["ok"] else 1
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
