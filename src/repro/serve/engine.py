"""Batched LM serving engine: prefill + greedy/temperature decode.

The engine serves fixed-shape batches (compiled once per (batch, prompt_len,
max_len) signature -- the production pattern for TPU serving).  The request
queue and slot scheduler are the shared machinery of
:mod:`repro.serve.queue` (the same pattern drives the CP decomposition
service, :mod:`repro.serve.cp_service`): queued requests are packed into the
next fixed-size batch; finished sequences are padded out with EOS so the
batch shape stays static.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

from .queue import RequestQueue

Array = jax.Array


@dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1  # -1 => never stops early
    seed: int = 0


def generate(
    model: Model,
    params: Any,
    batch: dict,
    gen: GenerationConfig,
) -> np.ndarray:
    """Generate continuations for a batch of equal-length prompts.

    batch: {"tokens": (B, S) int32, ...family extras...}.  Returns
    (B, max_new_tokens) int32.
    """
    prompt_len = batch["tokens"].shape[1]
    max_len = prompt_len + gen.max_new_tokens + 1
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    cache, logits = prefill(params, batch)
    key = jax.random.PRNGKey(gen.seed)
    outs = []
    tok = _select(logits[:, -1, :], gen, key)
    for i in range(gen.max_new_tokens):
        outs.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = _select(logits[:, -1, :], gen, sub)
    return np.stack(outs, 1).astype(np.int32)


def _select(logits: Array, gen: GenerationConfig, key: jax.Array) -> Array:
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    scaled = logits.astype(jnp.float32) / gen.temperature
    return jax.random.categorical(key, scaled, -1).astype(jnp.int32)[:, None]


@dataclass
class Request:
    """One LM generation request: prompt tokens in, generated tokens out."""

    rid: int
    tokens: np.ndarray  # (S,)
    done: bool = False
    output: np.ndarray | None = None


@dataclass
class ServeEngine:
    """Micro engine: enqueue prompts, flush() packs them into fixed batches.

    The queue/slot-scheduler machinery is the shared
    :class:`repro.serve.queue.RequestQueue`; this engine keeps its
    historical surface (``submit`` returns an int rid, ``flush`` returns
    ``{rid: generated tokens}``) and serves a single bucket (every prompt
    shares one compiled signature family).
    """

    model: Model
    params: Any
    gen: GenerationConfig
    batch_size: int = 4
    max_pending: int | None = None
    _queue: RequestQueue = field(default_factory=RequestQueue)

    def __post_init__(self):
        self._queue = RequestQueue(self.max_pending)

    def submit(self, tokens: np.ndarray) -> int:
        """Enqueue one prompt; returns its request id.

        Raises :class:`repro.serve.queue.QueueFull` when ``max_pending``
        requests are already waiting.
        """
        req = self._queue.submit(
            Request(rid=-1, tokens=np.asarray(tokens, np.int32))
        )
        req.payload.rid = req.rid  # the queue owns rid assignment
        return req.rid

    def flush(self) -> dict[int, np.ndarray]:
        """Serve every queued request; returns rid -> generated tokens."""
        results: dict[int, np.ndarray] = {}
        while True:
            chunk = self._queue.take(self.batch_size)
            if not chunk:
                break
            s = max(len(r.payload.tokens) for r in chunk)
            toks = np.zeros((self.batch_size, s), np.int32)
            for i, r in enumerate(chunk):
                toks[i, s - len(r.payload.tokens) :] = r.payload.tokens  # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if self.model.cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (self.batch_size, s, self.model.cfg.d_model), jnp.float32
                )
            out = generate(self.model, self.params, batch, self.gen)
            for i, r in enumerate(chunk):
                results[r.rid] = out[i]
        return results
