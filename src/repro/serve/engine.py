"""Batched serving engine: prefill + greedy/temperature decode, request queue.

The engine serves fixed-shape batches (compiled once per (batch, prompt_len,
max_len) signature -- the production pattern for TPU serving).  A simple slot
scheduler packs queued requests into the next batch; finished sequences are
padded out with EOS so the batch shape stays static.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

Array = jax.Array


@dataclass
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 => greedy
    eos_id: int = -1  # -1 => never stops early
    seed: int = 0


def generate(
    model: Model,
    params: Any,
    batch: dict,
    gen: GenerationConfig,
) -> np.ndarray:
    """Generate continuations for a batch of equal-length prompts.

    batch: {"tokens": (B, S) int32, ...family extras...}.  Returns
    (B, max_new_tokens) int32.
    """
    prompt_len = batch["tokens"].shape[1]
    max_len = prompt_len + gen.max_new_tokens + 1
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step)

    cache, logits = prefill(params, batch)
    key = jax.random.PRNGKey(gen.seed)
    outs = []
    tok = _select(logits[:, -1, :], gen, key)
    for i in range(gen.max_new_tokens):
        outs.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, tok, cache)
        key, sub = jax.random.split(key)
        tok = _select(logits[:, -1, :], gen, sub)
    return np.stack(outs, 1).astype(np.int32)


def _select(logits: Array, gen: GenerationConfig, key: jax.Array) -> Array:
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    scaled = logits.astype(jnp.float32) / gen.temperature
    return jax.random.categorical(key, scaled, -1).astype(jnp.int32)[:, None]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (S,)
    done: bool = False
    output: np.ndarray | None = None


@dataclass
class ServeEngine:
    """Micro engine: enqueue prompts, flush() packs them into fixed batches."""

    model: Model
    params: Any
    gen: GenerationConfig
    batch_size: int = 4
    _queue: list[Request] = field(default_factory=list)
    _next_id: int = 0

    def submit(self, tokens: np.ndarray) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, np.asarray(tokens, np.int32)))
        return rid

    def flush(self) -> dict[int, np.ndarray]:
        """Serve every queued request; returns rid -> generated tokens."""
        results: dict[int, np.ndarray] = {}
        while self._queue:
            chunk = self._queue[: self.batch_size]
            self._queue = self._queue[self.batch_size :]
            s = max(len(r.tokens) for r in chunk)
            toks = np.zeros((self.batch_size, s), np.int32)
            for i, r in enumerate(chunk):
                toks[i, s - len(r.tokens) :] = r.tokens  # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            if self.model.cfg.is_encdec:
                batch["frames"] = jnp.zeros(
                    (self.batch_size, s, self.model.cfg.d_model), jnp.float32
                )
            out = generate(self.model, self.params, batch, self.gen)
            for i, r in enumerate(chunk):
                results[r.rid] = out[i]
        return results
