"""Decomposition-as-a-service: a CP serving engine over the batched plan stack.

The production workload the paper's Sec. 6 fMRI scenario implies -- a fleet
of *small, same-shaped* tensors (one subject = one tensor), not one huge
tensor -- is served here the way the LM engine serves prompts: clients
:meth:`CPService.submit` a tensor and get a :class:`CPFuture` back, a
scheduler buckets pending requests by *signature* (shape, rank, dtype,
device count, update options -- :meth:`repro.plan.problem.Problem.signature`
plus the per-request sweep budget), packs each bucket into fixed-size
batches, and executes them through the existing front door::

    Problem(batch=B) -> plan_sweep -> batched cp_als   (ONE compiled dispatch)

Compiled shapes stay static: a partial batch is padded by *cycling the real
requests into the dummy slots*.  Batch entries never interact inside the
sweep algebra (every contraction and solve is batched per-slice), so the
masked dummies provably cannot perturb the real problems' iterates -- and
because each dummy duplicates a real problem, even the shared convergence
stop (batch-max fit delta) behaves exactly as if the padding were absent.

One compile per signature: the service keys a per-signature dispatch cache
into ``cp_als(dispatch_cache=...)``, so the jitted sweep-chunk is built once
and every later batch of that signature dispatches compile-free.  The
persistent :class:`repro.plan.autotune.TuningCache` doubles as the warm-plan
store under the same signature: with ``strategy="autotune"`` (the default) a
signature tuned by :func:`repro.plan.autotune.tune` plans straight from its
hardware measurements (counted in ``stats()["warm_plan_hits"]``); untuned
signatures degrade cleanly to the analytic model.

The queue is the bounded FIFO+priority :class:`repro.serve.queue.RequestQueue`
(submission raises :class:`repro.serve.queue.QueueFull` at capacity --
client-visible backpressure), and ``stats()`` exposes the serving counters
(queue depth, batch occupancy, compiles, warm-plan hits, problems/sec) the
throughput benchmark reports.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.tensor_ops import random_factors
from repro.plan import Problem, cp_als, make_executor, plan_sweep
from repro.plan.autotune import lookup_measurements, problem_key

from .queue import QueueFull, RequestQueue

Array = jax.Array


@dataclass(frozen=True)
class CPResult:
    """One finished decomposition, as the client reads it back.

    ``factors`` are the per-mode ``(I_k, C)`` factor matrices and
    ``weights`` the ``(C,)`` lambdas of this request's own problem (the
    batch axis is already stripped); ``fit`` is the request's final fit,
    ``sweeps`` the executed sweep count of its dispatch, ``signature`` the
    batch bucket it was served under, and ``latency_s`` the submit-to-result
    wall time (queue wait included).
    """

    rid: int
    factors: list[Array]
    weights: Array
    fit: float
    sweeps: int
    signature: str
    latency_s: float


class CPFuture:
    """Handle returned by :meth:`CPService.submit`; resolves on dispatch.

    The service is synchronous (results land during ``step``/``flush``), so
    ``done()`` flips exactly when the owning batch executed.
    """

    def __init__(self, rid: int, signature: str):
        """Internal: built by the service with the queue-assigned rid."""
        self.rid = rid
        self.signature = signature
        self._result: CPResult | None = None

    def done(self) -> bool:
        """True once the owning batch has executed."""
        return self._result is not None

    def result(self) -> CPResult:
        """The resolved :class:`CPResult`; raises if the batch has not run
        yet (call :meth:`CPService.step` or :meth:`CPService.flush`)."""
        if self._result is None:
            raise RuntimeError(
                f"request {self.rid} is still pending -- step()/flush() the service"
            )
        return self._result


@dataclass
class _CPRequest:
    """Queue payload: one tensor + its decomposition options."""

    tensor: Array
    rank: int
    n_iters: int
    tol: float
    pp_tol: float
    init_factors: list[Array] | None
    seed: int
    future: CPFuture


@dataclass
class _SignatureState:
    """Per-signature compiled state: plan once, dispatch compile-free after."""

    problem: Problem
    plan: Any
    executor: Any  # None = cp_als's LocalExecutor default
    dispatch: dict = field(default_factory=dict)
    warm: bool = False


class CPService:
    """CP decomposition serving engine: submit tensors, stream results back.

    ``batch_size`` fixes the compiled batch extent ``B`` of every dispatch
    (one compile per signature; partial batches are padded).  ``n_iters`` /
    ``tol`` are the default per-request sweep budget and convergence
    tolerance (``tol=0.0`` runs exactly ``n_iters`` sweeps -- the
    deterministic serving default; a positive ``tol`` stops a batch when
    every problem's fit delta clears it, the batched driver's shared stop).
    ``sweeps_per_sync`` sets the driver's sweeps-per-dispatch chunk
    (``None`` = the whole request budget in ONE device dispatch, the
    sync-free serving fast path).  ``strategy`` + ``tuning_cache`` feed
    :func:`repro.plan.plan_sweep` -- the default ``"autotune"`` makes the
    persistent tuning cache a warm-plan store keyed by the same signature as
    the batch buckets.  ``mesh`` shards the batch axis of every dispatch
    over all its axes (batch-parallel: zero collective traffic;
    ``batch_size`` must be divisible by the mesh's device count).
    ``max_pending`` bounds the queue; a full queue rejects submission with
    :class:`repro.serve.queue.QueueFull`.  ``pp_tol > 0`` makes
    pairwise-perturbation sweeps the service default (overridable per
    request); PP requests bucket under their own signature, so exact and PP
    traffic never share a compiled dispatch.
    """

    def __init__(
        self,
        *,
        batch_size: int = 8,
        max_pending: int | None = None,
        n_iters: int = 20,
        tol: float = 0.0,
        sweeps_per_sync: int | None = None,
        strategy: str = "autotune",
        tuning_cache=None,
        mesh=None,
        pp_tol: float = 0.0,
    ):
        """See the class docstring for the knobs; validation happens here."""
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = int(batch_size)
        self.n_iters = int(n_iters)
        self.tol = float(tol)
        self.pp_tol = float(pp_tol)
        self.sweeps_per_sync = sweeps_per_sync
        self.strategy = strategy
        self.tuning_cache = tuning_cache
        self.mesh = mesh
        if mesh is not None:
            n_dev = math.prod(dict(mesh.shape).values())
            if self.batch_size % n_dev:
                raise ValueError(
                    f"batch_size {batch_size} not divisible by the mesh's "
                    f"{n_dev} devices (batch-parallel placement shards the "
                    "batch axis evenly)"
                )
        self._queue = RequestQueue(max_pending)
        self._states: dict[str, _SignatureState] = {}
        self._counters = {
            "submitted": 0,
            "completed": 0,
            "rejected": 0,
            "batches": 0,
            "compiles": 0,
            "warm_plan_hits": 0,
            "padded_slots": 0,
        }
        self._execute_s = 0.0

    # ------------------------------------------------------------ submission
    def _problem_for(
        self, tensor: Array, rank: int, pp_tol: float | None = None
    ) -> Problem:
        """The batched Problem one dispatch of this tensor's bucket solves."""
        axis_sizes = dict(self.mesh.shape) if self.mesh is not None else {}
        batch_axes = (
            tuple(self.mesh.axis_names)
            if self.mesh is not None and self.batch_size > 1
            else ()
        )
        return Problem(
            shape=tuple(tensor.shape),
            rank=int(rank),
            dtype=tensor.dtype,
            batch=self.batch_size,
            batch_axes=batch_axes,
            axis_sizes=axis_sizes,
            pp_tol=self.pp_tol if pp_tol is None else float(pp_tol),
        )

    def signature_of(self, tensor: Array, rank: int, *, n_iters: int | None = None,
                     tol: float | None = None, pp_tol: float | None = None) -> str:
        """Batch-bucket signature of one request: the canonical
        :meth:`repro.plan.problem.Problem.signature` of the *batched*
        problem (shape, rank, dtype, device count, batch, PP tolerance --
        via :func:`repro.plan.autotune.problem_key`, so it shares the tuning
        cache's key space) extended with the update options (sweep budget,
        tolerance) that shape the compiled dispatch.  A ``pp_tol > 0``
        request buckets separately from the exact one for the same tensor
        (its compiled dispatch carries the PP cache through the scan)."""
        n_iters = self.n_iters if n_iters is None else int(n_iters)
        tol = self.tol if tol is None else float(tol)
        base = problem_key(self._problem_for(tensor, rank, pp_tol))
        return f"{base}|i{n_iters}|t{tol:g}"

    def submit(
        self,
        tensor: Array,
        rank: int,
        *,
        n_iters: int | None = None,
        tol: float | None = None,
        pp_tol: float | None = None,
        init_factors: Sequence[Array] | None = None,
        seed: int = 0,
        priority: int = 0,
    ) -> CPFuture:
        """Enqueue one tensor for rank-``rank`` CP decomposition.

        Returns a :class:`CPFuture` that resolves when the request's batch
        executes (during :meth:`step`/:meth:`flush`).  ``n_iters``/``tol``/
        ``pp_tol`` override the service defaults (they are part of the
        signature: requests only share a dispatch when their update options
        match -- a pairwise-perturbation request never shares a compiled
        dispatch with an exact one);
        ``init_factors`` pins the initial factors (per-mode ``(I_k, C)``,
        unbatched -- the service stacks them into the batch), otherwise they
        are drawn from ``seed``.  Higher ``priority`` serves first, FIFO
        within a priority.  Raises :class:`repro.serve.queue.QueueFull` when
        ``max_pending`` requests are already waiting.
        """
        tensor = jnp.asarray(tensor)
        rank = int(rank)
        if tensor.ndim < 2:
            raise ValueError(f"expected an order >= 2 tensor, got shape {tensor.shape}")
        if init_factors is not None:
            init_factors = [jnp.asarray(u) for u in init_factors]
            want = [(d, rank) for d in tensor.shape]
            got = [tuple(u.shape) for u in init_factors]
            if got != want:
                raise ValueError(f"init_factors shapes {got} != expected {want}")
        sig = self.signature_of(tensor, rank, n_iters=n_iters, tol=tol, pp_tol=pp_tol)
        payload = _CPRequest(
            tensor=tensor,
            rank=rank,
            n_iters=self.n_iters if n_iters is None else int(n_iters),
            tol=self.tol if tol is None else float(tol),
            pp_tol=self.pp_tol if pp_tol is None else float(pp_tol),
            init_factors=init_factors,
            seed=int(seed),
            future=CPFuture(-1, sig),
        )
        try:
            req = self._queue.submit(payload, key=sig, priority=priority)
        except QueueFull:
            self._counters["rejected"] += 1
            raise
        payload.future.rid = req.rid
        self._counters["submitted"] += 1
        return payload.future

    # ------------------------------------------------------------- execution
    def _state_for(self, sig: str, payload: _CPRequest) -> _SignatureState:
        """Memoized per-signature plan/executor (the warm-plan lookup)."""
        state = self._states.get(sig)
        if state is not None:
            return state
        problem = self._problem_for(payload.tensor, payload.rank, payload.pp_tol)
        warm = (
            self.strategy == "autotune"
            and lookup_measurements(problem, cache=self.tuning_cache) is not None
        )
        plan = plan_sweep(
            problem, strategy=self.strategy, tuning_cache=self.tuning_cache
        )
        executor = None
        if plan.executor != "local":
            executor = make_executor(
                plan.executor,
                self.mesh,
                plan.problem.mode_axes,
                batch_axes=plan.problem.batch_axes,
            )
        state = _SignatureState(
            problem=plan.problem, plan=plan, executor=executor, warm=warm
        )
        if warm:
            self._counters["warm_plan_hits"] += 1
        self._states[sig] = state
        return state

    def _init_for(self, payload: _CPRequest) -> list[Array]:
        """One request's initial factors (pinned or drawn from its seed)."""
        if payload.init_factors is not None:
            return payload.init_factors
        return random_factors(
            jax.random.PRNGKey(payload.seed),
            payload.tensor.shape,
            payload.rank,
            payload.tensor.dtype,
        )

    def step(self) -> list[CPFuture]:
        """Execute ONE batched dispatch over the most urgent bucket.

        Takes up to ``batch_size`` same-signature requests (priority order,
        FIFO within), pads the batch by cycling the real requests into the
        empty slots, runs the bucket's compiled ``cp_als`` dispatch, and
        resolves exactly the real requests' futures -- returned in slot
        order.  Returns ``[]`` when nothing is pending.
        """
        sig = self._queue.next_key()
        if sig is None:
            return []
        chunk = self._queue.take(self.batch_size, sig)
        payloads = [r.payload for r in chunk]
        state = self._state_for(sig, payloads[0])
        B = self.batch_size
        n_iters, tol = payloads[0].n_iters, payloads[0].tol
        # pad by cycling the real requests: slot i >= len(chunk) duplicates a
        # real problem, so the shared convergence stop is unchanged and no
        # dummy can perturb anything (problems are independent per slice)
        slots = [payloads[i % len(payloads)] for i in range(B)]
        inits = [self._init_for(p) for p in slots]
        if B > 1:
            x = jnp.stack([p.tensor for p in slots])
            init = [
                jnp.stack([inits[b][m] for b in range(B)])
                for m in range(len(state.problem.shape))
            ]
        else:
            x = slots[0].tensor
            init = inits[0]
        if 0 not in state.dispatch:
            self._counters["compiles"] += 1  # the dispatch-cache miss compiles
        t0 = time.monotonic()
        st = cp_als(
            x,
            state.plan,
            executor=state.executor,
            n_iters=n_iters,
            tol=tol,
            init_factors=init,
            sweeps_per_sync=self.sweeps_per_sync or n_iters,
            dispatch_cache=state.dispatch,
            dispatch_key=0,
        )
        now = time.monotonic()
        self._execute_s += now - t0
        self._counters["batches"] += 1
        self._counters["padded_slots"] += B - len(chunk)
        self._counters["completed"] += len(chunk)
        futures = []
        for i, req in enumerate(chunk):
            if B > 1:
                factors = [u[i] for u in st.factors]
                weights, fit = st.weights[i], float(st.fit[i])
            else:
                factors, weights, fit = list(st.factors), st.weights, float(st.fit)
            req.payload.future._result = CPResult(
                rid=req.rid,
                factors=factors,
                weights=weights,
                fit=fit,
                sweeps=int(st.it),
                signature=sig,
                latency_s=now - req.submitted_at,
            )
            futures.append(req.payload.future)
        return futures

    def flush(self) -> list[CPFuture]:
        """Drain the queue: :meth:`step` until empty; resolved futures in
        completion order (results stream back batch by batch)."""
        out: list[CPFuture] = []
        while True:
            done = self.step()
            if not done:
                return out
            out.extend(done)

    # -------------------------------------------------------------- counters
    def stats(self) -> dict:
        """Serving counters for the benchmark / monitoring.

        ``queue_depth`` (pending now), ``submitted`` / ``completed`` /
        ``rejected`` (QueueFull backpressure events), ``batches`` and
        ``padded_slots``, ``batch_occupancy`` (mean real-slot fraction over
        executed batches), ``signatures`` (distinct buckets seen),
        ``compiles`` (jitted dispatches built -- one per signature),
        ``warm_plan_hits`` (signatures planned from tuning-cache
        measurements), ``execute_s`` and ``problems_per_s`` (completed real
        problems over in-dispatch seconds).
        """
        c = dict(self._counters)
        served_slots = c["completed"] + c["padded_slots"]
        c.update(
            queue_depth=self._queue.depth,
            signatures=len(self._states),
            batch_occupancy=(c["completed"] / served_slots) if served_slots else 1.0,
            execute_s=self._execute_s,
            problems_per_s=(
                c["completed"] / self._execute_s if self._execute_s > 0 else 0.0
            ),
        )
        return c
