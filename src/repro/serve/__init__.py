"""``repro.serve`` -- the serving layer: fixed-shape batches, one compile per signature.

Two engines over one pattern (clients submit work, a scheduler packs
same-signature requests into fixed-size compiled batches, results stream
back):

* :class:`CPService` (:mod:`repro.serve.cp_service`) -- decomposition as a
  service: submit tensors, get :class:`CPFuture` handles, batches run
  through ``Problem(batch=B) -> plan_sweep -> batched cp_als`` with the
  persistent tuning cache as the warm-plan store.
* :class:`ServeEngine` (:mod:`repro.serve.engine`) -- the LM micro engine
  (prefill + decode) the pattern was first prototyped on.

Both share the bounded FIFO+priority :class:`RequestQueue` of
:mod:`repro.serve.queue` (backpressure via :class:`QueueFull`).
"""

from .cp_service import CPFuture, CPResult, CPService
from .engine import GenerationConfig, Request, ServeEngine, generate
from .queue import PendingRequest, QueueFull, RequestQueue

__all__ = [
    "CPFuture",
    "CPResult",
    "CPService",
    "GenerationConfig",
    "PendingRequest",
    "QueueFull",
    "Request",
    "RequestQueue",
    "ServeEngine",
    "generate",
]
