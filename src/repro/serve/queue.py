"""Generic request queue + slot scheduler shared by the serving engines.

The production pattern both engines follow (the LLM :mod:`repro.serve.engine`
and the CP :mod:`repro.serve.cp_service`) is the same: clients submit work
and get a request id back, a scheduler packs pending requests into
fixed-size batches (one compiled dispatch per batch signature), and results
stream back as batches complete.  This module holds the engine-agnostic
half of that pattern:

* :class:`RequestQueue` -- a bounded in-process queue of
  :class:`PendingRequest` entries.  Requests carry a *key* (the batch
  bucket: only same-key requests may share one compiled dispatch) and a
  *priority*; dequeue order is priority-descending, FIFO within a priority.
  A full queue rejects submission with :class:`QueueFull` -- backpressure
  the caller can surface to its own clients.
* the slot scheduler is :meth:`RequestQueue.take`: pop up to ``batch_size``
  requests of one bucket, in serving order; :meth:`RequestQueue.next_key`
  names the bucket owning the globally most urgent request, so engines that
  serve multiple signatures pick the right bucket without peeking inside.

The queue is deliberately synchronous and single-process (matching the
engines' flush-driven execution); nothing here imports jax, so scheduling
policy stays testable without a device runtime.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Iterator


class QueueFull(RuntimeError):
    """Raised by :meth:`RequestQueue.submit` when the queue is at capacity.

    The bounded queue's backpressure signal: callers should retry after
    draining (``flush``/``step``) or surface the rejection to their client.
    """


@dataclass(frozen=True)
class PendingRequest:
    """One queued unit of work, as the scheduler orders it.

    ``rid`` is the queue-assigned id (also the FIFO tiebreak: rids increase
    in submission order); ``key`` is the batch bucket -- only requests with
    equal keys may be packed into one compiled dispatch; higher ``priority``
    serves first; ``submitted_at`` (monotonic seconds) feeds the engines'
    latency accounting; ``payload`` is engine-owned and opaque here.
    """

    rid: int
    payload: Any
    key: str = ""
    priority: int = 0
    submitted_at: float = field(default_factory=time.monotonic)

    def sort_index(self) -> tuple[int, int]:
        """Serving order: priority descending, then FIFO (rid ascending)."""
        return (-self.priority, self.rid)


class RequestQueue:
    """Bounded FIFO+priority queue with per-key batch buckets.

    ``max_pending`` caps the total pending count across every bucket
    (``None`` = unbounded); hitting the cap makes :meth:`submit` raise
    :class:`QueueFull` rather than grow without bound -- the engines expose
    that as client-visible backpressure.
    """

    def __init__(self, max_pending: int | None = None):
        """Create an empty queue holding at most ``max_pending`` requests."""
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_pending = max_pending
        self._pending: dict[str, list[PendingRequest]] = {}
        self._next_rid = 0

    def __len__(self) -> int:
        """Total pending requests across every bucket."""
        return sum(len(v) for v in self._pending.values())

    def __iter__(self) -> Iterator[PendingRequest]:
        """Every pending request, in global serving order."""
        return iter(sorted(
            (r for v in self._pending.values() for r in v),
            key=PendingRequest.sort_index,
        ))

    @property
    def depth(self) -> int:
        """Current queue depth (same as ``len``; the engines' counter name)."""
        return len(self)

    def submit(self, payload: Any, *, key: str = "", priority: int = 0) -> PendingRequest:
        """Enqueue ``payload`` under bucket ``key``; returns the entry.

        Raises :class:`QueueFull` when ``max_pending`` requests are already
        waiting (the queue is left unchanged).
        """
        if self.max_pending is not None and len(self) >= self.max_pending:
            raise QueueFull(
                f"queue full: {len(self)} pending >= max_pending={self.max_pending}"
            )
        req = PendingRequest(
            rid=self._next_rid, payload=payload, key=str(key), priority=int(priority)
        )
        self._next_rid += 1
        self._pending.setdefault(req.key, []).append(req)
        return req

    def keys(self) -> list[str]:
        """Buckets with pending work, most urgent front request first."""
        return sorted(
            self._pending,
            key=lambda k: min(r.sort_index() for r in self._pending[k]),
        )

    def next_key(self) -> str | None:
        """Bucket owning the most urgent pending request; ``None`` if empty."""
        ks = self.keys()
        return ks[0] if ks else None

    def take(self, batch_size: int, key: str | None = None) -> list[PendingRequest]:
        """Pop up to ``batch_size`` requests of one bucket, in serving order.

        ``key=None`` serves the :meth:`next_key` bucket.  Returns ``[]``
        when nothing is pending (or the named bucket is empty) -- the
        engines' drain loops stop on that.
        """
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if key is None:
            key = self.next_key()
        bucket = self._pending.get(key or "", [])
        if not bucket:
            return []
        bucket.sort(key=PendingRequest.sort_index)
        chunk, rest = bucket[:batch_size], bucket[batch_size:]
        if rest:
            self._pending[key] = rest
        else:
            del self._pending[key]
        return chunk
