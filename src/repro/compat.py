"""Version-compatibility shims over the installed jax.

The codebase is written against the jax >= 0.6 public API:

  * ``jax.shard_map(..., check_vma=...)``      (renamed from ``check_rep``)
  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)``
  * ``jax.sharding.Mesh(..., axis_types=...)``

Older jax (the container pins 0.4.37) predates all three.  Every
version-dependent call funnels through this module so call sites stay
written against the new API and the supported range stays wide
(see README "Supported jax versions"): on old jax the wrappers drop
``axis_types`` and translate ``check_vma`` -> ``check_rep``; on new jax
they pass everything through untouched.

Importing this module also installs ``jax.shard_map`` as an alias of the
wrapper when the attribute is missing, so scripts written against the
public >= 0.6 surface (``from jax import shard_map``) run unchanged as
long as anything under ``repro`` was imported first.
"""

from __future__ import annotations

import inspect
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

JAX_VERSION: tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit()
)

HAS_AXIS_TYPE: bool = hasattr(jax.sharding, "AxisType")

try:  # jax >= 0.6 public API
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)
_MAKE_MESH_PARAMS = frozenset(inspect.signature(jax.make_mesh).parameters)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` on jax >= 0.6; ``None`` (= "don't pass the
    kwarg") on older jax, where every mesh axis is implicitly auto."""
    if HAS_AXIS_TYPE:
        return (jax.sharding.AxisType.Auto,) * n
    return None


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices=None,
    axis_types=None,
) -> Mesh:
    """``jax.make_mesh`` that drops ``axis_types`` on jax < 0.6."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        kwargs["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def mesh_from_devices(devices, axis_names: Sequence[str], *, axis_types=None) -> Mesh:
    """``Mesh(device_array, names)`` constructor with optional ``axis_types``
    (only forwarded where the installed Mesh accepts it)."""
    devices = np.asarray(devices)
    if axis_types is not None and "axis_types" in inspect.signature(Mesh).parameters:
        return Mesh(devices, tuple(axis_names), axis_types=axis_types)
    return Mesh(devices, tuple(axis_names))


def shard_map(
    f,
    *,
    mesh,
    in_specs,
    out_specs,
    check_vma: bool | None = None,
    check_rep: bool | None = None,
    **kwargs,
):
    """``jax.shard_map`` with the replication-check flag translated to
    whatever name the installed jax uses (``check_vma`` >= 0.6,
    ``check_rep`` before).  Accepts either spelling."""
    flag = check_vma if check_vma is not None else check_rep
    if flag is not None:
        if "check_vma" in _SHARD_MAP_PARAMS:
            kwargs["check_vma"] = flag
        elif "check_rep" in _SHARD_MAP_PARAMS:
            kwargs["check_rep"] = flag
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


if not hasattr(jax, "shard_map"):  # pre-0.6: expose the public alias
    jax.shard_map = shard_map
