"""Qwen3-8B [hf:Qwen/Qwen3-8B; hf].  Dense GQA kv=8 with per-head QK-RMSNorm."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=12288,
    vocab=151936,
    head_dim=128,
    rope_theta=1.0e6,
    qk_norm=True,
    norm="rmsnorm",
    act="swiglu",
    source="[hf:Qwen/Qwen3-8B; hf]",
)
