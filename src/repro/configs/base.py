"""Model / shape configuration schema shared by all assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention options ---
    rope_theta: float = 1.0e4
    qk_norm: bool = False                     # qwen3
    sliding_window: int = 0                   # h2o-danube (0 = full)
    mrope_sections: tuple[int, ...] = ()      # qwen2-vl M-RoPE half-dim split
    norm: str = "rmsnorm"                     # rmsnorm | layernorm | layernorm_np
    act: str = "swiglu"                       # swiglu | geglu | gelu
    logit_softcap: float = 0.0

    # --- MoE ---
    n_experts: int = 0
    n_experts_per_tok: int = 0
    d_ff_expert: int = 0
    d_ff_shared: int = 0                      # qwen2-moe shared expert
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    dt_rank: int = 0
    expand: int = 2

    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple[str, ...] = ()       # cycle over ('rec','rec','attn')
    local_window: int = 0                     # local attention window
    lru_width: int = 0

    # --- encoder-decoder (whisper) ---
    enc_layers: int = 0
    dec_layers: int = 0

    # --- substrate knobs ---
    tie_embeddings: bool = False
    scan_layers: bool = True
    remat: bool = True
    seq_shard: bool = True                    # Megatron-style sequence parallelism:
                                              # layer-boundary activations sharded
                                              # (dp, tp, -) -- 16x less saved-carry HBM
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    seq_chunk: int = 0                        # q-chunk for long-seq attention / ssm scan
    cp_rank: int = 0                          # CP-factorized FFN (paper technique hook)

    # provenance note: "[source; verified-tier]"
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (bounded attention state)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def reduced(self) -> "ModelConfig":
        """Smoke-test scale config of the same family (CPU-runnable)."""
        def cap(v, m):
            return min(v, m) if v else v

        pattern = self.block_pattern
        n_layers = min(self.n_layers, 3 if not pattern else len(pattern))
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=cap(self.d_model, 64),
            n_heads=cap(self.n_heads, 4),
            n_kv_heads=cap(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=cap(self.d_ff, 128),
            vocab=cap(self.vocab, 256),
            n_experts=cap(self.n_experts, 8),
            n_experts_per_tok=cap(self.n_experts_per_tok, 2),
            d_ff_expert=cap(self.d_ff_expert, 64),
            d_ff_shared=cap(self.d_ff_shared, 64),
            ssm_state=cap(self.ssm_state, 8),
            dt_rank=cap(self.dt_rank, 8),
            lru_width=cap(self.lru_width, 64),
            sliding_window=cap(self.sliding_window, 16),
            local_window=cap(self.local_window, 16),
            mrope_sections=(2, 3, 3) if self.mrope_sections else (),
            enc_layers=cap(self.enc_layers, 2),
            dec_layers=cap(self.dec_layers, 2),
            compute_dtype="float32",
            scan_layers=self.scan_layers,
            seq_chunk=0,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The documented skip rules (DESIGN.md 'Shape-cell skips')."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode cache excluded by brief"
    return True, ""
