"""DBRX-132B [hf:databricks/dbrx-base; unverified].

Fine-grained MoE: 16 experts, top-4 routing, every layer MoE (no dense FFN).
GQA kv=8, head_dim 128, LayerNorm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,            # per-expert FFN width
    vocab=100352,
    head_dim=128,
    rope_theta=5.0e5,
    norm="layernorm",
    act="swiglu",
    n_experts=16,
    n_experts_per_tok=4,
    d_ff_expert=10752,
    source="[hf:databricks/dbrx-base; unverified]",
)
