"""Architecture config registry:  get_config(name) / list_archs()."""

from __future__ import annotations

from .base import LM_SHAPES, ModelConfig, ShapeConfig, cell_is_applicable
from .dbrx_132b import CONFIG as _dbrx
from .deepseek_coder_33b import CONFIG as _dsc
from .falcon_mamba_7b import CONFIG as _mamba
from .h2o_danube3_4b import CONFIG as _danube
from .olmo_1b import CONFIG as _olmo
from .qwen2_moe_a2_7b import CONFIG as _qmoe
from .qwen2_vl_7b import CONFIG as _qvl
from .qwen3_8b import CONFIG as _q3
from .recurrentgemma_2b import CONFIG as _rg
from .whisper_base import CONFIG as _whisper

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [_qvl, _dbrx, _qmoe, _whisper, _olmo, _dsc, _q3, _danube, _rg, _mamba]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)


def get_shape(name: str) -> ShapeConfig:
    if name not in LM_SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(LM_SHAPES)}")
    return LM_SHAPES[name]


__all__ = [
    "ARCHS",
    "LM_SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cell_is_applicable",
    "get_config",
    "get_shape",
    "list_archs",
]
