"""Falcon-Mamba-7B [arXiv:2410.05355; unverified].

Attention-free Mamba-1 stack: 64 layers of (in_proj -> causal conv4 -> SiLU ->
selective SSM (d_state 16) -> gate -> out_proj), d_inner = 2*d = 8192,
dt_rank = d/16 = 256.  The selective scan is a chunked associative scan
(TPU-native parallel scan; chunking bounds the (B, S_c, d_inner, d_state)
discretized-state intermediate).  O(1) decode state -> long_500k eligible.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm_state=16,
    ssm_conv=4,
    dt_rank=256,
    expand=2,
    norm="rmsnorm",
    source="[arXiv:2410.05355; unverified]",
)
