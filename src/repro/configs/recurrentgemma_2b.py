"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427; hf].

Hybrid: RG-LRU recurrent blocks + local attention, pattern (rec, rec, attn)
repeating over 26 layers.  MQA (kv=1), head_dim 256, GeGLU MLP, local window
2048.  Recurrent state is O(1) in sequence length -> long_500k eligible.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=1.0e4,
    norm="rmsnorm",
    act="geglu",
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    lru_width=2560,
    scan_layers=False,     # heterogeneous pattern: loop
    source="[arXiv:2402.19427; hf]",
)
