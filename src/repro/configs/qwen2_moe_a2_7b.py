"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

Fine-grained MoE: 60 routed experts top-4 (padded to 64 for EP divisibility
over the 16-way model axis; the router emits -inf for pads) plus a shared
expert of width 4x1408 = 5632 that every token uses.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,             # per-expert (fine-grained)
    vocab=151936,
    head_dim=128,
    rope_theta=1.0e6,
    norm="rmsnorm",
    act="swiglu",
    n_experts=60,
    n_experts_per_tok=4,
    d_ff_expert=1408,
    d_ff_shared=5632,
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
)
