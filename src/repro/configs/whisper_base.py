"""Whisper-base backbone [arXiv:2212.04356; unverified].

Encoder-decoder; the conv1d audio frontend is a STUB per the brief --
input_specs() provides precomputed frame embeddings (B, S, d_model) for the
encoder plus decoder token ids.  Bidirectional encoder self-attention,
causal decoder self-attention + cross-attention, GELU MLP, LayerNorm,
sinusoidal (enc) / learned (dec) absolute positions.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=12,           # 6 encoder + 6 decoder
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    scan_layers=False,     # shallow heterogeneous stack: loop
    source="[arXiv:2212.04356; unverified]",
)
