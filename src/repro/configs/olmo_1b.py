"""OLMo-1B [arXiv:2402.00838; hf].

Non-parametric LayerNorm (no scale/bias), SwiGLU, RoPE, tied embeddings.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    head_dim=128,
    rope_theta=1.0e4,
    norm="layernorm_np",
    act="swiglu",
    tie_embeddings=True,
    source="[arXiv:2402.00838; hf]",
)
