"""H2O-Danube-3-4B [arXiv:2401.16818; unverified].

Llama+Mistral mix: dense GQA kv=8 with sliding-window attention (window 4096,
ring-buffer decode cache) -- the SWA bound makes this arch eligible for the
long_500k shape.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    head_dim=120,          # 3840 / 32
    rope_theta=1.0e4,
    sliding_window=4096,
    norm="rmsnorm",
    act="swiglu",
    source="[arXiv:2401.16818; unverified]",
)
