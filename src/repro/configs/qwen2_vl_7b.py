"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

VLM: the vision frontend (dynamic-resolution ViT) is a STUB per the brief --
input_specs() provides token ids plus the 3-channel M-RoPE position ids the
frontend would emit.  The backbone implements M-RoPE for real (head_dim 128,
half-dim split 16/24/24 over temporal/height/width position streams).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    head_dim=128,
    rope_theta=1.0e6,
    mrope_sections=(16, 24, 24),
    norm="rmsnorm",
    act="swiglu",
    source="[arXiv:2409.12191; hf]",
)
